"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run            # everything
  python -m benchmarks.run --quick    # reduced grids (CI)
  python -m benchmarks.run --only alignment

Tables/figures covered:
  Fig 2b      bench_flops_vs_time   FLOPs ≠ runtime (motivates stage 2)
  Tables 1–2  bench_ds_reduction    DS size per pruning stage
  Figs 5–8    bench_alignment       ratio_FLOPs / ratio_Memory
  Fig 11      bench_fc_fraction     FC share of inference time
  Figs 12–14  bench_einsum_kernels  first/middle/final kernels, CB0–CB7
  Fig 15      bench_end_to_end      dense vs TT FC layers (§6.4 picks)
  Fig 16      bench_breakdown       progressive optimization stages
  §Roofline   repro.analysis.roofline --table  (reads results/dryrun)
  DESIGN §8   bench_quant           int8-resident kernels: weights x
                                    backend x depth (+ fused-under-int8
                                    showcase) -> results/BENCH_quant.json
  DESIGN §12  bench_dse_quality     analytic-proxy vs quality-gated DSE
                                    fronts per config family ->
                                    results/BENCH_dse.json
"""
from __future__ import annotations

import argparse
import sys
import time


BENCHES = ["ds_cloud", "ds_reduction", "alignment", "einsum_kernels",
           "end_to_end", "breakdown", "fc_fraction", "flops_vs_time",
           "serve_tt", "quant", "dse_quality"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, choices=BENCHES)
    args = ap.parse_args()

    # $REPRO_COMPILE_CACHE (launch.cache): benchmark reruns skip every
    # compile a previous invocation already paid for
    from repro.launch.cache import enable_compile_cache
    cache_dir = enable_compile_cache()
    if cache_dir:
        print(f"# persistent compile cache: {cache_dir}")

    names = [args.only] if args.only else BENCHES
    t_all = time.time()
    failures = []
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(quick=args.quick)
        except Exception as e:           # keep the harness going
            failures.append((name, repr(e)))
            print(f"!! bench_{name} FAILED: {e!r}")
        print(f"# bench_{name}: {time.time() - t0:.1f}s")
    print(f"\n# total: {time.time() - t_all:.1f}s")
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
