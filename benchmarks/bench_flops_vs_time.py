"""Paper Fig 2b: FLOPs and execution time do NOT always align.

For one FC layer we take surviving TT solutions with similar parameter
counts, time each on this host, and report the rank correlation between
Eq.(11) FLOPs and measured time.  The paper's motivating observation —
that low-FLOPs solutions can execute slowly (shape/stride effects) — is
what justifies its low-level (inference-time) pruning stage.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.dse import DSEConfig, explore
from repro.core.tt import tt_apply, tt_init

from .common import header, row, time_fn

M, N = 512, 512          # paper Fig 2 uses 120×84; 512² gives a richer DS
BATCH = 16


def run(quick: bool = False) -> None:
    res = explore(M, N, DSEConfig(vl=8, rank_step=8, rank_cap=32))
    sols = res.solutions[: (8 if quick else 20)]
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (BATCH, N))
    header(f"Fig 2b: FLOPs vs measured time, FC [{N}->{M}] "
           f"({len(sols)} solutions)",
           ["plan", "d", "flops", "params", "time_us", "gflops"])
    flops, times = [], []
    fn = jax.jit(tt_apply, static_argnums=())
    for s in sols:
        cores = tt_init(key, s.plan)
        t = time_fn(lambda c, xx: tt_apply(c, xx), cores, x,
                    warmup=2, iters=5)
        flops.append(s.flops)
        times.append(t)
        print(row("x".join(map(str, s.plan.ms)) + "|"
                  + "x".join(map(str, s.plan.ns)),
                  s.d, s.flops, s.params, f"{t*1e6:.0f}",
                  f"{BATCH*s.flops/t/1e9:.2f}"))
    fr = np.argsort(np.argsort(flops)).astype(float)
    tr = np.argsort(np.argsort(times)).astype(float)
    rho = float(np.corrcoef(fr, tr)[0, 1])
    print(row("SPEARMAN_RHO", "", "", "", "", f"{rho:.3f}"))
    print("# paper claim: rho < 1 — FLOPs alone do not predict runtime; "
          "the DSE's inference-time stage is justified"
          if rho < 0.999 else "# WARNING: perfectly correlated on this host")


if __name__ == "__main__":
    run()
