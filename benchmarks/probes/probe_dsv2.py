"""Probe: where do dsv2-lite's excess HLO FLOPs come from?
Compile one MoE layer fwd+bwd (unrolled, 16x16 mesh) and ablate parts."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build, get_config
from repro.distributed import sharding as shd
from repro.models import transformer as tf
from repro.models import moe as moe_mod
from repro.models.spec import is_spec

mesh = jax.make_mesh((16, 16), ("data", "model"))
cfg = get_config("deepseek_v2_lite_16b", "full")
rules = dict(shd.ACT_RULES_TRAIN)
shd.set_ctx(shd.ShardCtx(mesh, rules, ("data",)))

B, S = 256, 4096
tf.SCAN_UNROLL = True


def flops_of(counts, label):
    model = build(cfg, counts=counts)
    spec_tree = model.param_specs()
    shard_tree = shd.param_shardings(spec_tree, mesh, fsdp=True)
    params_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        spec_tree, shard_tree, is_leaf=is_spec)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def loss_fn(p, b):
        return model.loss(p, b, remat=False)

    def step(p, b):
        return jax.value_and_grad(loss_fn)(p, b)

    lowered = jax.jit(step).lower(params_sds, batch)
    c = lowered.compile()
    ca = c.cost_analysis()
    ca = ca if isinstance(ca, dict) else ca[0]
    print(f"{label:28s} flops/dev={ca.get('flops', 0):.3e} "
          f"bytes/dev={ca.get('bytes accessed', 0):.3e}")
    return ca.get("flops", 0)


# 1 dense layer only vs dense + 1 moe layer → isolate one MoE layer's cost
f_dense = flops_of({0: 1, 1: 0}, "1 dense layer (g1=0)")
f_moe1 = flops_of({0: 1, 1: 1}, "dense + 1 moe layer")
print(f"one MoE layer marginal: {f_moe1 - f_dense:.3e} flops/dev "
      f"(x256 = {(f_moe1 - f_dense) * 256:.3e} global)")
# analytic: routed+shared ≈ 1.4e14+3.6e13 fwd, ~3x for bwd ≈ 5.2e14 global
print("analytic expectation ≈ 5.2e14 global")

# --- ablate: replace the cumsum position assignment with a fake one --------
import repro.models.moe as M

orig = M.moe_apply

def moe_no_cumsum(p, cfg_, x, backend="xla"):
    m = cfg_.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eidx = jax.lax.top_k(probs, m.top_k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)
    C = int(np.ceil(m.top_k * T / m.num_experts * m.capacity_factor))
    C = max(C, 8)
    e_flat = eidx.reshape(-1)
    # FAKE positions (wrong math, same shapes/ops minus cumsum)
    pos_in_e = (jnp.arange(T * m.top_k) % C)
    keep = pos_in_e < C
    tok = jnp.repeat(jnp.arange(T), m.top_k)
    buf = jnp.zeros((m.num_experts, C + 1, d), x.dtype)
    buf = buf.at[e_flat, pos_in_e].set(xt[tok], mode="drop")
    buf = M.shard_act(buf, ("act_experts", None, None))
    ys = M._expert_mlp(p["experts"], buf[:, :C], backend)
    ys = M.shard_act(ys, ("act_experts", None, None))
    y_tok = ys.at[e_flat, jnp.minimum(pos_in_e, C - 1)].get(
        mode="fill", fill_value=0)
    y_tok = jnp.where(keep[:, None], y_tok, 0)
    w = gate.reshape(-1)[:, None].astype(y_tok.dtype)
    y = jnp.zeros_like(xt).at[tok].add(y_tok * w)
    if m.num_shared:
        y = y + M.mlp_apply(p["shared"], xt, backend)
    return y.reshape(B, S, d)

M.moe_apply = moe_no_cumsum
import repro.models.transformer as tfm
tfm.moe_apply = moe_no_cumsum
f_moe_nc = flops_of({0: 1, 1: 1}, "dense + 1 moe (no cumsum)")
print(f"marginal without cumsum: {(f_moe_nc - f_dense):.3e} flops/dev")
M.moe_apply = orig
tfm.moe_apply = orig
