"""Probe: largest all-gather / all-reduce ops in one compiled MoE layer."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re
import sys
import jax
import jax.numpy as jnp

from repro.configs import build, get_config
from repro.distributed import sharding as shd
from repro.models import transformer as tf
from repro.models.spec import is_spec
from repro.analysis.roofline import _COLL_RE, _shape_bytes_list, _group_size

arch = sys.argv[1] if len(sys.argv) > 1 else "deepseek_v2_lite_16b"
mesh = jax.make_mesh((16, 16), ("data", "model"))
cfg = get_config(arch, "full")
shd.set_ctx(shd.ShardCtx(mesh, dict(shd.ACT_RULES_TRAIN), ("data",)))
B, S = 256, 4096
tf.SCAN_UNROLL = True

model = build(cfg, counts={0: 1, 1: 1} if arch != "mixtral_8x7b" else {0: 1})
spec_tree = model.param_specs()
shard_tree = shd.param_shardings(spec_tree, mesh, fsdp=True)
params_sds = jax.tree.map(
    lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
    spec_tree, shard_tree, is_leaf=is_spec)
batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def step(p, b):
    return jax.value_and_grad(lambda pp, bb: model.loss(pp, bb,
                                                        remat=False))(p, b)


txt = jax.jit(step).lower(params_sds, batch).compile().as_text()
ops = []
for line in txt.splitlines():
    m = _COLL_RE.search(line)
    if not m:
        continue
    shapes = _shape_bytes_list(m.group(1))
    g = _group_size(line)
    if not shapes or g <= 1:
        continue
    ops.append((max(shapes), m.group(2), g, line.strip()[:120]))
ops.sort(reverse=True)
from collections import Counter
tot = Counter()
for b_, kind, g, _ in ops:
    tot[kind] += b_
print("totals (sum of op result bytes):",
      {k: f"{v:.3e}" for k, v in tot.items()})
print("\ntop 12 ops:")
for b_, kind, g, line in ops[:12]:
    print(f"  {b_:.3e}B g={g:3d} {kind:18s} {line[:100]}")
