"""Probe: where do the TT variant's extra HLO bytes come from? (qwen3)"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
import jax.numpy as jnp

from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.distributed import sharding as shd
from repro.models import transformer as tf
from repro.models.spec import is_spec

mesh = jax.make_mesh((16, 16), ("data", "model"))
shd.set_ctx(shd.ShardCtx(mesh, dict(shd.ACT_RULES_TRAIN), ("data",)))
B, S = 256, 4096
tf.SCAN_UNROLL = True


def cost(tt, remat, label, layers=1):
    cfg = get_config("qwen3_32b", "full", tt=tt)
    model = build(cfg, counts={0: layers})
    spec_tree = model.param_specs()
    shard_tree = shd.param_shardings(spec_tree, mesh, fsdp=True)
    params_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        spec_tree, shard_tree, is_leaf=is_spec)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def step(p, b):
        return jax.value_and_grad(
            lambda pp, bb: model.loss(pp, bb, remat=remat))(p, b)

    c = jax.jit(step).lower(params_sds, batch).compile()
    ca = c.cost_analysis()
    ca = ca if isinstance(ca, dict) else ca[0]
    print(f"{label:34s} flops/dev={ca.get('flops', 0):.3e} "
          f"bytes/dev={ca.get('bytes accessed', 0):.3e}")
    return ca.get("flops", 0), ca.get("bytes accessed", 0)


TT = TTConfig(enabled=True, families=("ffn",), rank=16, length=2,
              min_factor=8, backend="xla")
f0, b0 = cost(None, True, "dense 1L remat")
f1, b1 = cost(TT, True, "tt-ffn 1L remat")
f2, b2 = cost(None, False, "dense 1L norem")
f3, b3 = cost(TT, False, "tt-ffn 1L norem")
print(f"\nmarginal bytes tt-vs-dense: remat {b1-b0:+.3e}  norem {b3-b2:+.3e}")
print(f"remat cost: dense {b0-b2:+.3e}  tt {b1-b3:+.3e}")

print("\n-- after tt_m -> model sharding (re-import not needed; rules are "
      "read at param_shardings time) --")
f4, b4 = cost(TT, True, "tt-ffn 1L remat (m-sharded)")
print(f"tt-vs-dense marginal now: {b4-b0:+.3e} B/dev "
      f"(was {b1-b0:+.3e})")
