"""Paper Tables 1–2: design-space reduction per pruning stage.

For each studied FC layer shape we report the size of the solution space
after every stage of the §4 pipeline:

  all_initial → alignment → vectorization → initial-layer → scalability

Stages 0–2 are counted analytically (they reach 1e20+); stages 3–4 are the
enumerated survivors.  Compare against the magnitudes in Tables 1–2.
"""
from __future__ import annotations

from repro.core.dse import DSEConfig, count_stages, explore

from .common import header, row

# (model, [M_out, N_in]) — paper Tables 1–2 (a representative subset; the
# full table is just more rows of the same computation)
CNN_LAYERS = [
    ("LeNet5", 120, 400), ("LeNet5", 84, 120),
    ("LeNet300", 300, 784), ("LeNet300", 100, 300),
    ("AlexNet-c10", 2048, 4096), ("AlexNet-c10", 2048, 2048),
    ("AlexNet-imnet", 4096, 9216), ("AlexNet-imnet", 4096, 4096),
    ("AlexNet-imnet", 1000, 4096),
    ("VGG-c10", 512, 512), ("VGG-c10", 256, 512),
    ("VGG-imnet", 4096, 25088),
    ("ResNet", 1000, 2048), ("GoogleNet", 1000, 1024),
    ("Xception", 1000, 2048),
]

LLM_LAYERS = [
    ("GPT2-Medium", 1024, 1024), ("GPT2-Medium", 4096, 1024),
    ("GPT2-Medium", 1024, 4096),
    ("GPT2-Large", 1280, 1280), ("GPT2-Large", 5120, 1280),
    ("GPT3-Ada", 768, 3072), ("GPT3-Curie", 2048, 2048),
    ("GPT3-Curie", 8192, 2048),
]


def run(quick: bool = False) -> None:
    cfg = DSEConfig(vl=8, rank_step=8)
    layers = CNN_LAYERS + LLM_LAYERS
    if quick:
        layers = layers[:6] + LLM_LAYERS[:3]
    header("Tables 1-2: DS reduction per stage",
           ["model", "M", "N", "all_initial", "aligned", "vectorized",
            "initial_layer", "scalability", "alignment_reduction_x"])
    for name, M, N in layers:
        res = explore(M, N, cfg, with_counts=True)
        c = res.counts
        red = c["all_initial"] / max(c["aligned"], 1)
        print(row(name, M, N, f"{c['all_initial']:.1e}",
                  f"{c['aligned']:.1e}", f"{c['vectorized']:.1e}",
                  c["initial_layer"], c["scalability"], f"{red:.1f}"))


if __name__ == "__main__":
    run()
