"""Paper Fig 15: end-to-end FC-layer speedup of TT-factorized vs dense.

For every §6.4 deployment (model, [N_in, M_out], factorization, R=8) we
time the dense matmul (the "uncompressed IREE" baseline) against the TT
chain over the DSE-chosen plan, batch 32, and report the measured speedup
plus the analytic FLOPs/params reduction that drives it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dse import best_plan
from repro.core.flops import dense_flops, dense_params
from repro.core.tt import tt_apply, tt_init

from .common import header, row, time_fn

# §6.4 list: (model, M_out, N_in)
DEPLOYMENTS = [
    ("ResNet", 1000, 2048),
    ("Xception", 1000, 2048),
    ("VGG", 512, 512), ("VGG", 256, 512), ("VGG", 100, 256),
    ("GoogleNet", 1000, 1024),
    ("AlexNet", 2048, 4096), ("AlexNet", 2048, 2048), ("AlexNet", 10, 2048),
    ("GPT2-M", 1024, 1024), ("GPT2-M", 1024, 4096), ("GPT2-M", 4096, 1024),
]

BATCH = 32


def run(quick: bool = False) -> None:
    deps = DEPLOYMENTS[:5] if quick else DEPLOYMENTS
    header("Fig 15: dense vs TT-factorized FC layers (R=8, d=2, batch=32)",
           ["model", "M", "N", "plan", "params_x", "flops_x",
            "dense_ms", "tt_ms", "speedup"])
    key = jax.random.PRNGKey(0)
    dense_fn = jax.jit(lambda x, W: x @ W)
    tt_fn = jax.jit(lambda cores, x: tt_apply(cores, x))
    total_d = total_t = 0.0
    for name, M, N in deps:
        plan = best_plan(M, N, rank=8, length=2)
        if plan is None:
            print(row(name, M, N, "none", "-", "-", "-", "-", "-"))
            continue
        k1, k2 = jax.random.split(jax.random.fold_in(key, M * N))
        W = jax.random.normal(k1, (N, M), jnp.float32)
        x = jax.random.normal(k2, (BATCH, N), jnp.float32)
        cores = tt_init(k1, plan)
        t_dense = time_fn(dense_fn, x, W)
        t_tt = time_fn(tt_fn, cores, x)
        total_d += t_dense
        total_t += t_tt
        print(row(name, M, N,
                  f"{'x'.join(map(str, plan.ms))}|{'x'.join(map(str, plan.ns))}",
                  f"{dense_params(M, N, False)/plan.params:.1f}",
                  f"{dense_flops(M, N, False)/plan.flops:.1f}",
                  f"{t_dense*1e3:.3f}", f"{t_tt*1e3:.3f}",
                  f"{t_dense/t_tt:.2f}"))
    print(row("MEAN", "", "", "", "", "", "", "",
              f"{total_d/max(total_t, 1e-12):.2f}"))


if __name__ == "__main__":
    run()
