"""Paper Fig 11: fraction of inference time spent in FC layers.

The paper profiles TFLite models on the K1 board; here we time our smoke
models' prefill with the FC projections (a) intact and (b) replaced by
identity-cost stubs, attributing the difference to the FC share.  The
claim being reproduced: FC layers dominate LM-family inference time and
are therefore the right compression target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import build, get_config
from repro.configs.shapes import concrete_batch

from .common import header, row, time_fn

ARCHS = ["deepseek_7b", "qwen3_32b", "gemma3_4b", "mamba2_2p7b",
         "internvl2_2b"]


def run(quick: bool = False) -> None:
    header("Fig 11: FC-layer share of inference time (smoke configs, CPU)",
           ["arch", "full_ms", "attn_only_ms", "fc_share_pct"])
    archs = ARCHS[:3] if quick else ARCHS
    B, S = 2, 64
    for arch in archs:
        cfg = get_config(arch, "smoke")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = concrete_batch(cfg, B, S)

        fwd = jax.jit(lambda p, b: model.loss(p, b, remat=False))
        t_full = time_fn(fwd, params, batch, warmup=1, iters=3)

        # zero-width FC proxy: drop the FFN/projection cost by zeroing the
        # heavy weights' contribution (multiply by 0 keeps shapes; XLA
        # cannot elide the matmuls, so instead we time a model whose d_ff
        # is cut to the minimum the family allows)
        import dataclasses
        if cfg.d_ff:
            thin = dataclasses.replace(cfg, d_ff=8)
        elif cfg.moe:
            thin = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, expert_ff=8))
        else:                               # ssm: shrink expansion
            thin = dataclasses.replace(
                cfg, ssm=dataclasses.replace(cfg.ssm, expand=1))
        model_t = build(thin)
        params_t = model_t.init(jax.random.PRNGKey(0))
        fwd_t = jax.jit(lambda p, b: model_t.loss(p, b, remat=False))
        t_thin = time_fn(fwd_t, params_t, batch, warmup=1, iters=3)

        share = max(0.0, 1 - t_thin / t_full)
        print(row(arch, f"{t_full*1e3:.1f}", f"{t_thin*1e3:.1f}",
                  f"{share*100:.0f}"))


if __name__ == "__main__":
    run()
