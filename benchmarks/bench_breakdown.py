"""Paper Fig 16: performance gain per progressive optimization stage.

Stages (hardware-adapted, DESIGN.md §2 table):

  stage0_naive    loop-faithful chain: einsum per step, runtime transposes
                  (the paper's 'GCC -O3 unoptimized' analogue)
  stage1_packed   compile-time array packing: cores pre-packed, contraction
                  is matmul-only (paper §4.3.1 + §4.3.3 vectorize)
  stage2_fused    whole chain jit-fused, reshapes eliminated by indexing
                  (paper §4.3.2 + register blocking; XLA fuses the VMEM-
                  resident path the Pallas fused2 kernel implements on TPU)
  stage3_batched  batch-parallel over tokens (paper §4.3.5 parallelize —
                  the CPU analogue is one fused call over the whole batch
                  instead of a Python loop over batch tiles)

We report per-stage speedup over stage0 for the §6.4 GPT2-M layers at
rank 16 (the paper's Fig 16 configuration).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dse import best_plan
from repro.core.packing import pack_core
from repro.core.tt import tt_init

from .common import header, row, time_fn

LAYERS = [("GPT2M-attn", 1024, 1024), ("GPT2M-up", 4096, 1024),
          ("GPT2M-down", 1024, 4096), ("ResNet-fc", 1000, 2048)]
BATCH = 64
RANK = 16


def stage0_naive(cores, x):
    """Chain with runtime-transposed einsums and materialized reshapes."""
    B = x.shape[0]
    state = x.reshape(-1)
    b = state.shape[0]
    for t in range(len(cores) - 1, -1, -1):
        G = cores[t]
        r0, nt, mt, r1 = G.shape
        st = state.reshape(b // (nt * r1), nt, r1)
        out = jnp.einsum("rnmk,bnk->mbr", G, st)
        state = out.reshape(-1)
        b = state.shape[0]
    return state.reshape(b // B, B).T


def make_stage1_packed(cores):
    packs = [pack_core(G) for G in cores]
    dims = [G.shape for G in cores]

    def f(x):
        B = x.shape[0]
        state = x.reshape(-1)
        b = state.shape[0]
        for t in range(len(packs) - 1, -1, -1):
            r0, nt, mt, r1 = dims[t]
            st = state.reshape(b // (nt * r1), nt * r1)
            out = st @ packs[t]                    # [b, mt*r0]
            # paper layout: out[m, b, r0] — keep the m-major order
            state = out.reshape(-1, mt, r0).transpose(1, 0, 2).reshape(-1)
            b = state.shape[0]
        return state.reshape(b // B, B).T
    return f


def make_stage2_fused(cores):
    """d=2 fused path: two matmuls, relayouts by indexing (no transposes
    through memory at step boundaries — XLA fuses them into the matmuls)."""
    assert len(cores) == 2
    G1, G2 = cores
    _, n1, m1, r1 = G1.shape
    _, n2, m2, _ = G2.shape
    p2 = pack_core(G2)        # [n2, m2*r1]
    p1 = pack_core(G1)        # [n1*r1, m1]

    def f(x):
        B = x.shape[0]
        a = x.reshape(B * n1, n2) @ p2
        a = a.reshape(B, n1, m2, r1).transpose(0, 2, 1, 3)
        y = a.reshape(B * m2, n1 * r1) @ p1
        return y.reshape(B, m2, m1).transpose(0, 2, 1).reshape(B, m1 * m2)
    return f


def run(quick: bool = False) -> None:
    layers = LAYERS[:2] if quick else LAYERS
    header(f"Fig 16: optimization breakdown (rank={RANK}, batch={BATCH})",
           ["layer", "M", "N", "t0_naive_ms", "t1_packed_ms", "t2_fused_ms",
            "t3_batched_ms", "spd_packed", "spd_fused", "spd_batched"])
    key = jax.random.PRNGKey(0)
    for name, M, N in layers:
        plan = best_plan(M, N, rank=RANK, length=2)
        cores = tt_init(jax.random.fold_in(key, M + N), plan)
        x = jax.random.normal(jax.random.fold_in(key, M), (BATCH, N))

        f0 = jax.jit(stage0_naive)
        f1 = jax.jit(make_stage1_packed(cores))
        f2 = jax.jit(make_stage2_fused(cores))
        # stage3: batched = fused over 4x the batch in ONE call vs 4 calls
        xb = jnp.concatenate([x] * 4)
        f3 = jax.jit(make_stage2_fused(cores))

        t0 = time_fn(lambda xx: f0(cores, xx), x)
        t1 = time_fn(f1, x)
        t2 = time_fn(f2, x)
        t3 = time_fn(f3, xb) / 4.0            # per-batch-equivalent
        print(row(name, M, N, f"{t0*1e3:.3f}", f"{t1*1e3:.3f}",
                  f"{t2*1e3:.3f}", f"{t3*1e3:.3f}",
                  f"{t0/t1:.2f}", f"{t0/t2:.2f}", f"{t0/t3:.2f}"))


if __name__ == "__main__":
    run()
