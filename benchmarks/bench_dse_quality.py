"""Data-aware DSE quality gate: analytic-proxy vs measured fronts.

The point this benchmark proves (DESIGN.md §12, ISSUE 7 acceptance): the
analytic funnel's static ordering — flops/bytes/err_proxy — can crown a
TT plan that measurably damages model quality, and the study engine's
quality gate changes that pick.  Per config family:

1. A briefly-trained dense reference model (synthetic affine data — rank
   must correlate with quality, which an untrained net's noise weights
   cannot provide) is calibrated (``Model.activation_stats``) and every
   surviving (plan × weight-dtype) candidate of its FFN projection is
   evaluated end-to-end by ``core.study.make_model_evaluator``:
   activation-aware error, perplexity delta vs dense, scheduler decode
   tok/s — all through frozen ``TTExecutionPlan``s (zero re-resolutions,
   asserted per trial).
2. Two fronts are compared: the NO-GATE front (static axes: flops, bytes,
   err_proxy) and the GATED front (measured axes: flops, bytes, tok/s,
   ppl-delta) after ``apply_quality_gate`` with a perplexity budget
   τ = best_delta + 0.25·(worst − best) — plans in the top quarter of
   observed quality pass, the rest are rejected.
3. The tripwire: in ≥ 1 family the gated front's cheapest survivor is a
   DIFFERENT plan than the analytic front's cheapest — with the measured
   perplexity delta and tok/s of both picks recorded so the flip is
   auditable, not asserted into existence.

Trial grid: length-2 plans at ranks {4, 8, 16} × {fp32, int8} on the
smoke FFN shape [d_model → d_ff] — low ranks are statically cheapest and
(on a trained net) measurably worst, which is exactly the failure mode
the gate exists to catch.

Writes ``results/BENCH_dse.json``: per family the full trial table, both
fronts, τ, and the analytic-vs-gated picks.
"""
from __future__ import annotations

import json
import os

from repro.configs import get_config
from repro.core.dse import (DEFAULT_AXES, DSEConfig, QualityGate,
                            apply_quality_gate, pareto_front)
from repro.core.study import EvaluatorConfig, Study, make_model_evaluator

from .common import header, row

FAMILIES = [("deepseek-7b", "dense"), ("qwen3-32b", "dense-qknorm")]
STATE_DIR = os.path.join("results", "dse_studies")


def _sol_row(s) -> dict:
    return {"plan": s.plan.describe(), "ms": list(s.plan.ms),
            "ns": list(s.plan.ns), "ranks": list(s.plan.ranks),
            "weight_dtype": s.weight_dtype, "flops": s.flops,
            "bytes": s.bytes, "err_proxy": s.err_proxy,
            "act_err": s.act_err, "ppl_delta": s.ppl_delta,
            "tok_s": s.tok_s}


def _family(arch: str, label: str, quick: bool, seed: int = 0) -> dict:
    cfg = get_config(arch, "smoke")
    M, N = cfg.d_ff, cfg.d_model
    # length-2 plans, ranks {4, 8, 16}, fp32 + int8 twins — quick mode
    # keeps the SAME grid and training depth (the flip lives in the
    # factorization spread at low rank, which a coarser grid loses) and
    # economizes on trial count + serving steps instead
    dse = DSEConfig(vl=4, rank_step=4, rank_cap=16, max_d=2, min_factor=2,
                    weight_dtypes=("fp32", "int8"))
    ecfg = EvaluatorConfig(train_steps=60,
                           n_calib=2, n_eval=2, batch=2, seq=32,
                           measure_tok_s=True,
                           serve_steps=4 if quick else 8)
    evaluate = make_model_evaluator(cfg, ecfg, seed=seed)
    state = os.path.join(STATE_DIR, f"{arch}_{M}x{N}.json")
    if os.path.exists(state):
        os.unlink(state)                  # benches re-measure, not resume
    study = Study.create(state, M, N, dse, seed=seed,
                         max_trials=8 if quick else 12)
    study.run(evaluate, batch_size=4)
    res = study.result()
    if not res.solutions:
        raise AssertionError(
            f"{arch}: no completed trials — "
            f"{[t.metrics for t in study.trials]}")

    # analytic view: static axes only, cheapest survivor is the pick
    front_nogate = pareto_front(res.solutions, axes=DEFAULT_AXES)
    analytic_pick = res.solutions[0]      # static (flops, params, bytes)

    # gated view: perplexity budget τ from the observed spread
    deltas = [s.ppl_delta for s in res.solutions]
    lo, hi = min(deltas), max(deltas)
    tau = lo + 0.25 * (hi - lo)
    metrics_of = {(s.plan, s.weight_dtype):
                  {"act_err": s.act_err, "ppl_delta": s.ppl_delta,
                   "tok_s": s.tok_s} for s in res.solutions}
    gate = QualityGate(
        evaluate=lambda s: metrics_of[(s.plan, s.weight_dtype)],
        max_ppl_delta=tau, top_k=len(res.solutions))
    gated = apply_quality_gate(res, gate)
    gated_pick = gated.solutions[0] if gated.solutions else None
    front_gated = gated.measured_front(
        axes=("flops", "bytes", "tok_s", "ppl_delta"))

    flip = (gated_pick is not None
            and (gated_pick.plan, gated_pick.weight_dtype)
            != (analytic_pick.plan, analytic_pick.weight_dtype))
    header(f"{arch} [{N}→{M}] τ={tau:.4f}",
           ["pick", "plan", "dtype", "flops", "bytes", "ppl_delta",
            "tok_s"])
    for name, s in (("analytic", analytic_pick), ("gated", gated_pick)):
        print(row(name, s.plan.describe(), s.weight_dtype, s.flops,
                  s.bytes, f"{s.ppl_delta:+.4f}", f"{s.tok_s:.1f}"))
    print(f"# no-gate front: {len(front_nogate)} solutions | gated "
          f"front: {len(front_gated)} | gate rejected "
          f"{gated.counts['quality_gated']}/{len(res.solutions)} | "
          f"pick changed: {flip}")
    return {"arch": arch, "family": label, "M": M, "N": N,
            "tau": tau, "gate_changes_pick": flip,
            "trials": [_sol_row(s) for s in res.solutions],
            "analytic_pick": _sol_row(analytic_pick),
            "gated_pick": _sol_row(gated_pick) if gated_pick else None,
            "quality_gated": gated.counts["quality_gated"],
            "front_nogate": [_sol_row(s) for s in front_nogate],
            "front_gated": [_sol_row(s) for s in front_gated]}


def run(quick: bool = False) -> None:
    os.makedirs(STATE_DIR, exist_ok=True)
    fams = FAMILIES[:1] if quick else FAMILIES
    out = {"schema": 1, "quick": quick,
           "families": [_family(arch, label, quick)
                        for arch, label in fams]}
    flips = [f["arch"] for f in out["families"] if f["gate_changes_pick"]]
    print(f"\n# families where the gate changed the best pick: "
          f"{flips or 'NONE'}")
    # the acceptance tripwire: the measured gate must matter somewhere
    assert flips, ("quality gate changed no family's pick — the measured "
                   "accuracy loop is not doing its job")
    os.makedirs("results", exist_ok=True)
    with open(os.path.join("results", "BENCH_dse.json"), "w") as f:
        json.dump(out, f, indent=1)
    print("# wrote results/BENCH_dse.json")


if __name__ == "__main__":
    run()
