"""Model-level serving comparison: dense vs TT-compressed decode throughput,
fixed-batch loop vs continuous-batching scheduler (dense and block-paged
pools), swept over slot counts, plus a shared-prefix workload measuring
what hash-based prefix reuse buys at admission time.

The paper's Fig 15 compares layer-level execution; this bench closes the
loop at the model level on this host.  Per slot count B three decode loops
are measured post-compile:

  fixed — the lockstep loop (scalar cache position, jitted decode_step)
  sched — the dense slot-pool scheduler at full occupancy
  paged — the block-paged scheduler at full occupancy (same masked step,
          attention through block-table gather/scatter)

Each scheduler record carries its KV-pool bytes and (paged) the block
high-water mark — the dense-vs-paged pool-bytes column is the memory
argument of DESIGN.md §7.  The prefix workload admits N requests sharing a
long prompt prefix twice — prefix cache off vs on — and reports admission
wall time and the measured hit rate; the reduction is the prefill compute
the resident blocks saved.

The cold-start workload (DESIGN.md §13) launches ``launch.serve
--first-token`` twice as real subprocesses sharing one persistent
compilation cache: the first pays every compile (cold), the second must
re-jit NOTHING (asserted via the cache entry count) and be measurably
faster from process start to first token — the restart cost a crash-safe
deployment actually pays.

The long-prompt-adversary workload (DESIGN.md §15) queues short requests
behind one multi-thousand-token prompt and reports their p50/p95
time-to-first-token under monolithic vs chunked admission — the measured
p95 TTFT win of folding prefill chunks into the decode step.

The mesh-scaling sweep (DESIGN.md §14) serves the TT model over 1/2/4
forced host devices at a fixed slots-per-device, one subprocess per
measurement, asserting zero TT plan re-resolutions and paged≡dense token
identity on every mesh — see ``_mesh_scaling`` for how the single-core
container's forced serialization is reported vs corrected.  Results land
in ``results/BENCH_serve.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.configs.shapes import concrete_batch
from repro.serving.scheduler import Request, Scheduler

from .common import header, row

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"
BLOCK = 16


def _fixed_throughput(model, params, B, S, steps):
    """Steady-state decode tok/s of the lockstep loop (post-compile)."""
    batch = dict(concrete_batch(model.cfg, B, S), cache_len=S + steps + 2)
    logits, cache = model.jitted_prefill(S + steps + 2)(
        params, {"tokens": batch["tokens"]})
    step = model.jitted_decode_step()
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits, cache = step(params, cache, tok)          # compile
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(steps):
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        logits, cache = step(params, cache, tok)
    jax.block_until_ready(logits)
    return B * steps / (time.perf_counter() - t0)


def _sched_throughput(model, params, B, S, steps, paged):
    """Steady-state decode tok/s of a scheduler pool at full occupancy:
    B requests admitted, then ``steps`` masked decode steps with no
    admissions/retirements in the timed window.  Returns
    (tok/s, pool stats)."""
    budget = steps + 4                     # stays active through the window
    sched = Scheduler(model, params, num_slots=B,
                      cache_len=S + budget + 2, paged=paged,
                      block_size=BLOCK)
    for b in range(B):
        toks = concrete_batch(model.cfg, 1, S, seed=b)["tokens"]
        sched.submit(Request(uid=b, inputs={"tokens": toks},
                             max_new_tokens=budget))
    sched.step()                           # admissions + first masked step
    sched.step()                           # warm steady step
    t0 = time.perf_counter()
    for _ in range(steps):
        sched.step()
    return B * steps / (time.perf_counter() - t0), sched.stats()


def _prefix_workload(model, params, n_req, prefix_len, tail, steps):
    """Admission wall time of n_req requests sharing a prefix_len-token
    prompt prefix, paged pool, prefix cache off vs on.  The scheduler and
    every jit entry are warmed by the first (off) pass + a throwaway
    warm-up request per mode, so the measured difference is prefill
    compute, not compiles."""
    S = prefix_len + tail
    cache_len = S + steps + 2
    prefix = concrete_batch(model.cfg, 1, prefix_len, seed=0)["tokens"]

    def prompts(seed0):
        return [jnp.concatenate(
            [prefix, concrete_batch(model.cfg, 1, tail,
                                    seed=seed0 + i)["tokens"]], 1)
            for i in range(n_req)]

    out = {}
    for mode, use_prefix in (("off", False), ("on", True)):
        sched = Scheduler(model, params, num_slots=1, cache_len=cache_len,
                          paged=True, block_size=BLOCK,
                          prefix_cache=use_prefix)
        # warm-up: compile prefill/splice/decode (+ resume on a hit),
        # then zero the counters so only the timed pass is reported
        for uid, toks in enumerate(prompts(100)):
            sched.submit(Request(uid=-1 - uid, inputs={"tokens": toks},
                                 max_new_tokens=steps))
        sched.run()
        sched.reset_stats()
        # timed: admission wall only (submit + the admitting step), the
        # drain decode excluded — this isolates the prefill compute the
        # resident prefix blocks saved
        wall = 0.0
        for uid, toks in enumerate(prompts(200)):
            sched.submit(Request(uid=uid, inputs={"tokens": toks},
                                 max_new_tokens=steps))
            t0 = time.perf_counter()
            sched.step()
            wall += time.perf_counter() - t0
            sched.run()
        st = sched.stats()
        out[mode] = {"wall_s": wall, "hit_rate": st["prefix_hit_rate"],
                     "prefill_tokens_skipped":
                         st["prefill_tokens_skipped"]}
    out["speedup"] = out["off"]["wall_s"] / out["on"]["wall_s"]
    return out


def _cold_start(arch: str = "deepseek-7b", prompt_len: int = 8,
                steps: int = 4) -> dict:
    """Process start → first token, cold vs warm, via two real serve.py
    subprocesses sharing one persistent compile cache.  Identical flags
    both runs (config differences change XLA cache keys); the warm run
    carries --assert-cache-hits so zero-recompile is enforced inside the
    measured process itself."""
    repo = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ,
               PYTHONPATH=str(repo / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))

    def launch(cache_dir: str, warm: bool) -> dict:
        cmd = [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
               "--variant", "smoke", "--first-token",
               "--compile-cache", cache_dir,
               "--prompt-len", str(prompt_len), "--steps", str(steps),
               "--batch", "1", "--slots", "1"]
        if warm:
            cmd.append("--assert-cache-hits")
        out = subprocess.run(cmd, env=env, cwd=repo, capture_output=True,
                             text=True, check=True).stdout
        for line in out.splitlines():
            if line.startswith("COLD_START "):
                return json.loads(line[len("COLD_START "):])
        raise RuntimeError(f"no COLD_START line in serve output:\n{out}")

    with tempfile.TemporaryDirectory() as cache_dir:
        cold = launch(cache_dir, warm=False)
        warm = launch(cache_dir, warm=True)
    if warm["start_to_first_token_s"] >= cold["start_to_first_token_s"]:
        raise AssertionError(
            f"warm start→first-token ({warm['start_to_first_token_s']}s) "
            f"not faster than cold ({cold['start_to_first_token_s']}s) — "
            f"the persistent compile cache bought nothing")
    rec = {"arch": arch, "prompt_len": prompt_len, "steps": steps,
           "cold_start_to_first_token_s": cold["start_to_first_token_s"],
           "warm_start_to_first_token_s": warm["start_to_first_token_s"],
           "warm_speedup": round(cold["start_to_first_token_s"]
                                 / warm["start_to_first_token_s"], 2),
           "compile_cache_entries": cold["cache_entries"],
           "warm_new_compilations": (warm["cache_entries"]
                                     - cold["cache_entries"])}
    print(f"\ncold start ({arch}): start→first-token "
          f"{rec['cold_start_to_first_token_s']:.2f}s cold → "
          f"{rec['warm_start_to_first_token_s']:.2f}s warm "
          f"({rec['warm_speedup']:.2f}x, {rec['compile_cache_entries']} "
          f"cache entries, {rec['warm_new_compilations']} warm recompiles)")
    return rec


_MESH_WORKER = r'''
import json, os, re, sys, time
n = int(sys.argv[1]); k = int(sys.argv[2]); S = int(sys.argv[3])
steps = int(sys.argv[4]); windows = int(sys.argv[5])
full = bool(int(sys.argv[6]))          # census + identity on this round
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
import jax
import numpy as np
from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.configs.shapes import concrete_batch
from repro.kernels import plan as ttplan
from repro.launch.mesh import make_serve_mesh
from repro.serving.scheduler import Request, Scheduler
import dataclasses

BLOCK = 16
base = get_config("deepseek_7b", "smoke")
cfg = dataclasses.replace(base, tt=TTConfig(
    enabled=True, families=("ffn", "attn"), rank=4, min_factor=2))
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
mesh = make_serve_mesh(n)
out = {"devices": n}


def best_window(B):
    """Best-of-``windows`` steady-state step time at full occupancy; the
    decode budget outlives every timed window so no slot retires inside
    one (a draining pool would inflate tok/s with empty-slot steps)."""
    budget = 4 + windows * steps + 2
    sched = Scheduler(model, params, num_slots=B,
                      cache_len=S + budget + 2, paged=True,
                      block_size=BLOCK, mesh=mesh)
    for b in range(B):
        toks = concrete_batch(cfg, 1, S, seed=b)["tokens"]
        sched.submit(Request(uid=b, inputs={"tokens": toks},
                             max_new_tokens=budget))
    for _ in range(4):
        sched.step()                      # admissions + jit warm-up
    plans0 = ttplan.plan_resolutions()
    best = None
    for _ in range(windows):
        t0 = time.perf_counter()
        for _ in range(steps):
            sched.step()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    assert sched.num_active == B, "slots retired inside a timed window"
    replans = ttplan.plan_resolutions() - plans0
    assert replans == 0, f"{replans} TT plan re-resolutions on the mesh"
    return best / steps, sched


t_step, sched = best_window(k * n)
out["t_step_s"] = t_step
out["replans"] = 0
if n == 1:
    # two-point fit on the single device: T(B) = C_host + B*c gives the
    # host constant and per-token compute the parent needs to derive the
    # per-step collective time of the multi-device rows
    t2, _ = best_window(2 * k)
    out["t_step_2k_s"] = t2

if full:
    COLL = re.compile(r"%(all-reduce|all-gather|reduce-scatter|"
                      r"collective-permute|all-to-all)")
    B = k * n
    toks0 = np.zeros((B, 1), np.int32)
    act = np.ones((B,), bool)
    txt = model.jitted_decode_step_masked(mesh).lower(
        sched.params, sched.cache, jax.numpy.asarray(toks0),
        jax.numpy.asarray(act)).compile().as_text()
    counts = {}
    for m in COLL.finditer(txt):
        counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    out["collective_ops"] = counts
    out["executables"] = 1                # one partitioned program per step

    # token identity on the mesh: a fixed 4-request workload decoded
    # greedily through the paged and the dense pool must match token for
    # token — and (checked by the parent) match every other device count
    ident = {}
    for paged in (True, False):
        sch = Scheduler(model, params, num_slots=4, cache_len=S + 16,
                        paged=paged, block_size=BLOCK, mesh=mesh)
        for b in range(4):
            toks = concrete_batch(cfg, 1, S, seed=100 + b)["tokens"]
            sch.submit(Request(uid=b, inputs={"tokens": toks},
                               max_new_tokens=12))
        done = sch.run()
        for f in sch.finished:
            done[f.uid] = f
        ident["paged" if paged else "dense"] = [
            [int(t) for t in done[b].tokens] for b in range(4)]
    assert ident["paged"] == ident["dense"], \
        "paged/dense token identity broken on the mesh"
    out["identity_tokens"] = ident["paged"]
print("MESH_SCALING " + json.dumps(out))
'''


def _mesh_scaling(quick: bool) -> dict:
    """Device-count scaling sweep (DESIGN.md §14): the TT smoke model
    served from the paged scheduler over 1/2/4 forced host devices at a
    fixed 4 slots per device (weak scaling — a bigger mesh serves a
    bigger batch at the same per-device KV footprint).

    Each (device count, round) is its own subprocess because
    ``--xla_force_host_platform_device_count`` must be set before jax
    initializes; rounds are interleaved across device counts so ambient
    drift hits every count equally, and the median over rounds is kept.

    This container exposes ONE physical core, so the n partitions of each
    decode step — which a real mesh executes concurrently — run serially
    here, and measured wall time grows with device count by construction.
    The sweep therefore reports both series: ``tok_s_measured`` (raw,
    serialized host) and the headline ``tok_s``, which keeps the measured
    host constant serial and divides the measured device time by n —
    the same first-order deserialization the launch.dryrun methodology
    applies to model pod-scale meshes on this host.  Per-step collective
    time is derived from the single-device two-point fit:
    D(n) = T_n - C_host - B*c."""
    steps, windows = (24, 2) if quick else (48, 4)
    k, S = 4, 16
    rounds = 1 if quick else 3
    counts = (1, 2, 4)
    repo = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ,
               PYTHONPATH=str(repo / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))

    meas: dict[int, list[dict]] = {n: [] for n in counts}
    for r in range(rounds):
        for n in counts:
            cmd = [sys.executable, "-c", _MESH_WORKER, str(n), str(k),
                   str(S), str(steps), str(windows),
                   "1" if r == 0 else "0"]
            out = subprocess.run(cmd, env=env, cwd=repo,
                                 capture_output=True, text=True)
            if out.returncode != 0:
                raise RuntimeError(
                    f"mesh worker n={n} failed:\n{out.stdout[-2000:]}"
                    f"\n{out.stderr[-4000:]}")
            for line in out.stdout.splitlines():
                if line.startswith("MESH_SCALING "):
                    meas[n].append(json.loads(line[len("MESH_SCALING "):]))
                    break
            else:
                raise RuntimeError(f"no MESH_SCALING line (n={n})")

    med = {n: sorted(m["t_step_s"] for m in meas[n])[len(meas[n]) // 2]
           for n in counts}
    # host constant + per-token compute from the n=1 two-point fit
    t2k = sorted(m["t_step_2k_s"] for m in meas[1])[len(meas[1]) // 2]
    c_tok = max((t2k - med[1]) / k, 0.0)
    c_host = max(med[1] - k * c_tok, 0.0)

    rows = []
    for n in counts:
        first = meas[n][0]
        t = med[n]
        coll_s = max(t - c_host - k * n * c_tok, 0.0) if n > 1 else 0.0
        t_model = c_host + (t - c_host) / n
        rows.append({
            "devices": n, "slots": k * n, "tokens_per_step": k * n,
            "t_step_ms_measured": round(t * 1e3, 4),
            "tok_s_measured": round(k * n / t, 1),
            "per_step_collective_ms": round(coll_s * 1e3, 4),
            "collective_ops": first.get("collective_ops", {}),
            "replans": first["replans"],
            "tok_s": round(k * n / t_model, 1)})

    # identity: paged == dense inside each worker (asserted there), and
    # the same workload decodes identically at every device count
    ident = [meas[n][0]["identity_tokens"] for n in counts]
    if not all(i == ident[0] for i in ident):
        raise AssertionError("decode tokens differ across device counts")
    tok_s = [r["tok_s"] for r in rows]
    if not all(a < b for a, b in zip(tok_s, tok_s[1:])):
        raise AssertionError(
            f"mesh scaling not monotonic: tok/s {tok_s} over {counts} "
            f"devices")

    print("\nmesh scaling (deepseek_7b tt, paged pool, "
          f"{k} slots/device, {rounds} round(s)):")
    for r in rows:
        print(row(f"{r['devices']} dev", f"B={r['slots']}",
                  f"{r['tok_s_measured']:.0f} tok/s measured",
                  f"{r['tok_s']:.0f} tok/s deserialized",
                  f"coll {r['per_step_collective_ms']:.2f} ms/step"))
    return {
        "arch": "deepseek_7b", "mode": "tt", "pool": "paged",
        "slots_per_device": k, "prompt_len": S, "steps": steps,
        "rounds": rounds, "host_physical_cores": os.cpu_count() or 1,
        "host_ms_per_step": round(c_host * 1e3, 4),
        "compute_ms_per_token": round(c_tok * 1e3, 5),
        "method": (
            "weak scaling, one subprocess per (devices, round), median "
            "over interleaved rounds; tok_s keeps the measured host "
            "constant serial and divides measured device time by the "
            "device count (this host executes all partitions on one "
            "physical core); tok_s_measured is the raw serialized wall "
            "clock; per_step_collective_ms = T_n - host - B*compute"),
        "rows": rows, "tok_s": tok_s, "monotonic": True,
        "identity": {"paged_equals_dense_on_mesh": True,
                     "tokens_identical_across_device_counts": True}}


def _pct(xs: list[float], p: float) -> float:
    xs = sorted(xs)
    if len(xs) == 1:
        return xs[0]
    k = (len(xs) - 1) * p / 100.0
    lo = int(k)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (k - lo)


def _ttft_adversary(quick: bool) -> dict:
    """Long-prompt adversary (DESIGN.md §15): one multi-thousand-token
    prompt lands in a pool of short decoders, with more short requests
    queued behind it.  Monolithic admission prefills the whole adversary
    inside one scheduler step, so every short request behind it inherits
    that full prefill in its time-to-first-token; chunked admission slices
    the adversary into ``chunk_size`` pieces metered by ``prefill_budget``
    and the shorts' first tokens come out after their own (single) chunk.
    Reports p50/p95 TTFT of the trailing shorts, both modes, post-compile
    (an identical throwaway pass warms every jit entry first)."""
    long_len = 1024 if quick else 4096
    chunk, budget = 64, 128            # 2 lanes: adversary + one short
    n_short, S_short, steps = 4, 16, 24
    cfg = get_config("deepseek_7b", "smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = long_len + steps + 2
    slots = 2 + 1 + n_short            # decoders + adversary + shorts

    def workload(seed0):
        mk = lambda n, s: concrete_batch(cfg, 1, n, seed=s)["tokens"]
        return (
            [Request(uid=seed0 + i, inputs={"tokens": mk(S_short, seed0 + i)},
                     max_new_tokens=steps) for i in range(2)],
            Request(uid=seed0 + 50, inputs={"tokens": mk(long_len, seed0)},
                    max_new_tokens=steps),
            [Request(uid=seed0 + 100 + i,
                     inputs={"tokens": mk(S_short, seed0 + 100 + i)},
                     max_new_tokens=steps) for i in range(n_short)])

    def run_mode(chunked, seed0):
        kw = (dict(chunk_prefill=True, chunk_size=chunk,
                   prefill_budget=budget) if chunked else {})
        sched = Scheduler(model, params, num_slots=slots,
                          cache_len=cache_len, paged=True,
                          block_size=BLOCK, **kw)
        decoders, adversary, shorts = workload(seed0)
        for r in decoders:
            sched.submit(r)
        sched.step()                   # decoders admitted and decoding
        sched.step()
        sched.submit(adversary)        # FIFO: the adversary ranks first,
        for r in shorts:               # the shorts queue behind it
            sched.submit(r)
        finished = sched.run()
        ttfts = [finished[r.uid].first_token_time
                 - finished[r.uid].submit_time for r in shorts]
        return ttfts, sched.stats()

    out = {}
    for mode, chunked in (("monolithic", False), ("chunked", True)):
        run_mode(chunked, seed0=1000)            # warm every jit entry
        ttfts, st = run_mode(chunked, seed0=2000)
        out[mode] = {"ttft_p50_s": _pct(ttfts, 50),
                     "ttft_p95_s": _pct(ttfts, 95),
                     "ttft_max_s": max(ttfts)}
        if chunked:
            out[mode]["prefill_chunks"] = st["prefill_chunks"]
    red = out["monolithic"]["ttft_p95_s"] / out["chunked"]["ttft_p95_s"]
    if red <= 1.0:
        raise AssertionError(
            f"chunked prefill did not improve p95 TTFT under the "
            f"long-prompt adversary: {out}")
    out.update({
        "arch": "deepseek_7b", "long_prompt": long_len,
        "n_short": n_short, "short_prompt": S_short, "steps": steps,
        "chunk_size": chunk, "prefill_budget": budget, "block": BLOCK,
        "p95_ttft_reduction": round(red, 2)})
    print(f"\nlong-prompt adversary ({long_len}-token prompt, {n_short} "
          f"trailing shorts): p95 TTFT "
          f"{out['monolithic']['ttft_p95_s']*1e3:.1f}ms monolithic → "
          f"{out['chunked']['ttft_p95_s']*1e3:.1f}ms chunked "
          f"({red:.2f}x)")
    return out


def run(quick: bool = False) -> None:
    S, steps = 16, (8 if quick else 16)
    slot_counts = [2] if quick else [1, 2, 4, 8]
    archs = ["deepseek_7b"] if quick else ["deepseek_7b", "qwen3_32b",
                                           "gemma3_4b"]
    header("model-level serve: dense vs TT × fixed vs dense/paged pools",
           ["arch", "mode", "slots", "fixed_tok_s", "sched_tok_s",
            "paged_tok_s", "paged_over_sched", "pool_MB_dense",
            "pool_MB_paged"])
    records = []
    for arch in archs:
        base = get_config(arch, "smoke")
        variants = {
            "dense": dataclasses.replace(
                base, tt=dataclasses.replace(base.tt, enabled=False)),
            "tt": dataclasses.replace(
                base, tt=TTConfig(enabled=True, families=("ffn", "attn"),
                                  rank=4, min_factor=2)),
        }
        for mode, cfg in variants.items():
            model = build(cfg)
            params = model.init(jax.random.PRNGKey(0))
            n_params = model.num_params()
            for B in slot_counts:
                tps_f = _fixed_throughput(model, params, B, S, steps)
                tps_s, st_s = _sched_throughput(model, params, B, S, steps,
                                                paged=False)
                tps_p, st_p = _sched_throughput(model, params, B, S, steps,
                                                paged=True)
                mb_s = st_s["kv_pool_bytes"] / 1e6
                mb_p = st_p["kv_pool_bytes"] / 1e6
                print(row(arch, mode, B, f"{tps_f:.1f}", f"{tps_s:.1f}",
                          f"{tps_p:.1f}", f"{tps_p/tps_s:.2f}",
                          f"{mb_s:.2f}", f"{mb_p:.2f}"))
                records.append({
                    "arch": arch, "mode": mode, "slots": B,
                    "params": n_params, "prompt_len": S, "steps": steps,
                    "fixed_tok_s": tps_f, "sched_tok_s": tps_s,
                    "paged_tok_s": tps_p,
                    "dense_pool_bytes": st_s["kv_pool_bytes"],
                    "paged_pool_bytes": st_p["kv_pool_bytes"],
                    "paged_block_high_water": st_p["block_high_water"],
                    "paged_block_size": st_p["block_size"]})

    # shared-prefix workload: measured prefill-time reduction from reuse
    # (the prefix is long relative to the smoke model so the saved matmuls
    # dominate the per-admission dispatch overhead)
    px_arch = "deepseek_7b"
    px_len = 128 if quick else 384
    model = build(get_config(px_arch, "smoke"))
    params = model.init(jax.random.PRNGKey(0))
    px = _prefix_workload(model, params, n_req=2 if quick else 6,
                          prefix_len=px_len, tail=16, steps=2)
    print(f"\nshared-prefix workload ({px_arch}, {px_len}-token prefix): "
          f"admission {px['off']['wall_s']*1e3:.0f}ms → "
          f"{px['on']['wall_s']*1e3:.0f}ms "
          f"({px['speedup']:.2f}x), hit rate {px['on']['hit_rate']:.2f}, "
          f"{px['on']['prefill_tokens_skipped']} prefill tokens skipped")
    # cold vs warm process start→first token (persistent compile cache)
    cold_start = _cold_start()
    # chunked-vs-monolithic TTFT under a long-prompt adversary (§15)
    ttft_adversary = _ttft_adversary(quick)
    # device-count scaling over forced host meshes (DESIGN.md §14)
    mesh_scaling = _mesh_scaling(quick)

    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / "BENCH_serve.json"
    out.write_text(json.dumps(
        {"backend": jax.default_backend(), "records": records,
         "prefix_workload": {"arch": px_arch, "prefix_len": px_len,
                             "block": BLOCK, **px},
         "cold_start": cold_start,
         "ttft_adversary": ttft_adversary,
         "mesh_scaling": mesh_scaling}, indent=1))
    print(f"wrote {out}")


if __name__ == "__main__":
    run()
