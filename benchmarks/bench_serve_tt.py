"""Model-level serving comparison: dense vs TT-compressed decode throughput.

The paper's Fig 15 compares layer-level execution; this bench closes the
loop at the model level on this host: same smoke architecture served
dense vs TT(R=8, ffn+attn), measuring decode tokens/s (post-compile) and
the weight-memory ratio.  On TPU the decode win tracks the weight-byte
reduction (EXPERIMENTS §Perf it. 3: −25 % step time at qwen3-32b scale,
KV-cache bound); on CPU with a tiny model it mostly validates the path.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.configs.shapes import concrete_batch

from .common import header, row


def _throughput(cfg, B=4, S=32, steps=16):
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = model.num_params()
    batch = dict(concrete_batch(cfg, B, S), cache_len=S + steps)
    logits, cache = model.prefill(params, batch)
    step = jax.jit(model.decode_step, donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits, cache = step(params, cache, tok)          # compile
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(steps):
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        logits, cache = step(params, cache, tok)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    return B * steps / dt, n_params


def run(quick: bool = False) -> None:
    header("model-level serve: dense vs TT (smoke archs, greedy decode)",
           ["arch", "dense_tok_s", "dense_params", "tt_tok_s", "tt_params",
            "param_ratio", "tok_s_ratio"])
    for arch in (["deepseek_7b"] if quick
                 else ["deepseek_7b", "qwen3_32b", "gemma3_4b"]):
        base = get_config(arch, "smoke")
        dense = dataclasses.replace(
            base, tt=dataclasses.replace(base.tt, enabled=False))
        tt = dataclasses.replace(
            base, tt=TTConfig(enabled=True, families=("ffn", "attn"),
                              rank=4, min_factor=2))
        tps_d, np_d = _throughput(dense)
        tps_t, np_t = _throughput(tt)
        print(row(arch, f"{tps_d:.1f}", np_d, f"{tps_t:.1f}", np_t,
                  f"{np_d/np_t:.2f}", f"{tps_t/tps_d:.2f}"))


if __name__ == "__main__":
    run()
