"""Model-level serving comparison: dense vs TT-compressed decode throughput,
fixed-batch loop vs continuous-batching scheduler, swept over slot counts.

The paper's Fig 15 compares layer-level execution; this bench closes the
loop at the model level on this host.  Two decode loops are measured
post-compile at each slot count B:

  fixed — the lockstep loop (scalar cache position, jitted decode_step)
  sched — the slot-pool scheduler at full occupancy (vector positions +
          active mask through the same jitted step)

The sched/fixed ratio isolates the masking overhead of continuous batching
(it should be ~1: the masked step does the same matmuls plus cheap
per-row index compares), while dense-vs-TT at growing B shows where the
batching win compounds with the weight-memory reduction.  Results land in
``results/BENCH_serve.json``.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.configs.shapes import concrete_batch
from repro.serving.scheduler import Request, Scheduler

from .common import header, row

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def _fixed_throughput(model, params, B, S, steps):
    """Steady-state decode tok/s of the lockstep loop (post-compile)."""
    batch = dict(concrete_batch(model.cfg, B, S), cache_len=S + steps + 2)
    logits, cache = model.jitted_prefill(S + steps + 2)(
        params, {"tokens": batch["tokens"]})
    step = model.jitted_decode_step()
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits, cache = step(params, cache, tok)          # compile
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(steps):
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        logits, cache = step(params, cache, tok)
    jax.block_until_ready(logits)
    return B * steps / (time.perf_counter() - t0)


def _sched_throughput(model, params, B, S, steps):
    """Steady-state decode tok/s of the slot-pool scheduler at full
    occupancy: B requests admitted, then ``steps`` masked decode steps with
    no admissions/retirements in the timed window."""
    budget = steps + 4                     # stays active through the window
    sched = Scheduler(model, params, num_slots=B,
                      cache_len=S + budget + 2)
    for b in range(B):
        toks = concrete_batch(model.cfg, 1, S, seed=b)["tokens"]
        sched.submit(Request(uid=b, inputs={"tokens": toks},
                             max_new_tokens=budget))
    sched.step()                           # admissions + first masked step
    sched.step()                           # warm steady step
    t0 = time.perf_counter()
    for _ in range(steps):
        sched.step()
    return B * steps / (time.perf_counter() - t0)


def run(quick: bool = False) -> None:
    S, steps = 16, (8 if quick else 16)
    slot_counts = [2] if quick else [1, 2, 4, 8]
    archs = ["deepseek_7b"] if quick else ["deepseek_7b", "qwen3_32b",
                                           "gemma3_4b"]
    header("model-level serve: dense vs TT × fixed vs continuous-batching",
           ["arch", "mode", "slots", "params", "fixed_tok_s", "sched_tok_s",
            "sched_over_fixed"])
    records = []
    for arch in archs:
        base = get_config(arch, "smoke")
        variants = {
            "dense": dataclasses.replace(
                base, tt=dataclasses.replace(base.tt, enabled=False)),
            "tt": dataclasses.replace(
                base, tt=TTConfig(enabled=True, families=("ffn", "attn"),
                                  rank=4, min_factor=2)),
        }
        for mode, cfg in variants.items():
            model = build(cfg)
            params = model.init(jax.random.PRNGKey(0))
            n_params = model.num_params()
            for B in slot_counts:
                tps_f = _fixed_throughput(model, params, B, S, steps)
                tps_s = _sched_throughput(model, params, B, S, steps)
                print(row(arch, mode, B, n_params, f"{tps_f:.1f}",
                          f"{tps_s:.1f}", f"{tps_s/tps_f:.2f}"))
                records.append({"arch": arch, "mode": mode, "slots": B,
                                "params": n_params,
                                "fixed_tok_s": tps_f, "sched_tok_s": tps_s,
                                "prompt_len": S, "steps": steps})
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / "BENCH_serve.json"
    out.write_text(json.dumps(
        {"backend": jax.default_backend(), "records": records}, indent=1))
    print(f"wrote {out}")


if __name__ == "__main__":
    run()
