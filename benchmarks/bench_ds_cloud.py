"""Paper Fig 2a: the params-vs-FLOPs design-space cloud for a small layer.

Enumerates the aligned solution cloud for the paper's 120×84 example and
reports its envelope: how many solutions beat the dense layer on both
axes, the Pareto front size, and the spread — the figure's point is that
the cloud is huge and mostly dominated, which motivates pruning.
"""
from __future__ import annotations

from repro.core.dse import DSEConfig, aligned_combination_shapes
from repro.core.flops import (clip_ranks, dense_flops, dense_params,
                              tt_flops, tt_params)

from .common import header, row

M, N = 120, 84          # paper Fig 2a layer (LeNet5 FC)


def run(quick: bool = False) -> None:
    pts = []
    for ms, ns in aligned_combination_shapes(M, N, max_d=6):
        d = len(ms)
        for R in range(1, 33 if not quick else 17):
            ranks = clip_ranks(ms, ns, [1] + [R] * (d - 1) + [1])
            pts.append((tt_params(ms, ns, ranks), tt_flops(ms, ns, ranks)))
    dp, df = dense_params(M, N), dense_flops(M, N)
    better = [(p, f) for p, f in pts if p < dp and f < df]
    # Pareto front of the 'better' set
    front = []
    for p, f in sorted(set(better)):
        if not front or f < front[-1][1]:
            front.append((p, f))
    header(f"Fig 2a: DS cloud for FC [{N}->{M}] (dense: {dp} params, "
           f"{df} FLOPs)",
           ["total_solutions", "beat_dense_both", "pareto_front",
            "min_params", "min_flops"])
    print(row(len(pts), len(better), len(front),
              min(p for p, _ in pts), min(f for _, f in pts)))
    print("pareto (params, flops):",
          " ".join(f"({p},{f})" for p, f in front[:12]))


if __name__ == "__main__":
    run()
