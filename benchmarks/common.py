"""Shared timing + reporting helpers for the benchmark harness."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call of a jitted fn (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def row(*cols) -> str:
    return ",".join(str(c) for c in cols)


def header(title: str, cols: list[str]) -> None:
    print(f"\n## {title}")
    print(",".join(cols))
