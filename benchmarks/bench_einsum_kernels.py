"""Paper Figs 12–14 / Table 3: the three einsum kernel classes (first,
middle, final) at the paper's CB0–CB7 sizes.

Hardware adaptation (DESIGN.md §2): the paper compares its hand-scheduled
RISC-V kernels against Pluto (no vectorization) and IREE (transpose-to-
matmul in HBM).  On this CPU container the analogues we can *time* are:

  naive   — jnp.einsum on the T3F layout, cores transposed at RUNTIME
            (the IREE-style data movement: every call pays the relayout)
  packed  — our compile-time packed layout: the contraction is a single
            matmul on pre-packed cores, zero runtime transposes
            (the paper's array-packing insight, MXU-mapped)

GFLOP/s here are CPU numbers — the *ratio* between schedules is the
reproduced claim.

The second half benchmarks the WHOLE einsum chain (paper Fig. 10 explores
lengths 2–12; §6.4 deploys d=2) across tt_forward backends:

  xla          — einsum chain lowered by XLA (baseline)
  pallas_step  — one blocked Pallas kernel per step, intermediates
                 round-trip through HBM
  fused        — single pallas_call for the whole chain (fused2 for d=2,
                 tt_fused_chain_pallas for d≥3), intermediates in VMEM

each with analytical ('off') and measured ('measure') block plans, and
emits ``results/BENCH_kernels.json`` so the perf trajectory is tracked
across PRs.  Launch counts are recorded to prove the fused path issues
exactly ONE pallas_call per forward (zero per-step HBM intermediates).
Pallas timings on CPU run the interpreter — relative ranking, not absolute
GFLOP/s, is the signal.
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.flops import prod
from repro.core.tt import make_plan, tt_init
from repro.kernels import autotune, tt_contract
from repro.kernels.ops import tt_forward
from repro.kernels.plan import plan_tt_forward

from .common import header, row, time_fn

# Table 3 sizes: (mt, bt, nt, rt) — first: rt_1=1; middle: rt=rt_1=R;
# final: rt=1, column is rt_1.  R=8 throughout (the paper's choice).
FIRST = [(512, 32, 128, 8), (64, 64, 64, 8), (128, 1024, 4, 8),
         (256, 64, 784, 8), (32, 64, 392, 8), (512, 896, 28, 8),
         (100, 12, 64, 8), (16, 4, 150, 8)]
MIDDLE = [(48, 224, 2, 8), (64, 3582, 4, 8), (96, 128, 14, 8),
          (64, 64, 32, 8), (256, 128, 4, 8), (32, 9, 7, 8),
          (4, 16383, 28, 8), (64, 1020, 28, 8)]
FINAL = [(32, 126, 256, 8), (64, 64, 128, 8), (32, 126, 4, 8),
         (256, 16, 7, 8), (8, 510, 896, 8), (32, 250, 4, 8),
         (124, 9, 16, 8), (48, 21, 4, 8)]


@functools.partial(jax.jit, static_argnames=())
def _naive(G, X):
    """Runtime-transposed einsum (the un-packed schedule)."""
    return jnp.einsum("rnmk,bnk->mbr", G, X)


@jax.jit
def _packed(P, X2):
    """state2 @ P on the packed layout — no runtime transpose."""
    return X2 @ P


def _bench_class(name, sizes, kind):
    header(f"Fig {12 + ['first', 'middle', 'final'].index(kind)}: "
           f"{name} einsum kernel (R=8)",
           ["id", "mt", "bt", "nt", "rt", "rt_1", "mflops",
            "naive_gflops", "packed_gflops", "speedup"])
    key = jax.random.PRNGKey(0)
    for i, (mt, bt, nt, r) in enumerate(sizes):
        rt = 1 if kind == "final" else r
        rt_1 = 1 if kind == "first" else r
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        G = jax.random.normal(k1, (rt_1, nt, mt, rt), jnp.float32)
        X = jax.random.normal(k2, (bt, nt, rt), jnp.float32)
        P = G.transpose(1, 3, 2, 0).reshape(nt * rt, mt * rt_1)
        X2 = X.reshape(bt, nt * rt)
        flops = 2 * mt * bt * nt * rt * rt_1
        t_naive = time_fn(_naive, G, X)
        t_packed = time_fn(_packed, P, X2)
        print(row(f"CB{i}", mt, bt, nt, rt, rt_1, f"{flops/1e6:.2f}",
                  f"{flops/t_naive/1e9:.2f}", f"{flops/t_packed/1e9:.2f}",
                  f"{t_naive/t_packed:.2f}"))


# ---------------------------------------------------------------------------
# Whole-chain comparison: xla vs pallas_step vs fused, d = 2/3/4
# ---------------------------------------------------------------------------

# deployed-style layer shapes (aligned m desc / n asc, rank 8 — the paper's
# §6.4 operating point), one per chain length the fused kernel covers
CHAINS = [
    ("d2", (32, 16), (16, 32), 8),
    ("d3", (8, 8, 8), (8, 8, 8), 8),
    ("d4", (8, 4, 4, 4), (4, 4, 4, 8), 8),
]

_FUSED_FOR_D = {2: "pallas_fused2", 3: "pallas_fused", 4: "pallas_fused"}


def _count_launches(cores, x, eplan):
    """pallas_call launches of ONE un-jitted forward (python wrappers run
    every call, so cached traces still count)."""
    tt_contract.reset_launch_counts()
    tt_forward(cores, x, plan=eplan, interpret=True)
    return sum(tt_contract.launch_counts().values())


def _bench_chains(quick: bool) -> list[dict]:
    B = 32 if quick else 128
    header("Fig 10 / §6.4: whole TT chain, xla vs pallas_step vs fused "
           f"(B={B})",
           ["chain", "backend", "tune", "ms", "gflops", "pallas_calls",
            "vs_step"])
    out: list[dict] = []
    for name, ms_, ns_, R in CHAINS:
        plan = make_plan(ms_, ns_, R)
        cores = tt_init(jax.random.PRNGKey(0), plan)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, plan.N),
                              jnp.float32)
        flops = B * plan.flops
        fused = _FUSED_FOR_D[plan.d]
        t_by: dict[tuple[str, str], float] = {}
        for backend, tune in [("xla", "off"),
                              ("pallas_step", "off"),
                              ("pallas_step", "measure"),
                              (fused, "off"),
                              (fused, "measure")]:
            # plan-compile-execute: resolution (incl. measure-mode tile
            # timing) happens ONCE here, outside the timed region — the
            # timed callable is the pure executor (DESIGN.md §10)
            eplan = plan_tt_forward(plan.ns, plan.ms, plan.ranks, batch=B,
                                    backend=backend, tune=tune,
                                    interpret=True)
            fn = jax.jit(functools.partial(
                tt_forward, plan=eplan, interpret=True))
            t = time_fn(fn, cores, x)
            launches = (0 if backend == "xla" else
                        _count_launches(cores, x, eplan))
            t_by[(backend, tune)] = t
            rec = {"chain": name, "d": plan.d, "ms": list(plan.ms),
                   "ns": list(plan.ns), "rank": R, "batch": B,
                   "backend": backend, "tune": tune,
                   "plan_source": eplan.source,
                   "time_s": t, "gflops": flops / t / 1e9,
                   "pallas_calls": launches}
            out.append(rec)
            t_step = t_by.get(("pallas_step", "off"))
            ratio = f"{t_step / t:.2f}" if t_step else "-"
            print(row(name, backend, tune, f"{t*1e3:.3f}",
                      f"{rec['gflops']:.2f}", launches, ratio))
    return out


def run(quick: bool = False,
        out_path: str = "results/BENCH_kernels.json") -> None:
    n = 3 if quick else 8
    _bench_class("first", FIRST[:n], "first")
    _bench_class("middle", MIDDLE[:n], "middle")
    _bench_class("final", FINAL[:n], "final")

    os.environ.setdefault("REPRO_AUTOTUNE_CACHE",
                          "results/autotune_cache.json")
    chains = _bench_chains(quick)

    payload = {
        "meta": {"jax_backend": jax.default_backend(),
                 "interpret_mode": jax.default_backend() != "tpu",
                 "quick": quick,
                 "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")},
        "chains": chains,
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote {out_path} ({len(chains)} chain records)")

    # regression tripwires (interpret mode ⇒ relative, not absolute)
    for d in (3, 4):
        fused = [c for c in chains
                 if c["d"] == d and c["backend"] == "pallas_fused"]
        assert all(c["pallas_calls"] == 1 for c in fused), \
            f"fused d={d} chain must be a single pallas_call"


if __name__ == "__main__":
    run()
