"""Paper Figs 12–14 / Table 3: the three einsum kernel classes (first,
middle, final) at the paper's CB0–CB7 sizes.

Hardware adaptation (DESIGN.md §2): the paper compares its hand-scheduled
RISC-V kernels against Pluto (no vectorization) and IREE (transpose-to-
matmul in HBM).  On this CPU container the analogues we can *time* are:

  naive   — jnp.einsum on the T3F layout, cores transposed at RUNTIME
            (the IREE-style data movement: every call pays the relayout)
  packed  — our compile-time packed layout: the contraction is a single
            matmul on pre-packed cores, zero runtime transposes
            (the paper's array-packing insight, MXU-mapped)

The Pallas kernel itself is validated in tests (interpret mode is a Python
interpreter — timing it is meaningless); its TPU performance is modeled in
the roofline analysis (EXPERIMENTS.md §Perf).  GFLOP/s here are CPU numbers
— the *ratio* between the two schedules is the reproduced claim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.flops import prod

from .common import header, row, time_fn

# Table 3 sizes: (mt, bt, nt, rt) — first: rt_1=1; middle: rt=rt_1=R;
# final: rt=1, column is rt_1.  R=8 throughout (the paper's choice).
FIRST = [(512, 32, 128, 8), (64, 64, 64, 8), (128, 1024, 4, 8),
         (256, 64, 784, 8), (32, 64, 392, 8), (512, 896, 28, 8),
         (100, 12, 64, 8), (16, 4, 150, 8)]
MIDDLE = [(48, 224, 2, 8), (64, 3582, 4, 8), (96, 128, 14, 8),
          (64, 64, 32, 8), (256, 128, 4, 8), (32, 9, 7, 8),
          (4, 16383, 28, 8), (64, 1020, 28, 8)]
FINAL = [(32, 126, 256, 8), (64, 64, 128, 8), (32, 126, 4, 8),
         (256, 16, 7, 8), (8, 510, 896, 8), (32, 250, 4, 8),
         (124, 9, 16, 8), (48, 21, 4, 8)]


@functools.partial(jax.jit, static_argnames=())
def _naive(G, X):
    """Runtime-transposed einsum (the un-packed schedule)."""
    return jnp.einsum("rnmk,bnk->mbr", G, X)


@jax.jit
def _packed(P, X2):
    """state2 @ P on the packed layout — no runtime transpose."""
    return X2 @ P


def _bench_class(name, sizes, kind):
    header(f"Fig {12 + ['first', 'middle', 'final'].index(kind)}: "
           f"{name} einsum kernel (R=8)",
           ["id", "mt", "bt", "nt", "rt", "rt_1", "mflops",
            "naive_gflops", "packed_gflops", "speedup"])
    key = jax.random.PRNGKey(0)
    for i, (mt, bt, nt, r) in enumerate(sizes):
        rt = 1 if kind == "final" else r
        rt_1 = 1 if kind == "first" else r
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        G = jax.random.normal(k1, (rt_1, nt, mt, rt), jnp.float32)
        X = jax.random.normal(k2, (bt, nt, rt), jnp.float32)
        P = G.transpose(1, 3, 2, 0).reshape(nt * rt, mt * rt_1)
        X2 = X.reshape(bt, nt * rt)
        flops = 2 * mt * bt * nt * rt * rt_1
        t_naive = time_fn(_naive, G, X)
        t_packed = time_fn(_packed, P, X2)
        print(row(f"CB{i}", mt, bt, nt, rt, rt_1, f"{flops/1e6:.2f}",
                  f"{flops/t_naive/1e9:.2f}", f"{flops/t_packed/1e9:.2f}",
                  f"{t_naive/t_packed:.2f}"))


def run(quick: bool = False) -> None:
    n = 3 if quick else 8
    _bench_class("first", FIRST[:n], "first")
    _bench_class("middle", MIDDLE[:n], "middle")
    _bench_class("final", FINAL[:n], "final")


if __name__ == "__main__":
    run()
