"""Int8-resident TT kernels: weight dtype × backend × chain depth sweep.

The point this benchmark proves (DESIGN.md §8): keeping the packed cores
int8 *in VMEM* shrinks the residency term of the fused-chain fit test 4×,
so chains whose fp32 (or bf16) weights bust the VMEM budget — and thus
fall back to the per-step kernel with HBM round-trips between steps —
come back as a SINGLE fused ``pallas_call`` under int8.  The showcase
``d3_int8only`` chain is constructed exactly on that boundary: its
16.8M-element middle core is 67 MB in fp32 (> the 32 MiB VMEM budget on
its own) but 16.8 MB in int8.

Sweep: weights ∈ {fp32, bf16, int8} × backend ∈ {xla, pallas_step, auto}
× chains d ∈ {2, 3, 4} + the showcase chain, recording per configuration:

  time_s          — median wall seconds (interpret-mode Pallas on CPU
                    containers: relative ranking is the signal)
  gflops          — chain FLOPs / time
  pallas_calls    — launches of ONE forward (fused ⇒ 1; step ⇒ d; xla ⇒ 0)
  bytes_resident  — resident packed-core bytes at this weight dtype
                    (int8 = core.quant.quantized_bytes: 1 B/elem + one
                    fp32 scale per core)
  max_rel_err     — max |y − y_fp32| / max |y_fp32| vs the fp32 XLA chain

into ``results/BENCH_quant.json``.  Regression tripwires assert the
acceptance contract: on the showcase chain int8 routes fused (1 launch)
while fp32 step-falls-back (d launches), int8 beats the fp32 step path,
and int8 error stays ≤ 5e-2.

Int8 cores are pre-quantized outside the timed region (mirroring the
serving engine's checkpoint-transform storage); tiles are the analytical
dtype-aware picks (``tune='off'``) so results are machine-deterministic.
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.dse import weight_bytes
from repro.core.quant import quantize_cores
from repro.core.tt import make_plan, tt_init
from repro.kernels import tt_contract
from repro.kernels.ops import tt_forward
from repro.kernels.plan import plan_tt_forward

from .common import header, row, time_fn

# (name, ms, ns, rank) — d ∈ {2, 3, 4} at the paper's §6.4-style shapes,
# plus the showcase chain that is fused-eligible ONLY under int8 residency
CHAINS = [
    ("d2", (32, 16), (16, 32), 8),
    ("d3", (8, 8, 8), (8, 8, 8), 8),
    ("d4", (8, 4, 4, 4), (4, 4, 4, 8), 8),
    ("d3_int8only", (32, 32, 4), (4, 32, 32), 128),
]

WEIGHTS = ["fp32", "bf16", "int8"]
BACKENDS = ["xla", "pallas_step", "auto"]

_CAST = {"fp32": jnp.float32, "bf16": jnp.bfloat16}


def _count_launches(call) -> int:
    """pallas_call launches of ONE un-jitted forward (python wrappers run
    every call, so cached traces still count)."""
    tt_contract.reset_launch_counts()
    call()
    return sum(tt_contract.launch_counts().values())


def _bench_one(plan, cores, x, wname: str, backend: str):
    """Returns (timed jitted callable, un-jitted callable for launch
    counting — the python kernel wrappers only run outside cached jit
    traces — and bytes_resident).  Dispatch is plan-first (DESIGN.md §10):
    the execution plan is resolved once per configuration, outside the
    timed region, and both callables execute it."""
    B = x.shape[0]
    if wname == "int8":
        qcores, qscales = quantize_cores(cores)
        eplan = plan_tt_forward(plan.ns, plan.ms, plan.ranks, batch=B,
                                backend=backend, tune="off",
                                weights="int8", interpret=True)
        fwd = jax.jit(functools.partial(tt_forward, plan=eplan,
                                        interpret=True))
        call = functools.partial(fwd, qcores, x, scales=qscales)
        raw = functools.partial(tt_forward, qcores, x, plan=eplan,
                                interpret=True, scales=qscales)
    else:
        wcores = [c.astype(_CAST[wname]) for c in cores]
        eplan = plan_tt_forward(
            plan.ns, plan.ms, plan.ranks, batch=B, backend=backend,
            tune="off", dtype=x.dtype,
            weight_itemsize=jnp.dtype(wcores[0].dtype).itemsize,
            interpret=True)
        fwd = jax.jit(functools.partial(tt_forward, plan=eplan,
                                        interpret=True))
        call = functools.partial(fwd, wcores, x)
        raw = functools.partial(tt_forward, wcores, x, plan=eplan,
                                interpret=True)
    return call, raw, weight_bytes(plan.params, plan.d, wname)


def run(quick: bool = False,
        out_path: str = "results/BENCH_quant.json") -> None:
    B = 8 if quick else 16
    header(f"int8-resident TT kernels: weights x backend x depth (B={B})",
           ["chain", "weights", "backend", "ms", "gflops", "pallas_calls",
            "kbytes_res", "max_rel_err", "vs_fp32_step"])
    out: list[dict] = []
    for name, ms_, ns_, R in CHAINS:
        plan = make_plan(ms_, ns_, R)
        cores = tt_init(jax.random.PRNGKey(0), plan)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, plan.N),
                              jnp.float32)
        flops = B * plan.flops
        ref = jax.jit(functools.partial(tt_forward, backend="xla"))(
            cores, x)
        ref_peak = float(jnp.max(jnp.abs(ref))) + 1e-30
        t_by: dict[tuple[str, str], float] = {}
        for wname in WEIGHTS:
            for backend in BACKENDS:
                call, raw, bytes_res = _bench_one(plan, cores, x, wname,
                                                  backend)
                t = time_fn(call)
                launches = (0 if backend == "xla"
                            else _count_launches(raw))
                err = float(jnp.max(jnp.abs(call() - ref))) / ref_peak
                t_by[(wname, backend)] = t
                rec = {"chain": name, "d": plan.d, "ms": list(plan.ms),
                       "ns": list(plan.ns), "rank": R, "batch": B,
                       "weights": wname, "backend": backend,
                       "time_s": t, "gflops": flops / t / 1e9,
                       "pallas_calls": launches,
                       "bytes_resident": bytes_res,
                       "max_rel_err_vs_fp32": err}
                out.append(rec)
                t_step = t_by.get(("fp32", "pallas_step"))
                ratio = f"{t_step / t:.2f}" if t_step else "-"
                print(row(name, wname, backend, f"{t*1e3:.3f}",
                          f"{flops/t/1e9:.2f}", launches,
                          f"{bytes_res/1024:.1f}", f"{err:.2e}", ratio))
    payload = {
        "meta": {"jax_backend": jax.default_backend(),
                 "interpret_mode": jax.default_backend() != "tpu",
                 "quick": quick,
                 "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")},
        "sweep": out,
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote {out_path} ({len(out)} records)")

    # regression tripwires — the acceptance contract of the int8 path
    def one(chain, wname, backend):
        (rec,) = [r for r in out if r["chain"] == chain
                  and r["weights"] == wname and r["backend"] == backend]
        return rec

    show = "d3_int8only"
    d = one(show, "fp32", "auto")["d"]
    assert one(show, "fp32", "auto")["pallas_calls"] == d, \
        "showcase chain must be step-fallback (d launches) in fp32"
    assert one(show, "bf16", "auto")["pallas_calls"] == d, \
        "showcase chain must be step-fallback in bf16 too"
    int8_auto = one(show, "int8", "auto")
    assert int8_auto["pallas_calls"] == 1, \
        "showcase chain must fuse to ONE pallas_call under int8 residency"
    # the speedup check is the one wall-clock-dependent tripwire: hard in
    # full runs, advisory in --smoke (CI shares loaded runners, and the
    # routing contract above is already asserted deterministically)
    t_fp_step = one(show, "fp32", "pallas_step")["time_s"]
    if int8_auto["time_s"] >= t_fp_step:
        msg = (f"fused int8 chain ({int8_auto['time_s']:.3f}s) did not "
               f"beat the fp32 step path ({t_fp_step:.3f}s)")
        if quick:
            print(f"WARNING: {msg} (advisory in --smoke)")
        else:
            raise AssertionError(msg)
    for rec in out:
        if rec["weights"] == "int8":
            assert rec["max_rel_err_vs_fp32"] <= 5e-2, \
                (rec["chain"], rec["backend"], rec["max_rel_err_vs_fp32"])
    fp32_bytes = one(show, "fp32", "auto")["bytes_resident"]
    assert int8_auto["bytes_resident"] < fp32_bytes / 3.5, \
        "int8 residency must be ~4x below fp32"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced batch for CI")
    ap.add_argument("--out", default="results/BENCH_quant.json")
    args = ap.parse_args()
    run(quick=args.smoke, out_path=args.out)
