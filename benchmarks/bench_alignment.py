"""Paper Figs 5–8 + Fig 7 boxplots: how good is the aligned permutation?

For sampled (layer, combination-shape, rank) configurations we compute
ratio_FLOPs and ratio_Memory (Eqs. 16–17) of the aligned shape against all
permutations.  The paper's claims:
  * ratio_FLOPs ≡ 1.0 (aligned is always FLOPs-optimal)
  * ratio_Memory concentrated near 1, ≈30 % exactly 1.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.dse import aligned_combination_shapes, aligned_pair
from repro.core.flops import tt_flops, tt_params

from .common import header, row

LAYERS = [(300, 784), (120, 400), (512, 512), (1000, 2048),
          (1024, 1024), (2048, 2048), (4096, 9216)]
RANKS = [2, 4, 8, 16, 32, 64]
MAX_D = 4          # permutation enumeration is (d!)²; d ≤ 4 keeps it exact


def ratios_for(ms, ns, rank):
    d = len(ms)
    ranks = [1] + [rank] * (d - 1) + [1]
    f, p = [], []
    for pm in set(itertools.permutations(ms)):
        for pn in set(itertools.permutations(ns)):
            f.append(tt_flops(pm, pn, ranks, bias=False))
            p.append(tt_params(pm, pn, ranks, bias=False))
    af = tt_flops(ms, ns, ranks, bias=False)
    ap = tt_params(ms, ns, ranks, bias=False)
    rf = 1.0 if max(f) == min(f) else (max(f) - af) / (max(f) - min(f))
    rp = 1.0 if max(p) == min(p) else (max(p) - ap) / (max(p) - min(p))
    return rf, rp


def run(quick: bool = False) -> None:
    layers = LAYERS[:4] if quick else LAYERS
    rf_all, rp_all = [], []
    for M, N in layers:
        for ms, ns in aligned_combination_shapes(M, N, max_d=MAX_D):
            for rank in (RANKS[:3] if quick else RANKS):
                rf, rp = ratios_for(ms, ns, rank)
                rf_all.append(rf)
                rp_all.append(rp)
    rf_arr, rp_arr = np.array(rf_all), np.array(rp_all)
    header("Fig 7: alignment quality ratios (1.0 = optimal)",
           ["metric", "n", "min", "p25", "median", "p75", "max",
            "frac_exactly_1"])
    for name, arr in (("ratio_FLOPs", rf_arr), ("ratio_Memory", rp_arr)):
        print(row(name, len(arr), f"{arr.min():.4f}",
                  f"{np.percentile(arr, 25):.4f}",
                  f"{np.median(arr):.4f}",
                  f"{np.percentile(arr, 75):.4f}", f"{arr.max():.4f}",
                  f"{np.mean(arr >= 1.0 - 1e-12):.3f}"))
    assert rf_arr.min() >= 1.0 - 1e-12, "paper claim violated: aligned " \
        "shape not FLOPs-optimal"


if __name__ == "__main__":
    run()
