"""Chunked prefill correctness (DESIGN.md §15): the mixed decode+prefill
step must be *token-identical* to monolithic admission across every
self-mixer family — full GQA, windowed-ring, MLA+MoE, SSM and the hybrid —
for paged and dense pools, every interesting chunk size (1, block-1,
block, whole-prompt), greedy and seeded sampling, with zero TT plan
re-resolutions.  On top of identity: prefix-block reuse still fires under
chunked admission, a victim preempted mid-prefill requeues and resumes
bit-identically, and a snapshot taken mid-prefill round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build, get_config
from repro.configs.shapes import concrete_batch
from repro.kernels.plan import plan_resolutions
from repro.serving.scheduler import Request, Scheduler, make_requests

# One arch per attention family (test_serving.PARITY_ARCHS minus mixtral,
# whose mixer is the same dense-GQA+MoE shape deepseek_v2 already covers).
CHUNK_ARCHS = ["qwen3_32b", "gemma3_4b", "deepseek_v2_lite_16b",
               "mamba2_2p7b", "jamba_v0_1_52b"]
BLOCK = 4
PROMPT = 13          # deliberately not a block multiple

_cache: dict[str, tuple] = {}


def _built(arch):
    """Model + params + the monolithic greedy reference, built once per
    arch — every chunked variant below compares against the same run."""
    if arch not in _cache:
        cfg = get_config(arch, "smoke")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _cache[arch] = (cfg, model, params)
    return _cache[arch]


def _reqs(cfg, temperature=0.0):
    batch = concrete_batch(cfg, 3, PROMPT)
    return make_requests(batch, max_new_tokens=6, key=jax.random.PRNGKey(7),
                         temperature=temperature,
                         top_k=5 if temperature else 0)


def _run(model, params, cfg, *, chunked, chunk=BLOCK, paged=True,
         temperature=0.0):
    kw = dict(eos_id=None, paged=paged, block_size=BLOCK, preempt=False)
    if chunked:
        kw.update(chunk_prefill=True, chunk_size=chunk)
    sched = Scheduler(model, params, num_slots=3, cache_len=32, **kw)
    for r in _reqs(cfg, temperature):
        sched.submit(r)
    return sched.run(), sched


@pytest.mark.parametrize("arch", CHUNK_ARCHS)
@pytest.mark.parametrize("paged", [True, False])
def test_chunked_identity_all_families(arch, paged):
    """Chunked == monolithic token-for-token on every family, both pools,
    with no TT plan re-resolutions during the chunked run."""
    cfg, model, params = _built(arch)
    ref, _ = _run(model, params, cfg, chunked=False, paged=paged)
    r0 = plan_resolutions()
    got, sched = _run(model, params, cfg, chunked=True, paged=paged)
    assert plan_resolutions() == r0, "chunked prefill re-resolved a TT plan"
    assert sched.prefill_chunks > 0
    for uid in ref:
        np.testing.assert_array_equal(
            np.asarray(ref[uid].tokens), np.asarray(got[uid].tokens),
            err_msg=f"{arch} paged={paged} uid={uid}")
        assert got[uid].first_token_time is not None


# {1, block-1, block, prompt_len}: the chunk-boundary sweep of the issue —
# degenerate single-token chunks, one-off-the-block straddles, block-aligned
# chunks, and a whole-prompt chunk (chunked machinery, monolithic shape).
@pytest.mark.parametrize("chunk", [1, BLOCK - 1, BLOCK, PROMPT])
def test_chunk_size_boundary_sweep(chunk):
    cfg, model, params = _built("qwen3_32b")
    ref, _ = _run(model, params, cfg, chunked=False)
    got, _ = _run(model, params, cfg, chunked=True, chunk=chunk)
    for uid in ref:
        np.testing.assert_array_equal(
            np.asarray(ref[uid].tokens), np.asarray(got[uid].tokens),
            err_msg=f"chunk={chunk} uid={uid}")


def test_chunked_identity_seeded_sampling():
    """Chunk-completion must consume exactly the PRNG splits monolithic
    admission does, so seeded sampling stays bit-identical too."""
    cfg, model, params = _built("qwen3_32b")
    ref, _ = _run(model, params, cfg, chunked=False, temperature=0.8)
    got, _ = _run(model, params, cfg, chunked=True, temperature=0.8)
    for uid in ref:
        np.testing.assert_array_equal(np.asarray(ref[uid].tokens),
                                      np.asarray(got[uid].tokens))


def test_chunked_ssm_odd_chunk():
    """SSM state threading with a chunk size that divides nothing."""
    cfg, model, params = _built("mamba2_2p7b")
    ref, _ = _run(model, params, cfg, chunked=False)
    got, _ = _run(model, params, cfg, chunked=True, chunk=5)
    for uid in ref:
        np.testing.assert_array_equal(np.asarray(ref[uid].tokens),
                                      np.asarray(got[uid].tokens))


def test_prefill_budget_caps_lanes():
    """prefill_budget bounds concurrent chunk lanes: budget == chunk_size
    means one lane, so three admissions prefill strictly in rank order."""
    cfg, model, params = _built("qwen3_32b")
    ref, _ = _run(model, params, cfg, chunked=False)
    sched = Scheduler(model, params, num_slots=3, cache_len=32,
                      eos_id=None, paged=True, block_size=BLOCK,
                      preempt=False, chunk_prefill=True, chunk_size=BLOCK,
                      prefill_budget=BLOCK)
    assert sched.chunk_lanes == 1
    for r in _reqs(cfg):
        sched.submit(r)
    got = sched.run()
    for uid in ref:
        np.testing.assert_array_equal(np.asarray(ref[uid].tokens),
                                      np.asarray(got[uid].tokens))


def test_chunked_prefix_reuse():
    """Hash-based prefix reuse still fires when admission is chunked: the
    full prompt's blocks are published at prefill *completion* and a later
    identical/shared-prefix prompt skips the covered chunks."""
    cfg, model, params = _built("qwen3_32b")
    toks = np.asarray(concrete_batch(cfg, 1, 12)["tokens"])
    t2 = toks.copy()
    t2[0, -2:] = [5, 9]

    def reqs():
        return [Request(uid=0, inputs={"tokens": jnp.asarray(toks)},
                        max_new_tokens=5),
                Request(uid=1, inputs={"tokens": jnp.asarray(toks)},
                        max_new_tokens=5),
                Request(uid=2, inputs={"tokens": jnp.asarray(t2)},
                        max_new_tokens=5)]

    def run(chunked):
        s = Scheduler(model, params, num_slots=1, cache_len=32, paged=True,
                      block_size=BLOCK, prefix_cache=True,
                      chunk_prefill=chunked, chunk_size=BLOCK)
        for r in reqs():
            s.submit(r)
        return s.run(), s.stats()

    ref, _ = run(False)
    got, st = run(True)
    for uid in ref:
        np.testing.assert_array_equal(np.asarray(ref[uid].tokens),
                                      np.asarray(got[uid].tokens))
    assert st["prefix_hit_tokens"] > 0
    assert st["prefill_tokens_skipped"] > 0


@pytest.mark.parametrize("stagger", [1, 2, 3])
def test_preempt_mid_prefill(stagger):
    """A low-priority victim preempted partway through its prefill must
    requeue with its PRNG untouched and resume bit-identically, whichever
    chunk boundary the high-priority arrival lands on."""
    cfg, model, params = _built("qwen3_32b")
    long_toks = np.asarray(concrete_batch(cfg, 1, 20)["tokens"])
    short_toks = np.asarray(concrete_batch(cfg, 1, 12)["tokens"])

    def run(chunked, stagger):
        s = Scheduler(model, params, num_slots=1, cache_len=32, paged=True,
                      block_size=BLOCK, prefix_cache=True, preempt=True,
                      chunk_prefill=chunked, chunk_size=BLOCK)
        out = {}
        s.submit(Request(uid=10, inputs={"tokens": jnp.asarray(long_toks)},
                         max_new_tokens=4, priority=0))
        for _ in range(stagger):       # long request starts prefilling
            for f in s.step():
                out[f.uid] = f
        s.submit(Request(uid=11, inputs={"tokens": jnp.asarray(short_toks)},
                         max_new_tokens=4, priority=5))
        out.update(s.run())
        return out, s

    ref, _ = run(False, 1)
    got, s = run(True, stagger)
    assert s.preemptions >= 1
    for uid in ref:
        np.testing.assert_array_equal(
            np.asarray(ref[uid].tokens), np.asarray(got[uid].tokens),
            err_msg=f"stagger={stagger} uid={uid}")


def test_snapshot_mid_prefill_roundtrip():
    """snapshot() taken while a slot is mid-prefill restores the chunk
    state machine (prefill_pos, reserved block tables, pending tokens) and
    completes identically — with first_token_time surviving the trip."""
    cfg, model, params = _built("gemma3_4b")
    toks = np.asarray(concrete_batch(cfg, 2, 14)["tokens"])

    def reqs():
        return [Request(uid=i, inputs={"tokens": jnp.asarray(toks[i:i + 1])},
                        max_new_tokens=5, key=jax.random.PRNGKey(3),
                        temperature=0.7, top_k=4) for i in range(2)]

    def base():
        s = Scheduler(model, params, num_slots=2, cache_len=32, paged=True,
                      block_size=BLOCK, chunk_prefill=True, chunk_size=BLOCK)
        for r in reqs():
            s.submit(r)
        return s

    ref = base().run()
    s = base()
    s.step()
    assert any(x is not None and x.prefill_pos is not None
               for x in s.slots), "step() already finished every prefill"
    s2 = Scheduler.from_snapshot(model, params, s.snapshot())
    out = s2.run()
    for uid in ref:
        np.testing.assert_array_equal(np.asarray(ref[uid].tokens),
                                      np.asarray(out[uid].tokens))
        assert out[uid].first_token_time is not None


def test_chunked_rejects_unsupported_model():
    """Cross-attention caches have no chunked admission path: asking for
    chunk_prefill on an enc-dec model must fail at construction."""
    cfg = get_config("seamless_m4t_large_v2", "smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert not model.supports_chunked_prefill
    with pytest.raises(ValueError, match="chunked prefill"):
        Scheduler(model, params, num_slots=1, cache_len=32,
                  chunk_prefill=True, chunk_size=4)
    _, qmodel, qparams = _built("qwen3_32b")
    with pytest.raises(ValueError):
        Scheduler(qmodel, qparams, num_slots=1, cache_len=32,
                  chunk_prefill=True, chunk_size=0)


# ------------------------------------------------------------- satellite 3
# submit()-time validation regression: a request whose lifetime reservation
# cannot fit must raise at submit, never corrupt ring/pos mid-decode.

def test_submit_rejects_negative_budget():
    cfg, model, params = _built("qwen3_32b")
    sched = Scheduler(model, params, num_slots=1, cache_len=32)
    batch = concrete_batch(cfg, 1, 8)
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit(Request(uid=0, inputs={"tokens": batch["tokens"]},
                             max_new_tokens=-1))


@pytest.mark.parametrize("paged", [True, False])
@pytest.mark.parametrize("chunked", [True, False])
def test_submit_rejects_cache_overflow(paged, chunked):
    """prompt + max_new_tokens > cache_len raises in every pool/admission
    mode — dense, paged, monolithic and chunked alike."""
    cfg, model, params = _built("qwen3_32b")
    kw = dict(chunk_prefill=True, chunk_size=4) if chunked else {}
    sched = Scheduler(model, params, num_slots=1, cache_len=16,
                      paged=paged, block_size=BLOCK, **kw)
    batch = concrete_batch(cfg, 1, 12)
    with pytest.raises(ValueError, match="cache_len"):
        sched.submit(Request(uid=0, inputs={"tokens": batch["tokens"]},
                             max_new_tokens=5))
    # the boundary case fits
    sched.submit(Request(uid=1, inputs={"tokens": batch["tokens"]},
                         max_new_tokens=4))
    out = sched.run()
    assert len(out[1].tokens) == 4


def test_submit_rejects_pool_overflow():
    """A paged request needing more blocks than the whole pool can ever
    hold is rejected up front (it would otherwise hang the drain loop)."""
    cfg, model, params = _built("qwen3_32b")
    sched = Scheduler(model, params, num_slots=1, cache_len=64,
                      paged=True, block_size=BLOCK, num_blocks=4)
    batch = concrete_batch(cfg, 1, 24)
    with pytest.raises(ValueError, match="blocks"):
        sched.submit(Request(uid=0, inputs={"tokens": batch["tokens"]},
                             max_new_tokens=8))
