"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, shape + finiteness asserts.

Every arch runs with the paper's technique ENABLED (TT on FFN projections in
the smoke configs) so the TT path is exercised inside every model family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, build, get_config
from repro.configs.shapes import concrete_batch
from repro.models.spec import is_spec
from repro.training.optimizer import OptConfig
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)

B, S = 2, 16


def _finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.fixture(scope="module")
def built():
    """Build + init each smoke model once per module."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, "smoke")
            model = build(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss(arch, built):
    cfg, model, params = built(arch)
    batch = concrete_batch(cfg, B, S)
    loss = model.loss(params, batch, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # a random model over vocab V should sit near ln(V)
    assert 0.1 * np.log(cfg.vocab_size) < float(loss) \
        < 3.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, built):
    cfg, model, params = built(arch)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0), remat=False,
                       compute_dtype=jnp.float32)
    state = {"params": params,
             "opt": {"m": jax.tree.map(jnp.zeros_like, params),
                     "v": jax.tree.map(jnp.zeros_like, params),
                     "step": jnp.zeros((), jnp.int32)}}
    step = make_train_step(model, tcfg)
    batch = concrete_batch(cfg, B, S)
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert _finite(new_state["params"])
    # params actually moved
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state["params"], new_state["params"])
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_and_decode_shapes(arch, built):
    cfg, model, params = built(arch)
    params_h = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    batch = dict(concrete_batch(cfg, B, S))
    batch["cache_len"] = S + 4
    logits, cache = model.prefill(params_h, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    logits2, cache2 = model.decode_step(params_h, cache, tok)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_have_logical_axes(arch, built):
    cfg, model, _ = built(arch)
    specs = model.param_specs()
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    assert leaves
    for s in leaves:
        assert len(s.axes) == len(s.shape)
    assert model.num_params() > 1000


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_uses_tt_somewhere(arch, built):
    """The smoke configs enable the paper's technique — verify TT cores are
    actually present in the parameter tree (DSE found a surviving plan)."""
    cfg, model, params = built(arch)
    if not cfg.tt.enabled:
        pytest.skip("smoke config has TT disabled")
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    keys = {"/".join(str(getattr(p, "key", p)) for p in path)
            for path, _ in flat}
    assert any("/tt/" in k or k.endswith("/tt") or "tt/c0" in k
               for k in keys), f"no TT cores in {arch} params"


def test_full_configs_match_assignment():
    """Spot-check the assigned full configs against the brief's table."""
    spec = {
        "qwen3_32b": dict(num_layers=64, d_model=5120, num_heads=64,
                          num_kv_heads=8, d_ff=25600, vocab_size=151936),
        "gemma3_4b": dict(num_layers=34, d_model=2560, num_heads=8,
                          num_kv_heads=4, d_ff=10240, vocab_size=262144),
        "deepseek_7b": dict(num_layers=30, d_model=4096, num_heads=32,
                            num_kv_heads=32, d_ff=11008, vocab_size=102400),
        "granite_8b": dict(num_layers=36, d_model=4096, num_heads=32,
                           num_kv_heads=8, d_ff=14336, vocab_size=49152),
        "jamba_v0_1_52b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=14336, vocab_size=65536),
        "deepseek_v2_lite_16b": dict(num_layers=27, d_model=2048,
                                     num_heads=16, vocab_size=102400),
        "mixtral_8x7b": dict(num_layers=32, d_model=4096, num_heads=32,
                             num_kv_heads=8, d_ff=14336, vocab_size=32000),
        "internvl2_2b": dict(num_layers=24, d_model=2048, num_heads=16,
                             num_kv_heads=8, d_ff=8192, vocab_size=92553),
        "mamba2_2p7b": dict(num_layers=64, d_model=2560, vocab_size=50280),
        "seamless_m4t_large_v2": dict(num_layers=24, d_model=1024,
                                      num_heads=16, num_kv_heads=16,
                                      d_ff=8192, vocab_size=256206),
    }
    for arch, fields in spec.items():
        cfg = get_config(arch, "full")
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
    # MoE structure
    assert get_config("mixtral_8x7b", "full").moe.num_experts == 8
    assert get_config("mixtral_8x7b", "full").moe.top_k == 2
    assert get_config("jamba_v0_1_52b", "full").moe.num_experts == 16
    assert get_config("deepseek_v2_lite_16b", "full").moe.num_experts == 64
    assert get_config("deepseek_v2_lite_16b", "full").moe.top_k == 6
    assert get_config("deepseek_v2_lite_16b", "full").mla.kv_lora == 512
    assert get_config("mamba2_2p7b", "full").ssm.d_state == 128
    assert get_config("seamless_m4t_large_v2", "full").enc_dec
