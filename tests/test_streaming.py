"""Streaming front-end (DESIGN.md §15): StreamEngine's per-token buffers
and replayable streams, the SSE HTTP server (overlapping clients, ordered
events, reconnect-from-index, graceful shutdown), and journal-aware
reconnect — a token acknowledged before a crash is replayable after it,
because a recovered ``DurableScheduler``'s partial streams seed the new
engine's buffers."""
import http.client
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build, get_config
from repro.configs.shapes import concrete_batch
from repro.serving.durable import DurableScheduler
from repro.serving.engine import StreamEngine
from repro.serving.scheduler import Request, Scheduler
from repro.serving.server import make_server

_cache: dict[str, tuple] = {}


def _built(arch="qwen3_32b"):
    if arch not in _cache:
        cfg = get_config(arch, "smoke")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _cache[arch] = (cfg, model, params)
    return _cache[arch]


def _sched(model, params, **kw):
    base = dict(num_slots=2, cache_len=32, paged=True, block_size=4,
                chunk_prefill=True, chunk_size=4)
    base.update(kw)
    return Scheduler(model, params, **base)


def _events(resp):
    """Parse an SSE byte stream into decoded ``data:`` events."""
    buf = b""
    while True:
        chunk = resp.read1(4096)
        if not chunk:
            return
        buf += chunk
        while b"\n\n" in buf:
            raw, buf = buf.split(b"\n\n", 1)
            for line in raw.split(b"\n"):
                if line.startswith(b"data: "):
                    yield json.loads(line[6:])


def test_stream_engine_token_order_and_results():
    """Tokens arrive through on_token in index order, stream() replays
    them, and the final result matches a plain synchronous scheduler
    run of the same requests."""
    cfg, model, params = _built()
    toks = np.asarray(concrete_batch(cfg, 2, 10)["tokens"])

    def reqs():
        return [Request(uid=i, inputs={"tokens": jnp.asarray(toks[i:i + 1])},
                        max_new_tokens=6) for i in range(2)]

    ref_sched = _sched(model, params)
    for r in reqs():
        ref_sched.submit(r)
    ref = ref_sched.run()

    eng = StreamEngine(_sched(model, params))
    try:
        for r in reqs():
            eng.submit(r)
        for uid in (0, 1):
            f = eng.result(uid, timeout=60)
            np.testing.assert_array_equal(np.asarray(f.tokens),
                                          np.asarray(ref[uid].tokens))
            evs = list(eng.stream(uid))
            assert evs[-1] == {"uid": uid, "done": "length"}
            assert [e["i"] for e in evs[:-1]] == list(range(6))
            assert [e["token"] for e in evs[:-1]] == \
                [int(t) for t in ref[uid].tokens]
            # replay from an offset: the reconnect contract
            tail = list(eng.stream(uid, start=4))
            assert [e["i"] for e in tail[:-1]] == [4, 5]
    finally:
        eng.close()
    with pytest.raises(KeyError):
        list(eng.stream(999))


def test_stream_engine_rejects_invalid_request():
    """A request the scheduler would refuse at submit() is surfaced as a
    rejection through the stream/result APIs, not a hung engine loop."""
    cfg, model, params = _built()
    toks = np.asarray(concrete_batch(cfg, 1, 10)["tokens"])
    eng = StreamEngine(_sched(model, params))
    try:
        eng.submit(Request(uid=0, inputs={"tokens": jnp.asarray(toks)},
                           max_new_tokens=999))       # overflows cache_len
        evs = list(eng.stream(0, timeout=30))
        assert evs[-1]["done"].startswith("rejected:")
        with pytest.raises(RuntimeError, match="rejected"):
            eng.result(0, timeout=30)
    finally:
        eng.close()


def test_sse_server_end_to_end():
    """Two overlapping SSE clients each see their own ordered token
    events ending in done; a reconnect replays from the requested index;
    /stats exposes engine+scheduler counters; POST /shutdown stops the
    HTTP loop; a non-streaming POST returns one JSON result."""
    cfg, model, params = _built()
    toks = np.asarray(concrete_batch(cfg, 2, 10)["tokens"])
    eng = StreamEngine(_sched(model, params))
    srv = make_server(eng)
    port = srv.server_address[1]
    srv_t = threading.Thread(target=srv.serve_forever, daemon=True)
    srv_t.start()
    try:
        def client(rows, out, uid):
            c = http.client.HTTPConnection("127.0.0.1", port)
            c.request("POST", "/generate", json.dumps(
                {"tokens": rows, "max_new_tokens": 6, "uid": uid}),
                {"Content-Type": "application/json"})
            r = c.getresponse()
            assert r.status == 200
            for ev in _events(r):
                out.append(ev)
                if "done" in ev:
                    break
            c.close()

        o1, o2 = [], []
        t1 = threading.Thread(target=client, args=(toks[0].tolist(), o1, 0))
        t2 = threading.Thread(target=client, args=(toks[1].tolist(), o2, 1))
        t1.start()
        time.sleep(0.01)
        t2.start()
        t1.join(60)
        t2.join(60)
        assert not t1.is_alive() and not t2.is_alive()
        for o in (o1, o2):
            assert o[-1].get("done") == "length"
            assert [e["i"] for e in o[:-1]] == list(range(6))

        # reconnect: replay uid 0 from index 3 — same tokens, same order
        c = http.client.HTTPConnection("127.0.0.1", port)
        c.request("GET", "/stream/0?from=3")
        evs = []
        for ev in _events(c.getresponse()):
            evs.append(ev)
            if "done" in ev:
                break
        assert [e["i"] for e in evs[:-1]] == [3, 4, 5]
        assert [e["token"] for e in evs[:-1]] == \
            [e["token"] for e in o1[3:-1]]

        # non-streaming mode: one blocking JSON result
        c = http.client.HTTPConnection("127.0.0.1", port)
        c.request("POST", "/generate", json.dumps(
            {"tokens": toks[0].tolist(), "max_new_tokens": 4,
             "stream": False}), {"Content-Type": "application/json"})
        res = json.loads(c.getresponse().read())
        assert len(res["tokens"]) == 4
        assert res["finish_reason"] == "length"
        assert res["tokens"] == [e["token"] for e in o1[:4]]

        # malformed request → 400, not a dead server thread
        c = http.client.HTTPConnection("127.0.0.1", port)
        c.request("POST", "/generate", json.dumps({"tokens": [[1, 2]]}),
                  {"Content-Type": "application/json"})
        assert c.getresponse().status == 400

        c = http.client.HTTPConnection("127.0.0.1", port)
        c.request("GET", "/stats")
        st = json.loads(c.getresponse().read())
        assert st["prefill_chunks"] > 0
        assert st["requests_done"] >= 2

        c = http.client.HTTPConnection("127.0.0.1", port)
        c.request("POST", "/shutdown", "{}")
        assert json.loads(c.getresponse().read())["ok"]
        srv_t.join(10)
        assert not srv_t.is_alive()
    finally:
        srv.server_close()
        eng.close()


def test_journal_aware_reconnect(tmp_path):
    """Crash mid-generation, recover from the durable root, attach a new
    StreamEngine: the buffers are pre-seeded from journal/snapshot state,
    so a client reconnecting with its uid and last-seen index resumes the
    token stream — pre-crash tokens replay, post-crash tokens follow, and
    the whole sequence equals a crash-free run."""
    cfg, model, params = _built()
    toks = np.asarray(concrete_batch(cfg, 1, 10)["tokens"])

    def req():
        return Request(uid=0, inputs={"tokens": jnp.asarray(toks)},
                       max_new_tokens=8)

    ref_sched = _sched(model, params)
    ref_sched.submit(req())
    ref = [int(t) for t in ref_sched.run()[0].tokens]

    root = str(tmp_path / "durable")
    ds = DurableScheduler(_sched(model, params), root, snapshot_every=1)
    eng = StreamEngine(ds)
    eng.submit(req())
    deadline = time.time() + 60
    while time.time() < deadline:
        with eng._cond:
            n_seen = len(eng._buffers.get(0, ()))
            if n_seen >= 3:
                break
        time.sleep(0.005)
    assert n_seen >= 3, "no tokens generated before simulated crash"
    eng.close(drain=False)               # crash: in-flight work abandoned

    ds2 = DurableScheduler.recover(root, model, params)
    eng2 = StreamEngine(ds2)
    try:
        seeded = len(eng2._buffers.get(0, ()))
        assert seeded > 0, "recovered engine lost the acknowledged tokens"
        evs = list(eng2.stream(0, start=2, timeout=60))
        assert evs[-1] == {"uid": 0, "done": "length"}
        assert [e["i"] for e in evs[:-1]] == list(range(2, 8))
        assert [e["token"] for e in evs[:-1]] == ref[2:]
        # full replay from zero matches the crash-free reference exactly
        full = list(eng2.stream(0, start=0, timeout=60))
        assert [e["token"] for e in full[:-1]] == ref
    finally:
        eng2.close()
