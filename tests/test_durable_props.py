"""Property tests: snapshot split/join and the chunked durable writer
round-trip bit-exactly over awkward leaves — bf16, int8 quantized
bundles, 0-d arrays, empty block tables, deeply nested trees (DESIGN.md
§13 satellite).  Skipped when hypothesis is unavailable (CI installs it).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import durable
from repro.serving.faults import _join_arrays, _split_arrays


def _ml_bf16():
    import ml_dtypes
    return ml_dtypes.bfloat16


DTYPES = st.sampled_from(["float32", "int32", "int8", "bool", "bf16"])


@st.composite
def arrays(draw):
    dt = draw(DTYPES)
    shape = tuple(draw(st.lists(st.integers(0, 5), min_size=0, max_size=3)))
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    a = rng.integers(-100, 100, size=shape)
    if dt == "bf16":
        return a.astype(_ml_bf16())
    if dt == "bool":
        return (a > 0)
    return a.astype(dt)


@st.composite
def trees(draw, depth=3):
    if depth == 0:
        return draw(st.one_of(
            arrays(), st.integers(-5, 5), st.floats(allow_nan=False,
                                                    allow_infinity=False),
            st.text(max_size=8), st.none(), st.booleans()))
    return draw(st.one_of(
        arrays(),
        st.lists(trees(depth=depth - 1), max_size=3),
        st.dictionaries(
            st.text(st.characters(whitelist_categories=("Ll",)),
                    min_size=1, max_size=6),
            trees(depth=depth - 1), max_size=3)))


def _assert_tree_equal(a, b):
    assert type(a) is type(b) or (isinstance(a, (list, tuple))
                                  and isinstance(b, (list, tuple)))
    if isinstance(a, np.ndarray):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes()
    elif isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            _assert_tree_equal(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            _assert_tree_equal(x, y)
    else:
        assert a == b


@settings(max_examples=40, deadline=None)
@given(tree=trees())
def test_split_join_round_trip(tree):
    """_split_arrays → _join_arrays is the identity on any tree of JSON
    scalars and ndarray leaves (tuples canonicalise to lists, as JSON
    serialisation does)."""
    import json
    arrays_out: dict = {}
    skeleton = _split_arrays(tree, arrays_out, "snap")
    # the skeleton must survive a JSON round trip (it is what lands in
    # the manifest)
    skeleton = json.loads(json.dumps(skeleton))
    joined = _join_arrays(skeleton, arrays_out)

    def canon(t):
        if isinstance(t, tuple):
            return [canon(x) for x in t]
        if isinstance(t, list):
            return [canon(x) for x in t]
        if isinstance(t, dict):
            return {k: canon(v) for k, v in t.items()}
        if isinstance(t, (np.integer,)):
            return int(t)
        if isinstance(t, (np.floating,)):
            return float(t)
        return t

    _assert_tree_equal(canon(tree), joined)


@settings(max_examples=30, deadline=None)
@given(named=st.dictionaries(
    st.text(st.characters(whitelist_categories=("Ll",)),
            min_size=1, max_size=8),
    arrays(), max_size=6),
    chunk=st.integers(1, 4096))
def test_chunked_writer_round_trip(tmp_path_factory, named, chunk):
    """write_arrays → read_arrays is bit-exact for any chunk size ≥ 1,
    any dtype (bf16/int8/bool included), any shape (0-d and empty
    included), with every checksum verified on the way back."""
    d = tmp_path_factory.mktemp("chunked")
    index = durable.write_arrays(str(d), named, chunk_bytes=chunk)
    back = durable.read_arrays(str(d / "arrays.bin"), index,
                               chunk_bytes=chunk)
    assert set(back) == set(named)
    for k, a in named.items():
        a = np.asarray(a)
        assert back[k].dtype == a.dtype and back[k].shape == a.shape
        assert back[k].tobytes() == a.tobytes()


@settings(max_examples=20, deadline=None)
@given(named=st.dictionaries(st.sampled_from(["a", "b", "c"]),
                             arrays(), min_size=1, max_size=3),
       data=st.data())
def test_any_single_corruption_is_detected(tmp_path_factory, named, data):
    """Flipping one bit anywhere in a committed arrays.bin is caught by a
    checksum (load never silently returns wrong bytes)."""
    d = tmp_path_factory.mktemp("corrupt")
    index = durable.write_arrays(str(d), named)
    p = str(d / "arrays.bin")
    size = int(sum(m["nbytes"] for m in index.values()))
    if size == 0:
        return                            # nothing to corrupt
    off = data.draw(st.integers(0, size - 1))
    bit = data.draw(st.integers(0, 7))
    with open(p, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ (1 << bit)]))
    with pytest.raises(durable.CorruptGenerationError):
        durable.read_arrays(p, index)
