"""Eq. (4)/(11)/(13) analytic models vs. independent derivations + the
paper's own worked examples (§2 LeNet300, Prop. 4 permutation count)."""
import itertools
import math

import pytest

from repro.core.flops import (clip_ranks, dense_flops, dense_params,
                              einsum_loop_bounds, max_tt_rank_at_cut,
                              num_permutations_aligned, prod, tt_flops,
                              tt_flops_per_einsum, tt_flops_step, tt_params)

# The paper's §2 worked example: LeNet300 FC [N, M] = [784, 300].
LENET_MS = (5, 5, 3, 2, 2)            # M = 300
LENET_NS = (2, 2, 2, 7, 14)           # N = 784
LENET_RANKS = (1, 10, 10, 10, 10, 1)


def test_paper_example_core_shapes():
    """§2: G^0=[1,2,5,10], G^1=[10,2,5,10], G^2=[10,2,3,10],
    G^3=[10,7,2,10], G^4=[10,14,2,1]  (shape [r_{t-1}, n_t, m_t, r_t])."""
    from repro.core.tt import TTPlan
    plan = TTPlan(LENET_MS, LENET_NS, LENET_RANKS)
    assert plan.core_shapes == [
        (1, 2, 5, 10), (10, 2, 5, 10), (10, 2, 3, 10),
        (10, 7, 2, 10), (10, 14, 2, 1)]


def test_eq4_params_matches_core_sizes():
    """Eq. (4) equals the literal sum of core tensor sizes + bias."""
    core_sizes = sum(
        LENET_RANKS[t] * LENET_NS[t] * LENET_MS[t] * LENET_RANKS[t + 1]
        for t in range(5))
    assert tt_params(LENET_MS, LENET_NS, LENET_RANKS) == core_sizes + 300
    assert tt_params(LENET_MS, LENET_NS, LENET_RANKS, bias=False) == core_sizes


def test_eq11_equals_chain_execution_flops():
    """Eq. (11) closed form == FLOPs summed over the *executed* chain
    (Listing 2 loop bounds: 2·mt·bt·nt·rt·rt_1 per einsum).  This is an
    independent re-derivation of Proposition 2."""
    cases = [
        (LENET_MS, LENET_NS, LENET_RANKS),
        ((100, 10), (32, 64), (1, 8, 1)),          # paper §6.4 ResNet pick
        ((256, 2), (2, 256), (1, 16, 1)),
        ((8, 8, 8), (4, 8, 16), (1, 8, 8, 1)),
        ((12,), (18,), (1, 1)),                     # d=1 degenerate
    ]
    for ms, ns, ranks in cases:
        closed = tt_flops(ms, ns, ranks, bias=False)
        executed = sum(b["flops"]
                       for b in einsum_loop_bounds(ms, ns, ranks, batch=1))
        assert closed == executed, (ms, ns, ranks)


def test_eq13_per_step_terms():
    """FLOPs^(t) = 2·r_t·r_{t-1}·(m_t…m_d)·(n_1…n_t)  — term by term."""
    ms, ns, ranks = LENET_MS, LENET_NS, LENET_RANKS
    for t in range(1, 6):
        expect = (2 * ranks[t] * ranks[t - 1]
                  * prod(ms[t - 1:]) * prod(ns[:t]))
        assert tt_flops_step(ms, ns, ranks, t) == expect
    assert sum(tt_flops_per_einsum(ms, ns, ranks)) \
        == tt_flops(ms, ns, ranks, bias=False)


def test_chain_loop_bounds_telescope():
    """The running b_t dimension must telescope: each state has size
    m_t·b_t·r_{t-1} and the final state is exactly M (batch=1)."""
    bounds = einsum_loop_bounds(LENET_MS, LENET_NS, LENET_RANKS, batch=1)
    assert bounds[0]["bt"] == 784 // (14 * 1)       # b5 = N/(n5·r5)
    last = bounds[-1]
    assert last["mt"] * last["bt"] * last["rt_1"] == 300


def test_first_last_einsum_degenerate_ranks():
    """First einsum has rt=1 eliminating the r-loop; last has rt_1=1 (§2)."""
    bounds = einsum_loop_bounds(LENET_MS, LENET_NS, LENET_RANKS)
    assert bounds[0]["rt"] == 1                      # executes core d first
    assert bounds[-1]["rt_1"] == 1


def test_prop4_permutation_count_paper_example():
    """Prop. 4 example: d=5, ms=[5,5,3,2,2], ns=[2,2,2,7,14] → (5!)²/(2!2!3!)
    = 600 permutations collapse onto the aligned representative."""
    assert num_permutations_aligned(LENET_MS, LENET_NS) == 600


def test_prop4_all_distinct():
    assert num_permutations_aligned((8, 4, 2), (3, 5, 7)) \
        == math.factorial(3) ** 2


def test_max_rank_at_cut_and_clip():
    """Footnote 5: r_t bounded by min of unfolding sizes either side."""
    ms, ns = (4, 3), (2, 4)
    assert max_tt_rank_at_cut(ms, ns, 1) == min(4 * 2, 3 * 4)
    assert clip_ranks(ms, ns, [1, 999, 1]) == (1, 8, 1)
    assert clip_ranks(ms, ns, [1, 5, 1]) == (1, 5, 1)


def test_dense_baselines():
    assert dense_params(300, 784) == 300 * 784 + 300
    assert dense_flops(300, 784) == 2 * 300 * 784 + 300


def test_tt_beats_dense_on_paper_example():
    """The §2 example is a real compression: fewer params AND FLOPs."""
    assert tt_params(LENET_MS, LENET_NS, LENET_RANKS) \
        < dense_params(300, 784)
    assert tt_flops(LENET_MS, LENET_NS, LENET_RANKS) < dense_flops(300, 784)


@pytest.mark.parametrize("batch", [1, 4, 32])
def test_flops_scale_linearly_in_batch(batch):
    bounds1 = einsum_loop_bounds(LENET_MS, LENET_NS, LENET_RANKS, batch=1)
    boundsB = einsum_loop_bounds(LENET_MS, LENET_NS, LENET_RANKS, batch=batch)
    for b1, bB in zip(bounds1, boundsB):
        assert bB["flops"] == batch * b1["flops"]
