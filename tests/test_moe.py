"""MoE dispatch correctness: dropless == naive per-token reference; capacity
dropping behaves as GShard (prefix-causal drops)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.layers import mlp_apply
from repro.models.moe import moe_apply, moe_spec
from repro.models.spec import init_tree


def _setup(capacity_factor, num_shared=0, seed=0):
    cfg = get_config("mixtral_8x7b", "smoke")
    cfg = dataclasses.replace(
        cfg,
        tt=dataclasses.replace(cfg.tt, enabled=False),   # dense experts
        moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor,
                                num_shared=num_shared,
                                shared_ff=cfg.moe.expert_ff))
    p = init_tree(jax.random.PRNGKey(seed), moe_spec(cfg))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, cfg.d_model))
    return cfg, p, x


def _naive_moe(p, cfg, x):
    """Per-token dense reference: y_t = Σ_k gate·MLP_{e_k}(x_t)."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, eidx = jax.lax.top_k(probs, m.top_k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)
    outs = []
    for t in range(xt.shape[0]):
        y = jnp.zeros((d,), xt.dtype)
        for k in range(m.top_k):
            e = int(eidx[t, k])
            ep = jax.tree.map(lambda w: w[e], p["experts"])
            y = y + gate[t, k] * mlp_apply(ep, xt[t][None])[0]
        outs.append(y)
    y = jnp.stack(outs)
    if m.num_shared:
        y = y + jax.vmap(lambda v: mlp_apply(p["shared"], v[None])[0])(xt)
    return y.reshape(B, S, d)


def test_dropless_matches_naive_reference():
    cfg, p, x = _setup(capacity_factor=16.0)
    got = moe_apply(p, cfg, x)
    want = _naive_moe(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_shared_experts_added():
    cfg, p, x = _setup(capacity_factor=16.0, num_shared=1)
    got = moe_apply(p, cfg, x)
    want = _naive_moe(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_capacity_drops_are_prefix_causal():
    """GShard property our serving path relies on: shrinking capacity only
    zeroes contributions; it never changes the *kept* tokens' outputs, and
    token t's keep/drop status is independent of tokens after t."""
    cfg, p, x = _setup(capacity_factor=16.0)
    full = moe_apply(p, cfg, x)

    cfg_small = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    small = moe_apply(p, cfg_small, x)

    # some tokens must differ (drops happened)…
    d = np.abs(np.asarray(full) - np.asarray(small)).max(axis=-1).reshape(-1)
    assert (d > 1e-6).any(), "capacity_factor=0.25 produced no drops"

    # …and extending the sequence never changes earlier tokens' routing
    x_ext = jnp.concatenate(
        [x, jax.random.normal(jax.random.PRNGKey(9), (2, 4, x.shape[-1]))], 1)
    small_ext = moe_apply(p, cfg_small, x_ext)
    # flattening order is (B,S): row 0's S tokens are a prefix
    np.testing.assert_allclose(np.asarray(small_ext[0, :x.shape[1] - 1]),
                               np.asarray(small[0, :-1]),
                               rtol=1e-5, atol=1e-6)


def test_gate_weights_normalized():
    cfg, p, x = _setup(capacity_factor=16.0)
    m = cfg.moe
    xt = x.reshape(-1, x.shape[-1])
    probs = jax.nn.softmax((xt @ p["router"]).astype(jnp.float32), -1)
    gate, _ = jax.lax.top_k(probs, m.top_k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)
    np.testing.assert_allclose(np.asarray(jnp.sum(gate, -1)), 1.0, rtol=1e-5)


def test_sort_dispatch_matches_cumsum_reference():
    """The sort-based dispatch_positions must equal the GShard cumsum
    formulation exactly (same priority order)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models.moe import dispatch_positions
    key = jax.random.PRNGKey(0)
    for E in (4, 8, 64):
        for Tk in (16, 257, 1024):
            e_flat = jax.random.randint(jax.random.fold_in(key, E * Tk),
                                        (Tk,), 0, E)
            onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
            pos_ref = jnp.max(jnp.cumsum(onehot, 0) * onehot, -1) - 1
            got = dispatch_positions(e_flat, E)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(pos_ref))
