"""Block-shape selection + VMEM models (paper §4.3.4/§4.3.5 → TPU),
including the per-operand-itemsize (weights vs activations) fit model of
DESIGN.md §8."""
from repro.core import hw
from repro.core.packing import (BlockPlan, chain_fits_vmem,
                                chain_weight_elems, fused2_batch_tile,
                                fused_chain_batch_tile, select_blocks)


def test_select_blocks_respects_vmem_budget():
    plan = select_blocks(mt=4096, bt=8192, nt=64, rt=16, rt_1=16)
    assert plan.vmem_bytes <= hw.VMEM_BUDGET_BYTES
    assert plan.bm >= 8 and plan.bb >= 8 and plan.bn >= 8


def test_select_blocks_traffic_model_consistency():
    """The chosen plan minimizes the modeled traffic among a few manual
    alternatives (sanity on the objective, paper step 3)."""
    mt, bt, nt, rt, rt_1 = 1024, 2048, 32, 8, 8
    best = select_blocks(mt, bt, nt, rt, rt_1)

    def traffic(bm, bb):
        it = 4
        g = mt * nt * rt * rt_1 * it
        x = bt * nt * rt * it
        o = mt * bt * rt_1 * it
        return g * (-(-bt // bb)) + x * (-(-mt // bm)) + o

    assert best.traffic_bytes <= traffic(8, 8)
    assert best.traffic_bytes <= traffic(128, 128)


def test_select_blocks_tiny_problem():
    plan = select_blocks(mt=4, bt=4, nt=4, rt=1, rt_1=1)
    assert isinstance(plan, BlockPlan)
    assert plan.bm <= 8


def test_bigger_budget_never_increases_traffic():
    """Paper Eq. 26→28 intuition: more fast memory → no more HBM traffic."""
    small = select_blocks(2048, 4096, 64, 8, 8, vmem_budget=1 << 20)
    large = select_blocks(2048, 4096, 64, 8, 8, vmem_budget=64 << 20)
    assert large.traffic_bytes <= small.traffic_bytes


def test_chain_fits_vmem():
    assert chain_fits_vmem([1024, 1024])
    assert not chain_fits_vmem([hw.VMEM_BUDGET_BYTES, hw.VMEM_BUDGET_BYTES])


def test_fused2_batch_tile_monotone():
    t_small = fused2_batch_tile(N=4096, M=4096, mid=8192, weights=1 << 20)
    t_big = fused2_batch_tile(N=256, M=256, mid=512, weights=1 << 10)
    assert 8 <= t_small <= t_big <= 1024
    need = 2 * 4 * (t_small * (4096 + 8192 + 4096)) + 4 * (1 << 20)
    assert need <= hw.VMEM_BUDGET_BYTES or t_small == 8


# ---------------------------------------------------------------------------
# Per-operand itemsize (DESIGN.md §8): int8-resident weights enlarge the
# eligibility set and never shrink a tile
# ---------------------------------------------------------------------------

def test_chain_fits_vmem_weight_itemsize():
    """Weights priced per their own dtype: a weight block that busts the
    budget at 4 B/elem fits at 1 B/elem with identical states."""
    w = hw.VMEM_BUDGET_BYTES // 3           # 4w > budget > 1w + states
    states = [1024, 1024]
    assert not chain_fits_vmem(states, weight_elems=w, weight_itemsize=4)
    assert chain_fits_vmem(states, weight_elems=w, weight_itemsize=1)
    # default (None) keeps the old single-itemsize behavior
    assert chain_fits_vmem(states, weight_elems=w) == \
        chain_fits_vmem(states, weight_elems=w, weight_itemsize=4)


def test_fused_chain_tile_grows_under_int8_residency():
    """The dtype-aware fit test: int8 weights never yield a smaller tile,
    and on a weight-dominated chain they admit a strictly larger one (or
    flip None → fused-eligible)."""
    ns, ms, ranks = (4, 32, 32), (32, 32, 4), (1, 128, 128, 1)
    t_fp = fused_chain_batch_tile(ns, ms, ranks, weight_itemsize=4)
    t_bf = fused_chain_batch_tile(ns, ms, ranks, weight_itemsize=2)
    t_q = fused_chain_batch_tile(ns, ms, ranks, weight_itemsize=1)
    assert t_fp is None and t_bf is None      # 67/34 MB of weights: no fit
    assert t_q == 8                           # 16.8 MB int8: fused
    # a smaller chain: tile is monotone non-decreasing as weights shrink
    ns2, ms2, ranks2 = (8, 8, 8), (8, 8, 8), (1, 8, 8, 1)
    tiles = [fused_chain_batch_tile(ns2, ms2, ranks2, weight_itemsize=w)
             for w in (4, 2, 1)]
    assert all(t is not None for t in tiles)
    assert tiles[0] <= tiles[1] <= tiles[2]


def test_fused2_tile_weight_itemsize():
    N = M = 2048
    mid, w = 4096, 6 << 20
    t4 = fused2_batch_tile(N, M, mid, w, weight_itemsize=4)
    t1 = fused2_batch_tile(N, M, mid, w, weight_itemsize=1)
    assert t1 >= t4
    need = 2 * 4 * (t1 * (N + mid + M)) + 1 * w
    assert need <= hw.VMEM_BUDGET_BYTES or t1 == 8
