"""Fault-tolerant serving (DESIGN.md §11).

The invariant gate: under seeded alloc failures, admission holds,
cancellations, preemptions, a live resize and a simulated restart, every
*surviving* request's tokens are bit-identical to an uninterrupted run —
across all five cache families, greedy and seeded sampling — with zero
leaked blocks and zero TT plan re-resolutions.  Plus unit coverage for
the individual mechanisms: preemption anti-livelock, snapshot/restore
round-trips (in memory and on disk), and deadline bookkeeping on the
virtual clock.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build, get_config
from repro.configs.shapes import concrete_batch
from repro.serving.faults import (FaultPlan, load_snapshot, run_with_faults,
                                  save_snapshot, step_clock)
from repro.serving.scheduler import Request, Scheduler

BLOCK = 4

PAGED_ARCHS = ["qwen3_32b", "gemma3_4b", "deepseek_v2_lite_16b",
               "mamba2_2p7b", "jamba_v0_1_52b"]


def _build(arch):
    cfg = get_config(arch, "smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, S, steps, key, temperature=0.0):
    toks = concrete_batch(cfg, n, S)["tokens"]
    return [Request(uid=u, inputs={"tokens": toks[u:u + 1]},
                    max_new_tokens=steps,
                    key=jax.random.fold_in(key, u),
                    temperature=temperature,
                    priority=(2 if u == n - 1 else 0))
            for u in range(n)]


def _kw(cache_len, **over):
    kw = dict(num_slots=2, cache_len=cache_len, paged=True,
              block_size=BLOCK, num_blocks=10,
              key=jax.random.PRNGKey(7))
    kw.update(over)
    return kw


# ---------------------------------------------------------------------------
# The gate: survivor token identity under a full fault plan, per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_fault_plan_survivor_identity_across_families(arch):
    """Seeded faults — alloc failures, a hold, a cancel, one live resize
    (slots 2→3), one restart — with a staggered high-priority arrival so
    preemption fires too.  Survivors must match the uninterrupted run
    bit-for-bit; the pool must drain leak-free with zero replans."""
    cfg, model, params = _build(arch)
    S, steps = 8, 6
    reqs = _requests(cfg, 5, S, steps, jax.random.PRNGKey(1))
    plan = FaultPlan(alloc_fail_steps=frozenset({2, 5}),
                     hold_steps=frozenset({4}),
                     cancels=((3, 1),),
                     resizes=((2, 3, 14),),
                     restart_steps=frozenset({6}))
    rep = run_with_faults(model, params, reqs, plan,
                          sched_kwargs=_kw(S + steps + 2),
                          arrival_steps=[0, 0, 1, 2, 3])
    assert rep.restarts == 1
    assert rep.cancelled == 1
    assert rep.replans == 0
    assert sorted(rep.survivors) == [0, 2, 3, 4]


def test_fault_plan_survivor_identity_seeded_sampling():
    """Same gate under temperature>0: per-request PRNG streams survive
    preemption (state carried, not re-derived) and restart (state
    snapshotted), so sampled streams stay bit-identical too."""
    cfg, model, params = _build("qwen3_32b")
    S, steps = 8, 6
    reqs = _requests(cfg, 4, S, steps, jax.random.PRNGKey(2),
                     temperature=0.8)
    plan = FaultPlan(alloc_fail_steps=frozenset({1}),
                     cancels=(), resizes=(),
                     restart_steps=frozenset({4}))
    rep = run_with_faults(model, params, reqs, plan,
                          sched_kwargs=_kw(S + steps + 2),
                          arrival_steps=[0, 0, 1, 2])
    assert rep.restarts == 1 and rep.replans == 0
    assert sorted(rep.survivors) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# Preemption
# ---------------------------------------------------------------------------

def test_preemption_resumes_bit_identical():
    """A late high-priority arrival evicts an active low-priority slot;
    the victim requeues, re-admits via the published-prefix resume path
    and still finishes token-identical to an undisturbed run."""
    cfg, model, params = _build("qwen3_32b")
    S, steps = 8, 6
    key = jax.random.PRNGKey(3)
    reqs = _requests(cfg, 3, S, steps, key, temperature=0.7)

    ref = Scheduler(model, params, **_kw(S + steps + 2))
    for r in reqs:
        ref.submit(r)
    refout = ref.run()

    clk = {"t": 0.0}
    sched = Scheduler(model, params, clock=step_clock(clk),
                      **_kw(S + steps + 2))
    sched.submit(reqs[0])
    sched.submit(reqs[1])
    for _ in range(2):                    # both low-prio slots mid-decode
        clk["t"] += 1
        sched.step()
    sched.submit(reqs[2])                 # priority 2: must preempt
    while not sched.idle:
        clk["t"] += 1
        sched.step()
    assert sched.preemptions >= 1
    sched.allocator.assert_quiescent()
    out = {f.uid: f for f in sched.finished}
    for u in range(3):
        np.testing.assert_array_equal(out[u].tokens, refout[u].tokens)
        np.testing.assert_allclose(out[u].logprobs, refout[u].logprobs,
                                   rtol=1e-5, atol=1e-5)


def test_preemption_no_livelock():
    """Preemption is strictly rank-decreasing: equal-priority work never
    preempts, so two requests contending for one slot alternate through
    the queue at most once each and the drain terminates."""
    cfg, model, params = _build("qwen3_32b")
    S, steps = 8, 4
    reqs = _requests(cfg, 4, S, steps, jax.random.PRNGKey(4))
    sched = Scheduler(model, params,
                      **_kw(S + steps + 2, num_slots=1, num_blocks=4))
    # equal priorities: strictly-worse victims never exist
    for r in reqs[:3]:
        sched.submit(dataclasses.replace(r, priority=0))
    out = sched.run()
    assert sched.preemptions == 0
    assert len(out) == 3
    sched.allocator.assert_quiescent()


# ---------------------------------------------------------------------------
# Snapshot / restore
# ---------------------------------------------------------------------------

def test_snapshot_restore_mid_stream():
    """Snapshot with slots mid-decode and a queue backlog; a fresh
    scheduler restored from it finishes every stream bit-identical."""
    cfg, model, params = _build("deepseek_v2_lite_16b")
    S, steps = 8, 6
    reqs = _requests(cfg, 4, S, steps, jax.random.PRNGKey(5),
                     temperature=0.6)
    ref = Scheduler(model, params, **_kw(S + steps + 2))
    for r in reqs:
        ref.submit(r)
    refout = ref.run()

    clk = {"t": 0.0}
    sched = Scheduler(model, params, clock=step_clock(clk),
                      **_kw(S + steps + 2))
    for r in reqs:
        sched.submit(r)
    for _ in range(3):
        clk["t"] += 1
        sched.step()
    snap = sched.snapshot()
    del sched
    s2 = Scheduler.from_snapshot(model, params, snap,
                                 clock=step_clock(clk))
    while not s2.idle:
        clk["t"] += 1
        s2.step()
    s2.allocator.assert_quiescent()
    out = {f.uid: f for f in s2.finished}
    for u in range(4):
        np.testing.assert_array_equal(out[u].tokens, refout[u].tokens)


def test_snapshot_disk_round_trip(tmp_path):
    """save_snapshot/load_snapshot preserve every leaf (arrays split to
    npz, structure to JSON) well enough that a restore from disk equals a
    restore from memory."""
    cfg, model, params = _build("qwen3_32b")
    S, steps = 8, 5
    reqs = _requests(cfg, 3, S, steps, jax.random.PRNGKey(6))
    clk = {"t": 0.0}
    sched = Scheduler(model, params, clock=step_clock(clk),
                      **_kw(S + steps + 2))
    for r in reqs:
        sched.submit(r)
    for _ in range(2):
        clk["t"] += 1
        sched.step()
    snap = sched.snapshot()
    loaded = load_snapshot(save_snapshot(str(tmp_path / "snap"), snap))

    outs = []
    for source in (snap, loaded):
        clk2 = {"t": clk["t"]}
        s2 = Scheduler.from_snapshot(model, params, source,
                                     clock=step_clock(clk2))
        while not s2.idle:
            clk2["t"] += 1
            s2.step()
        s2.allocator.assert_quiescent()
        outs.append({f.uid: f.tokens.tolist() for f in s2.finished})
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Durability: kill -9 recovery from the journal + snapshot store (§13)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_kill_at_step_k_recovers_across_families(arch, tmp_path):
    """The durability gate: a seeded hard kill mid-drain — NO snapshot
    taken at kill time, recovery purely from the durable store (last
    committed generation + journal replay).  Every survivor's stream must
    be bit-identical to the uninterrupted run, zero leaked blocks, zero
    plan re-resolutions (all asserted inside run_with_faults)."""
    cfg, model, params = _build(arch)
    S, steps = 8, 6
    reqs = _requests(cfg, 4, S, steps, jax.random.PRNGKey(8))
    plan = FaultPlan(kill_steps=frozenset({3}))
    rep = run_with_faults(model, params, reqs, plan,
                          sched_kwargs=_kw(S + steps + 2),
                          arrival_steps=[0, 0, 1, 2],
                          durable_dir=str(tmp_path / "store"),
                          snapshot_every=2)
    assert rep.kills == 1 and rep.restarts == 0 and rep.replans == 0
    assert sorted(rep.survivors) == [0, 1, 2, 3]


def test_kill_recovery_seeded_sampling():
    """Same gate under temperature>0: per-slot PRNG state rides in the
    snapshot and journaled submits carry the request keys, so sampled
    streams survive a kill bit-identically too."""
    import tempfile
    cfg, model, params = _build("qwen3_32b")
    S, steps = 8, 6
    reqs = _requests(cfg, 4, S, steps, jax.random.PRNGKey(9),
                     temperature=0.8)
    plan = FaultPlan(kill_steps=frozenset({4}))
    with tempfile.TemporaryDirectory() as d:
        rep = run_with_faults(model, params, reqs, plan,
                              sched_kwargs=_kw(S + steps + 2),
                              arrival_steps=[0, 0, 1, 2],
                              durable_dir=d, snapshot_every=2)
    assert rep.kills == 1 and rep.replans == 0
    assert sorted(rep.survivors) == [0, 1, 2, 3]


def test_kill_late_replays_finished_requests(tmp_path):
    """A kill after some requests already retired: their journaled retire
    records are authoritative on replay — results preserved verbatim, not
    recomputed — while still-running streams finish identically."""
    cfg, model, params = _build("gemma3_4b")
    S, steps = 8, 4
    reqs = _requests(cfg, 5, S, steps, jax.random.PRNGKey(10))
    # with 2 slots and a 4-token budget, the first wave retires around
    # step 5; killing at step 7 exercises retire-replay + live recovery
    plan = FaultPlan(kill_steps=frozenset({7}))
    rep = run_with_faults(model, params, reqs, plan,
                          sched_kwargs=_kw(S + steps + 2),
                          durable_dir=str(tmp_path / "store"),
                          snapshot_every=3)
    assert rep.kills == 1
    assert sorted(rep.survivors) == [0, 1, 2, 3, 4]


def test_kill_with_corrupted_newest_generation(tmp_path):
    """Durability fault injection: the newest committed generation is
    bit-flipped between the kill and its recovery.  The checksummed
    fallback must restore the previous generation and the journal replay
    must carry the state across the gap — survivors still identical."""
    cfg, model, params = _build("qwen3_32b")
    S, steps = 8, 6
    reqs = _requests(cfg, 4, S, steps, jax.random.PRNGKey(12))
    plan = FaultPlan(kill_steps=frozenset({5}))
    corrupted = []

    def corruptor(root, step):
        import os
        from repro.core import durable as dur
        gens = dur.committed_generations(root)
        if len(gens) < 2:
            return
        p = os.path.join(root, f"gen_{gens[-1]:08d}", "arrays.bin")
        with open(p, "r+b") as f:
            f.seek(16)
            b = f.read(1)
            f.seek(16)
            f.write(bytes([b[0] ^ 0x20]))
        corrupted.append(gens[-1])

    rep = run_with_faults(model, params, reqs, plan,
                          sched_kwargs=_kw(S + steps + 2),
                          durable_dir=str(tmp_path / "store"),
                          snapshot_every=2, corruptor=corruptor)
    assert rep.kills == 1 and corrupted   # the fault actually fired
    assert sorted(rep.survivors) == [0, 1, 2, 3]


def test_kill_requires_durable_dir():
    """A kill without a durable store is a contract violation, rejected
    up front (there would be nothing to recover from)."""
    cfg, model, params = _build("qwen3_32b")
    reqs = _requests(cfg, 1, 8, 2, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="durable_dir"):
        run_with_faults(model, params, reqs,
                        FaultPlan(kill_steps=frozenset({1})),
                        sched_kwargs=_kw(12))
