"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device (the 512-device override belongs to repro.launch.dryrun only).
"""
import os
import sys

# Allow `pytest tests/` without PYTHONPATH=src.
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.abspath(_SRC) not in [os.path.abspath(p) for p in sys.path]:
    sys.path.insert(0, os.path.abspath(_SRC))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-minute subprocess tests (forced multi-device meshes, "
        "full decode loops); run in tier-1, deselectable with -m 'not slow'")


@pytest.fixture(autouse=True)
def _fresh_tt_plan_memo():
    """The process-wide TT plan memo (kernels.plan) caches resolutions by
    chain signature; tests that monkeypatch the fit model or redirect the
    autotune cache must not see (or leave behind) memoized plans."""
    from repro.kernels import plan
    plan.clear_plan_memo()
    yield
    plan.clear_plan_memo()
