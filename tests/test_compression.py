"""Gradient compression: quantization error bounds + the error-feedback
unbiasedness property."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.training.compression import (dequantize, ef_compress_tree,
                                        ef_init, quantize)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = quantize(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6     # half-ulp of the grid


def test_quantize_extremes_and_zeros():
    q, s = quantize(jnp.zeros((8,)))
    np.testing.assert_array_equal(np.asarray(q), 0)
    x = jnp.asarray([-3.0, 3.0])
    q, s = quantize(x)
    assert int(q[0]) == -127 and int(q[1]) == 127
    np.testing.assert_allclose(np.asarray(dequantize(q, s)), [-3.0, 3.0],
                               rtol=1e-4)


def test_error_feedback_is_unbiased_over_time():
    """Σ_t restored_t tracks Σ_t g_t: the residual never grows (the
    1-bit-Adam telescoping property)."""
    key = jax.random.PRNGKey(1)
    ef = ef_init({"w": jnp.zeros((64,))})
    total_true = np.zeros(64)
    total_restored = np.zeros(64)
    for t in range(50):
        key, k = jax.random.split(key)
        g = {"w": jax.random.normal(k, (64,))}
        restored, ef = ef_compress_tree(g, ef)
        total_true += np.asarray(g["w"])
        total_restored += np.asarray(restored["w"])
        # residual stays bounded by one quantization step
        assert np.abs(np.asarray(ef["w"])).max() < 0.2
    # cumulative sums agree to the residual (telescoping): Σrestored =
    # Σtrue − final residual
    np.testing.assert_allclose(total_restored + np.asarray(ef["w"]),
                               total_true, rtol=1e-4, atol=1e-4)


def test_ef_tree_structure_preserved():
    params = {"a": jnp.ones((4,)), "nest": {"b": jnp.ones((2, 2))}}
    ef = ef_init(params)
    g, ef2 = ef_compress_tree(params, ef)
    assert jax.tree.structure(g) == jax.tree.structure(params)
    assert jax.tree.structure(ef2) == jax.tree.structure(params)
