"""Pallas kernel sweeps vs. the pure-jnp oracles (interpret mode on CPU).

Per the brief: every kernel is swept over shapes and dtypes and asserted
allclose against ref.py.  Shapes include the paper's three einsum classes
(first: rt_1=1; middle: both ranks > 1; final: rt=1) and non-divisible
extents that exercise the padding path (the paper's 'padding ukernel').
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import BlockPlan, pack_core, select_blocks
from repro.core.tt import make_plan, tt_init
from repro.kernels.ops import tt_forward
from repro.kernels.ref import tt_chain_ref, tt_einsum_step_ref, tt_fused2_ref
from repro.kernels.tt_contract import tt_fused2_pallas, tt_step_pallas

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# (r0, n, m, r1, b) — first einsum r0=1 … wait: execution-order first has
# rt=1 meaning the LAST core (t=d) has r_d=1 → kernel sees r1=1; the final
# einsum (t=1) has r0=1.  Cover all three classes + padding extents.
STEP_SHAPES = [
    (8, 4, 16, 1, 32),      # paper "first einsum":  rt(=r1 here)=1
    (8, 7, 24, 8, 16),      # middle einsum, odd n
    (1, 4, 16, 8, 48),      # final einsum: rt_1(=r0)=1
    (4, 3, 10, 4, 9),       # nothing divides the default blocks
    (8, 16, 128, 8, 64),    # MXU-aligned m
]


@pytest.mark.parametrize("r0,n,m,r1,b", STEP_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_step_kernel_vs_ref(r0, n, m, r1, b, dtype):
    k1, k2 = jax.random.split(KEY)
    G = _rand(k1, (r0, n, m, r1), dtype)
    X = _rand(k2, (b, n, r1), dtype)
    plan = select_blocks(m, b, n, r1, r0, itemsize=G.dtype.itemsize)
    got = tt_step_pallas(G, X, plan, interpret=True)       # fp32 out
    want = jnp.einsum("rnmk,bnk->mbr", G.astype(jnp.float32),
                      X.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_step_kernel_small_blocks_accumulate():
    """Force a multi-tile grid (incl. n-accumulation) and check it still
    matches — this exercises the @pl.when init + revisiting output tiles."""
    r0, n, m, r1, b = 4, 32, 64, 8, 40
    k1, k2 = jax.random.split(KEY)
    G = _rand(k1, (r0, n, m, r1), jnp.float32)
    X = _rand(k2, (b, n, r1), jnp.float32)
    plan = BlockPlan(bm=16, bb=16, bn=8, traffic_bytes=0, vmem_bytes=0)
    got = tt_step_pallas(G, X, plan, interpret=True)
    want = tt_einsum_step_ref(G, X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


FUSED2_DIMS = [
    # (n1, n2, m1, m2, r1, B)
    (4, 8, 10, 5, 8, 16),
    (2, 16, 32, 8, 4, 33),     # B not divisible by block
    (8, 8, 16, 16, 16, 8),
    (16, 64, 100, 10, 8, 12),  # paper §6.4 ResNet-like
]


@pytest.mark.parametrize("n1,n2,m1,m2,r1,B", FUSED2_DIMS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused2_kernel_vs_refs(n1, n2, m1, m2, r1, B, dtype):
    plan = make_plan((m1, m2), (n1, n2), r1)
    if plan.ranks != (1, r1, 1):
        pytest.skip("rank clipped — covered elsewhere")
    cores = [c.astype(dtype) for c in tt_init(KEY, plan)]
    x = _rand(jax.random.PRNGKey(7), (B, n1 * n2), dtype)
    got = tt_fused2_pallas(x, pack_core(cores[1]), pack_core(cores[0]),
                           dims=(n1, n2, m1, m2, r1), block_b=16,
                           interpret=True)
    ref_fused = tt_fused2_ref(cores, x)
    ref_chain = tt_chain_ref(cores, x)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref_fused, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(ref_fused, np.float32),
                               np.asarray(ref_chain, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("backend", ["xla", "pallas_step", "pallas_fused2"])
def test_tt_forward_backends_agree_d2(backend):
    plan = make_plan((16, 8), (4, 16), 8)
    cores = tt_init(KEY, plan)
    x = _rand(jax.random.PRNGKey(3), (6, plan.N), jnp.float32)
    base = tt_forward(cores, x, backend="xla")
    got = tt_forward(cores, x, backend=backend, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-4, atol=1e-4)


def test_tt_forward_chain_d3_pallas_step():
    plan = make_plan((8, 4, 2), (2, 4, 8), 4)
    cores = tt_init(KEY, plan)
    x = _rand(jax.random.PRNGKey(4), (5, plan.N), jnp.float32)
    base = tt_forward(cores, x, backend="xla")
    got = tt_forward(cores, x, backend="pallas_step", interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-4, atol=1e-4)


def test_tt_forward_auto_and_bias_and_lead_dims():
    plan = make_plan((16, 8), (4, 16), 8)
    cores = tt_init(KEY, plan)
    bias = jnp.linspace(-1, 1, plan.M)
    x = _rand(jax.random.PRNGKey(5), (2, 3, plan.N), jnp.float32)
    y = tt_forward(cores, x, bias=bias, backend="auto", interpret=True)
    assert y.shape == (2, 3, plan.M)
    base = tt_forward(cores, x, bias=bias, backend="xla")
    np.testing.assert_allclose(np.asarray(y), np.asarray(base),
                               rtol=1e-4, atol=1e-4)


def test_pack_core_layout():
    """pack_core: [r0,n,m,r1] → [(n·r1), (m·r0)] such that the step
    contraction is literally `state2 @ P` — check against the einsum."""
    G = _rand(KEY, (3, 4, 5, 2), jnp.float32)           # r0,n,m,r1
    X = _rand(jax.random.PRNGKey(9), (7, 4, 2), jnp.float32)   # b,n,r1
    P = pack_core(G)
    assert P.shape == (4 * 2, 5 * 3)
    want = jnp.einsum("rnmk,bnk->mbr", G, X)            # [m,b,r0]
    got = (X.reshape(7, 8) @ P).reshape(7, 5, 3)        # [b,m,r0]
    np.testing.assert_allclose(np.asarray(got.transpose(1, 0, 2)),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


def test_tt_forward_rejects_inconsistent_shapes():
    """A core list inconsistent with x.shape[-1] (or with itself) must be a
    clear ValueError, not silent shape corruption in the chain reshape."""
    plan = make_plan((4, 4), (4, 4), 4)
    cores = tt_init(KEY, plan)
    good = _rand(jax.random.PRNGKey(11), (3, 16), jnp.float32)
    for backend in ("xla", "pallas_step"):
        tt_forward(cores, good, backend=backend, interpret=True)  # sanity
        with pytest.raises(ValueError, match="does not match"):
            tt_forward(cores, _rand(KEY, (3, 18), jnp.float32),
                       backend=backend, interpret=True)
    bad_rank = [cores[0], jnp.ones((5,) + cores[1].shape[1:],
                                   cores[1].dtype)]
    with pytest.raises(ValueError, match="rank mismatch"):
        tt_forward(bad_rank, good, backend="xla", interpret=True)
