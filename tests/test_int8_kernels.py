"""Int8-resident kernels (DESIGN.md §8), interpret mode.

Contract under test: (1) every int8 kernel variant is *exact* against the
dequantize-then-fp32-chain reference (the in-kernel epilogue scale is
algebraically identical to pre-matmul dequantization); (2) the ``auto``
routing under int8 issues ONE ``pallas_call`` for a VMEM-resident chain
(``LAUNCH_COUNTS``); (3) the dtype-aware fit model admits chains under
int8 residency that are step-fallback in fp32 — the compound speedup the
whole PR is about.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import (BlockPlan, chain_state_sizes,
                                chain_weight_elems, fused_chain_batch_tile,
                                pack_core)
from repro.core.quant import (dequantize_cores, pack_core_int8,
                              quantize_core, quantize_cores)
from repro.core.tt import make_plan, tt_apply, tt_init
from repro.kernels import autotune, tt_contract
from repro.kernels.ops import parse_backend_spec, tt_forward
from repro.kernels.tt_contract import (tt_fused2_int8_pallas,
                                       tt_fused_chain_int8_pallas,
                                       tt_step_int8_pallas)

KEY = jax.random.PRNGKey(0)


def _setup(ms, ns, rank, B=8, seed=0):
    plan = make_plan(ms, ns, rank)
    cores = tt_init(jax.random.PRNGKey(seed), plan)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, plan.N))
    return plan, cores, x


def _int8_reference(cores, x):
    """Dequantize-then-fp32-chain: what the int8 kernels must reproduce."""
    qs, ss = quantize_cores(cores)
    return tt_apply(dequantize_cores(qs, ss, jnp.float32), x)


CHAIN_CASES = [
    ((16, 8), (4, 16), 8, 33),           # d=2, B % tile != 0
    ((8, 4, 4), (4, 4, 8), 4, 19),       # d=3, ragged batch
    ((9, 5, 7), (3, 7, 5), 4, 12),       # d=3 all-odd factors
    ((4, 4, 4, 2), (2, 4, 4, 4), 4, 21),  # d=4, ragged batch
]


@pytest.mark.parametrize("ms,ns,rank,B", CHAIN_CASES)
def test_fused_chain_int8_exact_vs_dequant_reference(ms, ns, rank, B):
    plan, cores, x = _setup(ms, ns, rank, B)
    pq = [pack_core_int8(G) for G in reversed(cores)]
    got = tt_fused_chain_int8_pallas(
        x, [p for p, _ in pq], [s for _, s in pq],
        (plan.ns, plan.ms, plan.ranks), block_b=8, interpret=True)
    want = _int8_reference(cores, x)
    assert got.shape == (B, plan.M)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused2_int8_exact_vs_dequant_reference():
    plan, cores, x = _setup((16, 8), (4, 16), 8, 9)
    (q2, s2), (q1, s1) = pack_core_int8(cores[1]), pack_core_int8(cores[0])
    got = tt_fused2_int8_pallas(
        x, q2, q1, [s2, s1],
        (plan.ns[0], plan.ns[1], plan.ms[0], plan.ms[1], plan.ranks[1]),
        block_b=8, interpret=True)
    want = _int8_reference(cores, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_step_int8_exact_vs_dequant_reference():
    plan, cores, _ = _setup((8, 4, 4), (4, 4, 8), 4, 1)
    G = cores[1]
    r0, n, m, r1 = G.shape
    Gq, s = quantize_core(G)
    X = jax.random.normal(jax.random.PRNGKey(3), (19, n, r1))
    got = tt_step_int8_pallas(Gq, s, X, BlockPlan(8, 8, 8, 0, 0),
                              interpret=True)
    want = jnp.einsum("rnmk,bnk->mbr", Gq.astype(jnp.float32) * s, X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pack_core_int8_commutes_with_packing():
    """Packing is a pure relayout, so pack-then-quantize ==
    quantize-then-pack, bit for bit (same scale, same int codes)."""
    _, cores, _ = _setup((8, 4, 4), (4, 4, 8), 4)
    for G in cores:
        pq, ps = pack_core_int8(G)
        q, s = quantize_core(G)
        assert float(ps) == float(s)
        np.testing.assert_array_equal(np.asarray(pq),
                                      np.asarray(pack_core(q)))


# ---------------------------------------------------------------------------
# tt_forward dispatch: every backend, both core-input conventions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas_step", "pallas_fused",
                                     "auto"])
def test_tt_forward_int8_backends_agree(backend):
    plan, cores, x = _setup((8, 4, 4), (4, 4, 8), 4, 13)
    want = _int8_reference(cores, x)
    got = tt_forward(cores, x, backend=backend, interpret=True, tune="off",
                     weights="int8")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_tt_forward_prequantized_matches_on_the_fly():
    """Stored int8 cores + scales (models/layers quantized storage) must
    produce bit-identical output to on-the-fly quantization of the float
    cores — the serving consistency contract."""
    plan, cores, x = _setup((8, 4, 4), (4, 4, 8), 4, 13)
    qs, ss = quantize_cores(cores)
    on_the_fly = tt_forward(cores, x, backend="auto", interpret=True,
                            tune="off", weights="int8")
    stored = tt_forward(qs, x, backend="auto", interpret=True, tune="off",
                        scales=ss)      # weights='int8' implied by dtype
    np.testing.assert_array_equal(np.asarray(on_the_fly),
                                  np.asarray(stored))


def test_backend_suffix_parsing():
    assert parse_backend_spec("auto") == ("auto", None, None)
    assert parse_backend_spec("auto:measure") == ("auto", "measure", None)
    assert parse_backend_spec("auto:int8") == ("auto", None, "int8")
    assert parse_backend_spec("auto:measure:int8") == \
        ("auto", "measure", "int8")
    # fp32 alias (TTConfig spelling) normalizes to the canonical 'fp'
    assert parse_backend_spec("auto:off:fp32") == ("auto", "off", "fp")
    # explicit arguments win over the suffix
    assert parse_backend_spec("auto:off:int8", tune="measure",
                              weights="fp") == ("auto", "measure", "fp")
    with pytest.raises(ValueError):
        parse_backend_spec("auto:bogus")
    # duplicate suffix tokens of one category are a conflict, not a
    # silent first-one-wins
    with pytest.raises(ValueError, match="conflicting tune"):
        parse_backend_spec("auto:cached:measure")
    with pytest.raises(ValueError, match="conflicting weight"):
        parse_backend_spec("auto:fp:int8")


def test_int8_cores_without_scales_raise():
    plan, cores, x = _setup((8, 4, 4), (4, 4, 8), 4, 4)
    qs, ss = quantize_cores(cores)
    with pytest.raises(ValueError, match="scales"):
        tt_forward(qs, x, backend="auto", interpret=True, tune="off")
    # conflicting scales are rejected, never silently dropped
    with pytest.raises(ValueError, match="quantized on the fly"):
        tt_forward(cores, x, backend="auto", interpret=True, tune="off",
                   weights="int8", scales=ss)
    with pytest.raises(ValueError, match="silently ignored"):
        tt_forward(cores, x, backend="xla", scales=ss)


# ---------------------------------------------------------------------------
# Launch counting + int8-only fused eligibility
# ---------------------------------------------------------------------------

def test_auto_int8_dispatches_single_fused_launch():
    """auto + weights='int8' on a VMEM-resident d=3 chain must issue
    exactly ONE pallas_call, of the int8 chain kernel."""
    plan, cores, x = _setup((8, 4, 4), (4, 4, 8), 4, 16)
    tt_contract.reset_launch_counts()
    tt_forward(cores, x, backend="auto", interpret=True, tune="off",
               weights="int8")
    assert tt_contract.launch_counts() == {"fused_chain_int8": 1}
    tt_contract.reset_launch_counts()
    tt_forward(cores, x, backend="pallas_step", interpret=True, tune="off",
               weights="int8")
    assert tt_contract.launch_counts() == {"step_int8": 3}


def test_chain_fused_eligible_only_under_int8(monkeypatch):
    """The acceptance bar: a chain whose fp32 weights bust the VMEM budget
    (step fallback, d launches) must fuse to ONE launch under int8
    residency — same chain, same batch, only the resident dtype changed."""
    plan, cores, x = _setup((8, 4, 4), (4, 4, 8), 4, 16)
    sizes = chain_state_sizes(plan.ns, plan.ms, plan.ranks)
    weights = chain_weight_elems(plan.ns, plan.ms, plan.ranks)
    peak = max(a + b for a, b in zip(sizes, sizes[1:]))
    # budget between (states + int8 weights) and (states + fp32 weights)
    budget = peak * 8 * 4 * 2 + 2 * weights
    assert fused_chain_batch_tile(plan.ns, plan.ms, plan.ranks,
                                  vmem_budget=budget,
                                  weight_itemsize=4) is None
    assert fused_chain_batch_tile(plan.ns, plan.ms, plan.ranks,
                                  vmem_budget=budget,
                                  weight_itemsize=1) == 8

    import repro.kernels.plan as ttplan
    from repro.core.packing import chain_fit_report
    monkeypatch.setattr(
        ttplan, "chain_fit_report",
        lambda ns, ms, ranks, **kw: chain_fit_report(
            ns, ms, ranks, **dict(kw, vmem_budget=budget)))

    tt_contract.reset_launch_counts()
    got_fp = tt_forward(cores, x, backend="auto", interpret=True,
                        tune="off")
    assert tt_contract.launch_counts() == {"step": 3}, \
        "fp32 must fall back to the per-step kernel under this budget"

    tt_contract.reset_launch_counts()
    got_q = tt_forward(cores, x, backend="auto", interpret=True,
                       tune="off", weights="int8")
    assert tt_contract.launch_counts() == {"fused_chain_int8": 1}, \
        "int8 residency must re-admit the chain into the fused kernel"

    np.testing.assert_allclose(np.asarray(got_fp), np.asarray(got_q),
                               rtol=0.1, atol=0.1)   # quantization drift


# ---------------------------------------------------------------------------
# Autotuner: weight dtype in the key, int8 measure path
# ---------------------------------------------------------------------------

def test_explicit_weights_accepts_fp32_alias():
    """weights='fp32' (the TTConfig spelling) must normalize like the
    suffix form, not raise."""
    plan, cores, x = _setup((16, 8), (4, 16), 8, 8)
    base = tt_forward(cores, x, backend="xla")
    got = tt_forward(cores, x, backend="xla", weights="fp32")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
    with pytest.raises(ValueError, match="weight mode"):
        tt_forward(cores, x, backend="xla", weights="fp8")


def test_autotune_key_split_by_weight_itemsize(tmp_path):
    """bf16-resident cores (weight_itemsize=2 under fp32 activations)
    must not share a cache entry with fp32 cores of the same signature —
    a tile measured at 2 B/elem residency can bust VMEM at 4 B/elem."""
    cache = str(tmp_path / "tune.json")
    ns, ms, ranks = (4, 4, 8), (8, 4, 4), (1, 4, 4, 1)
    autotune.fused_tile(ns, ms, ranks, jnp.float32, 32, mode="measure",
                        interpret=True, cache_path=cache)
    autotune.fused_tile(ns, ms, ranks, jnp.float32, 32, mode="measure",
                        interpret=True, cache_path=cache,
                        weight_itemsize=2)
    import json
    entries = json.loads((tmp_path / "tune.json").read_text())
    assert {e.split("|")[-2] for e in entries} == {"wfp", "wfp2"}


def test_autotune_key_split_by_weight_dtype(tmp_path):
    cache = str(tmp_path / "tune.json")
    ns, ms, ranks = (4, 4, 8), (8, 4, 4), (1, 4, 4, 1)
    bb_fp = autotune.fused_tile(ns, ms, ranks, jnp.float32, 32,
                                mode="measure", interpret=True,
                                cache_path=cache)
    bb_q = autotune.fused_tile(ns, ms, ranks, jnp.float32, 32,
                               mode="measure", interpret=True,
                               cache_path=cache, weights="int8")
    assert bb_fp is not None and bb_q is not None
    import json
    entries = json.loads((tmp_path / "tune.json").read_text())
    assert len(entries) == 2
    assert {e.split("|")[-2] for e in entries} == {"wfp", "wint8"}


def test_autotune_atomic_write_leaves_no_temp_files(tmp_path):
    cache = str(tmp_path / "tune.json")
    autotune.fused_tile((4, 16), (16, 8), (1, 8, 1), jnp.float32, 16,
                        mode="measure", interpret=True, cache_path=cache)
    leftovers = [p for p in tmp_path.iterdir() if p.suffix == ".tmp"]
    assert leftovers == []
    assert (tmp_path / "tune.json").exists()
