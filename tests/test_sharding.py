"""Sharding rules + a real multi-device compile (8 host devices in a
subprocess so the main test process keeps seeing 1 device)."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.distributed import sharding as shd
from repro.models.spec import ParamSpec

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _mesh11():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(dev, ("data", "model"))


def test_param_pspec_logical_axes():
    mesh = _mesh11()
    # vocab → model axis
    s = ParamSpec((1024, 64), ("vocab", "embed"))
    p = shd.param_pspec(s, mesh)
    assert p[0] == "model" and p[1] is None
    # ff → model
    s = ParamSpec((64, 256), ("embed", "ff"))
    assert shd.param_pspec(s, mesh)[1] == "model"
    # heads → model
    s = ParamSpec((64, 8, 16), ("embed", "heads", "head_dim"))
    assert shd.param_pspec(s, mesh)[1] == "model"
    # TT cores: ranks/inputs replicated; the output-factor dim is
    # tensor-parallel when divisible (EXPERIMENTS §Perf it. 4)
    s = ParamSpec((1, 8, 8, 16), ("tt_r", "tt_n", "tt_m", "tt_r"))
    p = shd.param_pspec(s, mesh)
    assert p[0] is None and p[1] is None and p[3] is None
    assert p[2] in (None, "model")          # m shards iff divisible
    # layers axis never sharded
    s = ParamSpec((4, 64, 256), ("layers", "embed", "ff"))
    assert shd.param_pspec(s, mesh)[0] is None


def test_param_pspec_fsdp():
    mesh = _mesh11()
    s = ParamSpec((64, 256), ("embed", "ff"))
    p = shd.param_pspec(s, mesh, fsdp_axes=("data",))
    # largest free dim picks up the fsdp axis (embed: ff is taken by model)
    assert "data" in [a for a in jax.tree.leaves(list(p)) if a]


def test_shard_act_without_ctx_is_identity():
    import jax.numpy as jnp
    shd.set_ctx(None)
    x = jnp.ones((4, 4))
    y = shd.shard_act(x, ("act_batch", None))
    assert y is x


MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro.configs import build, get_config
from repro.configs.shapes import concrete_batch
from repro.distributed import sharding as shd
from repro.models.spec import is_spec
from repro.training.optimizer import OptConfig
from repro.training.train_loop import TrainConfig, make_train_step

assert len(jax.devices()) == 8
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

cfg = get_config("qwen3_32b", "smoke")
model = build(cfg)
rules = dict(shd.ACT_RULES_TRAIN)
shd.set_ctx(shd.ShardCtx(mesh, rules, ("pod", "data")))

params = model.init(jax.random.PRNGKey(0))
shards = shd.param_shardings(model.param_specs(), mesh, fsdp=True)
params = jax.device_put(params, shards)
state = {"params": params,
         "opt": {"m": jax.device_put(jax.tree.map(jnp.zeros_like, params), shards),
                 "v": jax.device_put(jax.tree.map(jnp.zeros_like, params), shards),
                 "step": jnp.zeros((), jnp.int32)}}
batch = concrete_batch(cfg, 8, 16)
step = jax.jit(make_train_step(model, TrainConfig(
    opt=OptConfig(warmup_steps=0), remat=True,
    compute_dtype=jnp.float32)))
new_state, metrics = step(state, batch)
loss1 = float(metrics["loss"])

# single-device reference: same math must come out of the SPMD program
shd.set_ctx(None)
params_r = model.init(jax.random.PRNGKey(0))
state_r = {"params": params_r,
           "opt": {"m": jax.tree.map(jnp.zeros_like, params_r),
                   "v": jax.tree.map(jnp.zeros_like, params_r),
                   "step": jnp.zeros((), jnp.int32)}}
new_r, metrics_r = jax.jit(make_train_step(model, TrainConfig(
    opt=OptConfig(warmup_steps=0), remat=True,
    compute_dtype=jnp.float32)))(state_r, batch)

import numpy as np
wa = np.asarray(jax.device_get(new_state["params"]["embed"]["table"]))
wb = np.asarray(jax.device_get(new_r["params"]["embed"]["table"]))
print(json.dumps({
    "loss_spmd": loss1,
    "loss_ref": float(metrics_r["loss"]),
    "max_param_diff": float(np.max(np.abs(wa - wb))),
}))
"""


@pytest.mark.slow
def test_spmd_train_step_matches_single_device(tmp_path):
    """8-device (pod,data,model)=(2,2,2) SPMD train step == 1-device math.
    Proves: sharding rules produce a valid GSPMD program AND the program
    computes the same update."""
    script = tmp_path / "multidev.py"
    script.write_text(MULTIDEV_SCRIPT)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["loss_spmd"] - res["loss_ref"]) < 1e-3, res
    assert res["max_param_diff"] < 1e-3, res


EP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.models.moe import moe_apply, moe_apply_ep, moe_spec
from repro.models.spec import init_tree

results = []
for arch, mesh_shape in (("mixtral_8x7b", (2, 4)),        # case A: E%M==0
                         ("deepseek_v2_lite_16b", (2, 4)),  # A + shared
                         ("mixtral_8x7b", (1, 8))):         # case B/C: E<M
    cfg = get_config(arch, "smoke")
    p = init_tree(jax.random.PRNGKey(0), moe_spec(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    ref = moe_apply(p, cfg, x)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    shd.set_ctx(shd.ShardCtx(mesh, dict(shd.ACT_RULES_TRAIN), ("data",)))
    got = jax.jit(lambda pp, xx: moe_apply_ep(pp, cfg, xx))(p, x)
    shd.set_ctx(None)
    results.append(float(jnp.max(jnp.abs(got - ref))))
print(results)
assert all(d < 2e-4 for d in results), results
print("OK")
"""


@pytest.mark.slow
def test_expert_parallel_matches_global_dispatch(tmp_path):
    """shard_map EP MoE (cases A/B/C) == the global GSPMD formulation on an
    8-device mesh — the §Perf iteration-2 optimization changes layout, not
    math."""
    script = tmp_path / "ep.py"
    script.write_text(EP_SCRIPT)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import build, get_config
from repro.distributed import sharding as shd
from repro.training import checkpoint as ckpt

cfg = get_config("deepseek_7b", "smoke")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

# save from a (4 data, 2 model) mesh
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
shards_a = shd.param_shardings(model.param_specs(), mesh_a, fsdp=True)
params_a = jax.device_put(params, shards_a)
ckpt.save("/tmp/elastic_ckpt", {"params": params_a}, step=1)

# restore onto a (2 data, 4 model) mesh — different DP/TP split
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
shards_b = shd.param_shardings(model.param_specs(), mesh_b, fsdp=True)
restored, manifest = ckpt.restore("/tmp/elastic_ckpt", {"params": params},
                                  shardings={"params": shards_b})
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(jax.device_get(a)),
                                  np.asarray(jax.device_get(b)))
# every restored leaf actually lives on mesh_b
for leaf in jax.tree.leaves(restored):
    assert leaf.sharding.mesh.shape == mesh_b.shape, leaf.sharding
print("ELASTIC_OK")
"""


@pytest.mark.slow
def test_elastic_rescale_restore(tmp_path):
    """Checkpoint saved under one mesh restores bit-identically onto a
    different (DP, TP) split — the elastic-rescale path of fault.py."""
    script = tmp_path / "elastic.py"
    script.write_text(ELASTIC_SCRIPT)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ELASTIC_OK" in out.stdout


# ---------------------------------------------------------------------------
# Serving shardings (DESIGN.md §14)
# ---------------------------------------------------------------------------

def test_serve_param_rules_replicate_tt_cores():
    """Serving replicates every TT core dim (tt_m loses its training-time
    TP rule) while embeddings/LM head stay vocab-sharded."""
    mesh = _mesh11()
    s = ParamSpec((1, 8, 8, 16), ("tt_r", "tt_n", "tt_m", "tt_r"))
    p = shd.param_pspec(s, mesh, rules=shd.SERVE_PARAM_RULES)
    assert all(a is None for a in p)
    s = ParamSpec((1024, 64), ("vocab", "embed"))
    p = shd.param_pspec(s, mesh, rules=shd.SERVE_PARAM_RULES)
    assert p[0] == "model"


def test_serve_param_shardings_survive_quantized_tree():
    """serve_param_shardings walks the params tree, so the int8 checkpoint
    transform (same paths, int8 dtypes, extra ``scales`` leaves) gets a
    complete sharding tree — scales fall back to replicated."""
    from repro.configs import build, get_config
    from repro.configs.base import TTConfig

    cfg = get_config("deepseek_7b", "smoke",
                     tt=TTConfig(enabled=True, families=("ffn",),
                                 rank=4, min_factor=2))
    model = build(cfg)
    params = model.quantize_params(model.init(jax.random.PRNGKey(0)))
    mesh = _mesh11()
    shards = shd.serve_param_shardings(model.param_specs(), params, mesh)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(shards)
    assert len(flat_p) == len(flat_s)
    saw_scales = saw_sharded = False
    for (path, leaf), sh in zip(flat_p, jax.tree.leaves(shards)):
        assert isinstance(sh, jax.sharding.NamedSharding)
        keys = [str(getattr(p, "key", p)) for p in path]
        if "scales" in keys:
            saw_scales = True
            assert sh.spec == jax.sharding.PartitionSpec()
        if "model" in jax.tree.leaves(list(sh.spec)):
            saw_sharded = True
            assert "tt" not in keys     # cores replicated when serving
    assert saw_scales and saw_sharded


def test_serve_cache_shardings_kv_and_batch_axes():
    mesh = _mesh11()
    cache = {"l": {"k": np.zeros((2, 8, 32, 4, 16)),
                   "v": np.zeros((2, 8, 32, 4, 16)),
                   "lat": np.zeros((2, 8, 32, 24))},
             "pos": np.zeros((8,), np.int32),
             "block_tables": np.zeros((8, 4), np.int32)}
    shards = shd.serve_cache_shardings(cache, mesh)
    P = jax.sharding.PartitionSpec
    assert shards["l"]["k"].spec == P(None, None, None, "model", None)
    assert shards["l"]["v"].spec == P(None, None, None, "model", None)
    def replicated(spec):
        return all(a is None for a in spec)
    assert replicated(shards["l"]["lat"].spec)   # MLA latents replicated
    assert replicated(shards["pos"].spec)
    assert replicated(shards["block_tables"].spec)  # host-logical, replicated
    # dense pools pass batch=num_slots: slot axis picks up 'data' — on
    # this 1-device mesh the extent-1 data axis is skipped, so the rule
    # is only visible through the KV spec staying unchanged
    shards = shd.serve_cache_shardings(cache, mesh, batch=8)
    assert shards["l"]["k"].spec == P(None, None, None, "model", None)


def test_make_serve_mesh_validation():
    from repro.launch.mesh import make_serve_mesh
    m = make_serve_mesh(1)
    assert dict(m.shape) == {"data": 1, "model": 1}
    with pytest.raises(ValueError, match="device_count"):
        make_serve_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError, match="divide"):
        make_serve_mesh(1, data=2)


SERVE_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
import numpy as np
from repro.configs import build, get_config
from repro.configs.shapes import concrete_batch
from repro.launch.mesh import make_serve_mesh
from repro.serving.scheduler import Request, Scheduler

assert len(jax.devices()) == 4
S, NEW = 8, 8


def decode(model, cfg, params, mesh, paged, sampled):
    key = jax.random.PRNGKey(7)
    sched = Scheduler(model, params, num_slots=2, cache_len=S + NEW + 4,
                      paged=paged, block_size=4, key=key, mesh=mesh)
    for b in range(2):
        toks = concrete_batch(cfg, 1, S, seed=b)["tokens"]
        kw = dict(temperature=1.0, top_k=3,
                  key=jax.random.fold_in(key, b)) if sampled else {}
        sched.submit(Request(uid=b, inputs={"tokens": toks},
                             max_new_tokens=NEW, **kw))
    done = sched.run()
    for f in sched.finished:
        done[f.uid] = f
    return [[int(t) for t in done[b].tokens] for b in range(2)]


for arch in ("qwen3_32b",            # gqa
             "gemma3_4b",            # local/global window
             "deepseek_v2_lite_16b", # mla + moe experts
             "mamba2_2p7b",          # ssm
             "jamba_v0_1_52b"):      # hybrid attn/ssm
    cfg = get_config(arch, "smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_serve_mesh(4)
    for sampled in (False, True):
        ref = decode(model, cfg, params, None, False, sampled)
        got_d = decode(model, cfg, params, mesh, False, sampled)
        got_p = decode(model, cfg, params, mesh, True, sampled)
        tag = f"{arch} sampled={sampled}"
        assert got_d == ref, f"{tag}: dense sharded != single-device"
        assert got_p == ref, f"{tag}: paged sharded != single-device"
    print(arch, "OK")
print("MESH_INVARIANCE_OK")
"""


@pytest.mark.slow
def test_mesh_invariance_all_families(tmp_path):
    """Sharded serving is pure data placement: on a 4-device mesh the
    scheduler decodes token-identically to the single-device run — greedy
    and seeded sampling, dense and paged pools — across the gqa, window,
    MLA+MoE, SSM and hybrid families (DESIGN.md §14)."""
    script = tmp_path / "serve_mesh.py"
    script.write_text(SERVE_MESH_SCRIPT)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, \
        out.stdout[-2000:] + out.stderr[-3000:]
    assert "MESH_INVARIANCE_OK" in out.stdout
