"""Property tests (hypothesis) for the paper's §4.1 alignment strategy.

The paper's central empirical claim (Fig. 7): over 374k configurations the
aligned permutation is ALWAYS FLOPs-optimal (ratio ≡ 1.0) and
near-memory-optimal.  We verify the FLOPs claim *exhaustively over all
permutations* for randomized factor shapes — a stronger statement than the
paper's sampled benchmark.
"""
import itertools

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.dse import aligned_pair
from repro.core.flops import (clip_ranks, num_permutations_aligned,
                              tt_flops, tt_params)

factor = st.integers(min_value=2, max_value=14)
dims = st.integers(min_value=2, max_value=4)         # d ≤ 4: (4!)² ≤ 576 perms


@st.composite
def shape_pair(draw):
    d = draw(dims)
    ms = tuple(draw(factor) for _ in range(d))
    ns = tuple(draw(factor) for _ in range(d))
    rank = draw(st.sampled_from([2, 4, 8, 16]))
    ranks = tuple([1] + [rank] * (d - 1) + [1])
    return ms, ns, ranks, rank


def _all_perm_values(ms, ns, ranks):
    """FLOPs/params at the SAME rank list for every permutation.

    Proposition 3 compares permutations at a fixed rank list.  (Clipping the
    ranks per-permutation — footnote 5 — can let the aligned shape admit a
    *larger* feasible rank and hence more FLOPs; hypothesis found that
    counterexample, recorded in EXPERIMENTS.md §Validation.)"""
    vals = []
    for pm in set(itertools.permutations(ms)):
        for pn in set(itertools.permutations(ns)):
            vals.append((tt_flops(pm, pn, ranks, bias=False),
                         tt_params(pm, pn, ranks, bias=False)))
    return vals


@given(shape_pair())
@settings(max_examples=60, deadline=None)
def test_aligned_is_flops_optimal_over_all_permutations(sp):
    """Fig. 7 FLOPs ratio ≡ 1.0: aligned == min over every permutation."""
    ms, ns, ranks, rank = sp
    ams, ans = aligned_pair(ms, ns)
    aligned_flops = tt_flops(ams, ans, ranks, bias=False)
    min_flops = min(f for f, _ in _all_perm_values(ms, ns, ranks))
    assert aligned_flops == min_flops


@given(shape_pair())
@settings(max_examples=40, deadline=None)
def test_aligned_memory_within_permutation_range(sp):
    """Fig. 8: aligned memory lies within [min, max] over permutations and
    is far below the max (ratio_Memory is concentrated near 1)."""
    ms, ns, ranks, rank = sp
    ams, ans = aligned_pair(ms, ns)
    amem = tt_params(ams, ans, ranks, bias=False)
    mems = [p for _, p in _all_perm_values(ms, ns, ranks)]
    assert min(mems) <= amem <= max(mems)


@given(shape_pair())
@settings(max_examples=60, deadline=None)
def test_prop4_counts_distinct_permutations(sp):
    """Prop. 4 formula == the literal number of distinct (m-perm, n-perm)
    pairs."""
    ms, ns, _, _ = sp
    n_perms = (len(set(itertools.permutations(ms)))
               * len(set(itertools.permutations(ns))))
    assert num_permutations_aligned(ms, ns) == n_perms


@given(shape_pair())
@settings(max_examples=60, deadline=None)
def test_alignment_definition(sp):
    """Definition 1: m non-increasing, n non-decreasing; products preserved."""
    ms, ns, _, _ = sp
    ams, ans = aligned_pair(ms, ns)
    assert all(ams[i] >= ams[i + 1] for i in range(len(ams) - 1))
    assert all(ans[i] <= ans[i + 1] for i in range(len(ans) - 1))
    import math
    assert math.prod(ams) == math.prod(ms)
    assert math.prod(ans) == math.prod(ns)


@given(shape_pair())
@settings(max_examples=60, deadline=None)
def test_rank_clipping_invariants(sp):
    """Clipped ranks: boundary 1s, never above requested, never above the
    unfolding bound (footnote 5)."""
    ms, ns, _, rank = sp
    from repro.core.flops import max_tt_rank_at_cut
    ranks = clip_ranks(ms, ns, [1] + [rank] * (len(ms) - 1) + [1])
    assert ranks[0] == ranks[-1] == 1
    for t in range(1, len(ms)):
        assert ranks[t] <= rank
        assert ranks[t] <= max_tt_rank_at_cut(ms, ns, t)
