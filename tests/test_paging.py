"""Block-paged KV-cache correctness (DESIGN.md §7).

Three layers of guarantees:

  * allocator invariants — refcounted free-list bookkeeping under random
    op sequences (hypothesis): no double-free, shared blocks never reach
    the free list while referenced, COW gives a private block exactly when
    the target is shared/published;
  * token identity — the paged scheduler reproduces the dense scheduler /
    sequential reference bit-for-bit across all five architecture families
    (full GQA, windowed+hybrid local:global, MLA+MoE, SSM, hybrid
    attn:mamba), greedy and seeded sampling;
  * prefix reuse — shared-prefix admissions share resident blocks, skip
    the covered prefill compute, trigger COW on full coverage, and still
    match the dense reference token-for-token.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build, get_config
from repro.configs.shapes import concrete_batch
from repro.serving.engine import generate, generate_fixed
from repro.serving.paging import BlockAllocator, chain_hashes, logical_blocks
from repro.serving.scheduler import Request, Scheduler

BLOCK = 4

PAGED_ARCHS = ["qwen3_32b", "gemma3_4b", "deepseek_v2_lite_16b",
               "mamba2_2p7b", "jamba_v0_1_52b"]


def _build(arch):
    cfg = get_config(arch, "smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference(model, params, toks_row, steps, cache_len):
    res = generate_fixed(model, params,
                         {"tokens": toks_row[None], "cache_len": cache_len},
                         steps=steps)
    return np.asarray(res.tokens)[0], np.asarray(res.logprobs)[0]


# ---------------------------------------------------------------------------
# Allocator unit + property tests
# ---------------------------------------------------------------------------

def test_allocator_basic_lifecycle():
    a = BlockAllocator(4, BLOCK)
    b0, b1 = a.alloc(), a.alloc()
    assert a.free_count == 2 and a.in_use == 2
    a.incref(b0)                          # shared: refcount 2
    a.decref(b0)
    assert a.refcount(b0) == 1            # still live — not freed
    a.decref(b0)
    assert a.free_count == 3              # unpublished → straight to free
    a.publish(b1, b"h1")
    a.decref(b1)
    assert a.free_count == 3 and a.cached_count == 1
    assert a.acquire(b"h1") == b1         # revived from the retired cache
    assert a.cached_count == 0 and a.refcount(b1) == 1


def test_allocator_double_free_raises():
    a = BlockAllocator(2, BLOCK)
    b = a.alloc()
    a.decref(b)
    with pytest.raises(RuntimeError):
        a.decref(b)
    with pytest.raises(RuntimeError):
        a.incref(b)                       # incref of a free block


def test_allocator_cow_semantics():
    a = BlockAllocator(4, BLOCK)
    b = a.alloc()
    assert a.cow(b) == b                  # exclusive + unpublished: in place
    a.incref(b)                           # now shared
    nb = a.cow(b)
    assert nb != b and a.refcount(b) == 1 and a.refcount(nb) == 1
    p = a.alloc()
    a.publish(p, b"hp")
    np_ = a.cow(p)                        # published: COW even at refcount 1
    assert np_ != p
    assert a.cached_count == 1            # the published original is cached


def test_allocator_eviction_lru():
    a = BlockAllocator(2, BLOCK)
    b0, b1 = a.alloc(), a.alloc()
    a.publish(b0, b"h0")
    a.publish(b1, b"h1")
    a.decref(b0)
    a.decref(b1)
    assert a.available == 2 and a.free_count == 0
    got = a.alloc()                       # evicts b0 (LRU)
    assert got == b0
    assert a.lookup(b"h0") is None and a.lookup(b"h1") == b1


def test_chain_hashes_prefix_property():
    t1 = np.arange(16)
    t2 = np.concatenate([np.arange(12), [99, 98, 97, 96]])
    h1, h2 = chain_hashes(t1, 4), chain_hashes(t2, 4)
    assert h1[:3] == h2[:3] and h1[3] != h2[3]
    assert len(chain_hashes(np.arange(7), 4)) == 1   # full blocks only
    assert logical_blocks(7, 4) == 2


def test_allocator_random_walk_invariants():
    """Hypothesis-driven random op walks: every block is always in exactly
    one of {free, live, evictable}; a referenced block can never be
    re-allocated (no freed-while-live); decref beyond zero raises (no
    double-free); COW never aliases a shared/published target."""
    pytest.importorskip("hypothesis", reason="hypothesis not installed")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=120, deadline=None)
    @given(ops=st.lists(st.tuples(st.integers(0, 5), st.integers(0, 31),
                                  st.integers(0, 7)),
                        min_size=1, max_size=120),
           nb=st.integers(2, 9))
    def walk(ops, nb):
        a = BlockAllocator(nb, BLOCK)
        live: dict[int, int] = {}         # bid -> our refcount
        for op, arg, harg in ops:
            h = b"h%d" % harg
            if op == 0:                   # alloc
                if a.available:
                    bid = a.alloc()
                    assert bid not in live        # never hands out a live id
                    live[bid] = 1
                else:
                    with pytest.raises(RuntimeError):
                        a.alloc()
            elif op == 1 and live:        # incref
                bid = sorted(live)[arg % len(live)]
                a.incref(bid)
                live[bid] += 1
            elif op == 2 and live:        # decref
                bid = sorted(live)[arg % len(live)]
                a.decref(bid)
                live[bid] -= 1
                if live[bid] == 0:
                    del live[bid]
            elif op == 3 and live:        # publish
                bid = sorted(live)[arg % len(live)]
                a.publish(bid, h)
            elif op == 4:                 # acquire
                bid = a.acquire(h)
                if bid is not None:
                    live[bid] = live.get(bid, 0) + 1
            elif op == 5 and live:        # cow (divergent append)
                bid = sorted(live)[arg % len(live)]
                before = a.refcount(bid)
                try:
                    nbid = a.cow(bid)
                except RuntimeError:      # pool exhausted mid-COW
                    continue
                if nbid == bid:           # in-place: must have been private
                    assert before == 1
                else:
                    live[bid] -= 1
                    if live[bid] == 0:
                        del live[bid]
                    live[nbid] = live.get(nbid, 0) + 1
                    # the shared original keeps its other references
                    if bid in live:
                        assert a.refcount(bid) == live[bid]
            # ---- invariants
            assert a.free_count + a.cached_count + a.in_use == a.num_blocks
            for bid, refs in live.items():
                assert a.refcount(bid) == refs > 0
        # drain: every reference can be returned exactly once
        for bid, refs in list(live.items()):
            for _ in range(refs):
                a.decref(bid)
        assert a.in_use == 0
        assert a.free_count + a.cached_count == a.num_blocks

    walk()


def test_allocator_resize_grow_and_shrink():
    """Grow is immediate (new ids join the free list); a shrink below a
    live id fences the tail — capacity and availability drop at once, the
    live id drains through its normal decref, and the caller finalizes."""
    a = BlockAllocator(4, BLOCK)
    b0, b1 = a.alloc(), a.alloc()         # ids 0, 1
    assert a.resize(6)
    assert a.num_blocks == 6 and a.available == 4
    assert not a.resize(1)                # b1 sits above the fence
    assert a.pending_target == 1 and a.capacity == 1
    assert a.available == 0               # free ids >= fence are gone
    assert not a.shrink_ready
    a.decref(b1)                          # drains the fence
    assert a.shrink_ready
    a.finalize_shrink()
    assert a.num_blocks == 1 and a.pending_target is None
    assert a.refcount(b0) == 1
    a.decref(b0)
    a.assert_quiescent()


def test_allocator_shrink_cancel_resurrects_ids():
    """Growing (or re-stating the current size) while a shrink is pending
    cancels it — ids dropped while the fence was up must return to the
    free list so the ledger still tiles the pool."""
    a = BlockAllocator(4, BLOCK)
    bids = [a.alloc() for _ in range(3)]  # ids 0, 1, 2
    assert not a.resize(2)                # id 2 live above the fence
    a.decref(bids[2])                     # dies at the fence (not refiled)
    assert a.resize(4)                    # cancel: back to full size
    assert a.pending_target is None and a.num_blocks == 4
    for b in bids[:2]:
        a.decref(b)
    a.assert_quiescent()                  # ids 2 and 3 resurrected


# ---------------------------------------------------------------------------
# Paged ≡ dense token identity across the five cache families
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_matches_sequential_across_families(arch):
    """Staggered admissions through a 2-slot paged pool (slots at different
    depths, block-table gather/scatter decode, SSM leaves slot-indexed)
    must reproduce the sequential per-request reference token-for-token —
    the paged twin of the dense-scheduler determinism contract."""
    cfg, model, params = _build(arch)
    S, cache_len = 8, 16
    budgets = [5, 3]
    toks = concrete_batch(cfg, 2, S)["tokens"]
    sched = Scheduler(model, params, num_slots=2, cache_len=cache_len,
                      paged=True, block_size=BLOCK)
    sched.submit(Request(uid=0, inputs={"tokens": toks[0:1]},
                         max_new_tokens=budgets[0]))
    sched.step()
    sched.step()                          # slot 0 two tokens deep …
    sched.submit(Request(uid=1, inputs={"tokens": toks[1:2]},
                         max_new_tokens=budgets[1]))  # … when slot 1 joins
    out = dict(sched.run())
    for f in sched.finished:
        out[f.uid] = f
    sched.allocator.assert_quiescent()    # drained: no leaked blocks
    for uid in range(2):
        ref, ref_lp = _reference(model, params, toks[uid], budgets[uid],
                                 cache_len)
        np.testing.assert_array_equal(out[uid].tokens, ref)
        np.testing.assert_allclose(out[uid].logprobs, ref_lp,
                                   rtol=1e-5, atol=1e-5)


def test_paged_matches_dense_seeded_sampling():
    """Per-request PRNG streams are pool-layout independent: seeded
    sampling through the paged pool equals the dense pool bit-for-bit."""
    cfg, model, params = _build("deepseek_7b")
    batch = dict(concrete_batch(cfg, 3, 8), cache_len=16)
    key = jax.random.PRNGKey(11)
    rd = generate(model, params, batch, steps=5, temperature=0.7, key=key)
    rp = generate(model, params, batch, steps=5, temperature=0.7, key=key,
                  paged=True, block_size=BLOCK)
    np.testing.assert_array_equal(np.asarray(rd.tokens),
                                  np.asarray(rp.tokens))


def test_paged_generate_greedy_matches_fixed():
    cfg, model, params = _build("deepseek_7b")
    batch = dict(concrete_batch(cfg, 3, 8), cache_len=16)
    rf = generate_fixed(model, params, batch, steps=5)
    rp = generate(model, params, batch, steps=5, paged=True,
                  block_size=BLOCK)
    np.testing.assert_array_equal(np.asarray(rf.tokens),
                                  np.asarray(rp.tokens))


def test_paged_zero_replanning():
    """Paged serving executes build-time TT plans only (DESIGN.md §10)."""
    from repro.configs.base import TTConfig
    from repro.kernels import plan as ttplan
    cfg = get_config("deepseek_7b", "smoke",
                     tt=TTConfig(enabled=True, families=("ffn",), rank=4,
                                 min_factor=2, backend="auto"))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    model.plan_book                        # resolve everything up front
    batch = dict(concrete_batch(cfg, 2, 8), cache_len=16)
    before = ttplan.plan_resolutions()
    generate(model, params, batch, steps=4, paged=True, block_size=BLOCK)
    assert ttplan.plan_resolutions() == before


# ---------------------------------------------------------------------------
# Prefix reuse
# ---------------------------------------------------------------------------

def test_prefix_reuse_shares_blocks_and_skips_prefill():
    """Second request sharing a 12-token prefix (3 full blocks) must admit
    through the resume path — nonzero hit tokens, skipped prefill compute,
    refcount 2 on the shared blocks while both are live — and stay
    token-identical to the dense reference."""
    cfg, model, params = _build("deepseek_7b")
    S, cache_len, steps = 16, 24, 5
    toks = concrete_batch(cfg, 2, S)["tokens"]
    t0 = np.asarray(toks[0:1])
    t1 = np.concatenate([t0[:, :12], np.asarray(toks[1:2, 12:])], axis=1)
    sched = Scheduler(model, params, num_slots=2, cache_len=cache_len,
                      paged=True, block_size=BLOCK)
    assert sched.prefix_cache             # full-attention arch qualifies
    sched.submit(Request(uid=0, inputs={"tokens": jnp.asarray(t0)},
                         max_new_tokens=steps))
    sched.step()
    sched.submit(Request(uid=1, inputs={"tokens": jnp.asarray(t1)},
                         max_new_tokens=steps))
    sched.step()
    # both live: the 3 shared prefix blocks are refcounted twice
    shared_refs = [sched.allocator.refcount(b)
                   for b in sched._slot_blocks[0][:3]]
    assert shared_refs == [2, 2, 2]
    assert sched._slot_blocks[0][:3] == sched._slot_blocks[1][:3]
    assert sched._slot_blocks[0][3] != sched._slot_blocks[1][3]  # diverge
    out = dict(sched.run())
    for f in sched.finished:
        out[f.uid] = f
    sched.allocator.assert_quiescent()    # drained: no leaked blocks
    st = sched.stats()
    assert st["prefix_hit_tokens"] == 12
    assert st["prefill_tokens_skipped"] == 12
    assert st["prefix_hit_rate"] > 0
    for uid, row in enumerate([t0, t1]):
        ref, _ = _reference(model, params, jnp.asarray(row)[0], steps,
                            cache_len)
        np.testing.assert_array_equal(out[uid].tokens, ref)


def test_prefix_full_coverage_cow():
    """An identical re-submitted prompt is fully covered by published
    blocks: admission COWs the last matched block (divergent append target)
    and recomputes only the final token — and shared blocks referenced by
    the cache are never handed out while live (the first request ran to
    retirement, its published blocks revived from the evictable cache)."""
    cfg, model, params = _build("deepseek_7b")
    S, cache_len, steps = 16, 24, 4
    t0 = concrete_batch(cfg, 1, S)["tokens"]
    sched = Scheduler(model, params, num_slots=1, cache_len=cache_len,
                      paged=True, block_size=BLOCK)
    for uid in range(2):                  # sequential: slot reuse via queue
        sched.submit(Request(uid=uid, inputs={"tokens": t0},
                             max_new_tokens=steps))
    out = sched.run()
    sched.allocator.assert_quiescent()    # drained: no leaked blocks
    st = sched.stats()
    assert st["prefix_hit_tokens"] == S   # full coverage
    assert st["prefill_tokens_skipped"] == S - 1   # last token recomputed
    ref, _ = _reference(model, params, t0[0], steps, cache_len)
    for uid in range(2):
        np.testing.assert_array_equal(out[uid].tokens, ref)


def test_prefix_cache_gated_by_family():
    """Window rings cycle in place and SSM state summarizes the whole
    history — prefix sharing must be disabled there automatically."""
    for arch, expect in [("qwen3_32b", True), ("deepseek_v2_lite_16b", True),
                         ("gemma3_4b", False), ("mamba2_2p7b", False),
                         ("jamba_v0_1_52b", False), ("mixtral_8x7b", False)]:
        model = build(get_config(arch, "smoke"))
        assert model.supports_prefix_reuse is expect, arch


# ---------------------------------------------------------------------------
# Admission by memory
# ---------------------------------------------------------------------------

def test_memory_admission_queues_until_blocks_free():
    """Two slots but blocks for only one in-flight request: the second
    stays queued (admission by memory, not slot count) until the first
    retires, and both outputs match the sequential reference."""
    cfg, model, params = _build("deepseek_7b")
    S, cache_len, steps = 8, 16, 4
    toks = concrete_batch(cfg, 2, S)["tokens"]
    blocks_per_req = logical_blocks(S + steps, BLOCK)
    sched = Scheduler(model, params, num_slots=2, cache_len=cache_len,
                      paged=True, block_size=BLOCK,
                      num_blocks=blocks_per_req, prefix_cache=False)
    for uid in range(2):
        sched.submit(Request(uid=uid, inputs={"tokens": toks[uid:uid + 1]},
                             max_new_tokens=steps))
    sched.step()
    assert sched.num_active == 1 and len(sched.queue) == 1   # head waits
    out = sched.run()
    sched.allocator.assert_quiescent()    # drained: no leaked blocks
    for uid in range(2):
        ref, _ = _reference(model, params, toks[uid], steps, cache_len)
        np.testing.assert_array_equal(out[uid].tokens, ref)


def test_oversized_request_rejected_up_front():
    cfg, model, params = _build("deepseek_7b")
    sched = Scheduler(model, params, num_slots=1, cache_len=16,
                      paged=True, block_size=BLOCK, num_blocks=2)
    with pytest.raises(ValueError):       # needs 4 blocks, pool has 2
        sched.submit(Request(
            uid=0, inputs={"tokens": concrete_batch(cfg, 1, 8)["tokens"]},
            max_new_tokens=8))


# ---------------------------------------------------------------------------
# Prompt-length bucketing + paged cache API
# ---------------------------------------------------------------------------

def test_bucketed_prefill_bounds_compiles():
    """Varied-length traffic through the bucketed prefill compiles
    O(log cache_len) variants, asserted via the build counter."""
    cfg, model, params = _build("deepseek_7b")
    cache_len = 64
    fn = model.jitted_prefill_bucketed(cache_len)
    ref = {}
    for L in range(3, 41):
        logits, cache = fn(params, {
            "tokens": concrete_batch(cfg, 1, L, seed=L)["tokens"]})
        assert int(cache["pos"]) == L     # true length, not the bucket
        ref[L] = logits
    assert model.prefill_builds <= 3      # buckets {16, 32, 64} only
    # bucketing is transparent up to padding-induced reduction reorder in
    # the logit head (~1e-6; KV rows are bitwise-identical, so decode
    # token streams match — the identity tests above assert that)
    for L in (5, 23):
        exact, _ = model.jitted_prefill(cache_len, shape_key=L)(
            params, concrete_batch(cfg, 1, L, seed=L))
        np.testing.assert_allclose(np.asarray(ref[L]), np.asarray(exact),
                                   rtol=1e-4, atol=1e-5)


def test_init_cache_paged_layout():
    cfg, model, params = _build("jamba_v0_1_52b")
    cache = model.init_cache(2, 16, paged=True, block=BLOCK, num_blocks=6)
    assert cache["pos"].shape == (2,)
    assert cache["block_tables"].shape == (2, 4)
    assert bool(jnp.all(cache["block_tables"] == 6))   # sentinel-initialized
    leaves = {k: v for k, v in cache["g0"]["b0"].items()}
    # jamba period: b0/b1 ssm, attn at index 2 — ssm leaves slot-indexed
    assert leaves["state"].shape[1] == 2
    attn = cache["g0"]["b2"]
    assert attn["k"].shape[1:3] == (7, BLOCK)          # 6 blocks + sentinel


def test_per_request_sampling_mixed_batch():
    """One pool mixing greedy and sampled requests: the greedy rows must
    equal the all-greedy reference (their PRNG stream untouched by the
    sampled neighbors), and top_k=1 must equal greedy."""
    cfg, model, params = _build("deepseek_7b")
    S, cache_len, steps = 8, 16, 4
    toks = concrete_batch(cfg, 3, S)["tokens"]
    sched = Scheduler(model, params, num_slots=3, cache_len=cache_len,
                      paged=True, block_size=BLOCK,
                      key=jax.random.PRNGKey(3))
    sched.submit(Request(uid=0, inputs={"tokens": toks[0:1]},
                         max_new_tokens=steps))                 # greedy
    sched.submit(Request(uid=1, inputs={"tokens": toks[1:2]},
                         max_new_tokens=steps, temperature=0.9))
    sched.submit(Request(uid=2, inputs={"tokens": toks[2:3]},
                         max_new_tokens=steps, temperature=0.9, top_k=1))
    out = sched.run()
    ref0, _ = _reference(model, params, toks[0], steps, cache_len)
    np.testing.assert_array_equal(out[0].tokens, ref0)
    ref2, _ = _reference(model, params, toks[2], steps, cache_len)
    np.testing.assert_array_equal(out[2].tokens, ref2)  # top-1 == greedy
