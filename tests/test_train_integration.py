"""Training integration: loss goes down, microbatching is exact, the data
pipeline is deterministic/resumable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build, get_config
from repro.data.pipeline import DataIterator, DataState, make_batch
from repro.training.optimizer import OptConfig, adamw_init, adamw_update
from repro.training.train_loop import TrainConfig, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("deepseek_7b", "smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_loss_decreases_over_steps(tiny):
    """~30 steps on a repeating synthetic batch must reduce the loss —
    end-to-end gradient correctness through every layer type."""
    cfg, model, params = tiny
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=5,
                                     total_steps=50, weight_decay=0.0),
                       remat=False, compute_dtype=jnp.float32)
    state = {"params": params, "opt": adamw_init(params)}
    step_fn = jax.jit(make_train_step(model, tcfg))
    batch = make_batch(cfg, B=4, S=32, step=0)
    losses = []
    for _ in range(30):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::6]
    assert all(np.isfinite(losses))


def test_microbatch_accumulation_matches_full_batch(tiny):
    """micro_batches=4 must equal the full-batch loss/grad direction
    (same effective batch, scan-accumulated)."""
    cfg, model, params = tiny
    batch = make_batch(cfg, B=8, S=16, step=3)
    full = TrainConfig(opt=OptConfig(lr=1e-3), remat=False,
                       compute_dtype=jnp.float32, micro_batches=1)
    micro = TrainConfig(opt=OptConfig(lr=1e-3), remat=False,
                        compute_dtype=jnp.float32, micro_batches=4)
    state = {"params": params, "opt": adamw_init(params)}
    s_full, m_full = jax.jit(make_train_step(model, full))(state, batch)
    s_micro, m_micro = jax.jit(make_train_step(model, micro))(state, batch)
    # losses: full is the batch mean; micro is the mean of per-micro means —
    # equal when every micro batch has the same token count (it does here)
    np.testing.assert_allclose(float(m_full["loss"]),
                               float(m_micro["loss"]), rtol=1e-4)
    # parameters after one update agree
    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_micro["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)


def test_remat_matches_no_remat(tiny):
    """Activation rematerialization must not change the math."""
    cfg, model, params = tiny
    batch = make_batch(cfg, B=2, S=16, step=0)
    l0 = model.loss(params, batch, remat=False)
    l1 = model.loss(params, batch, remat=True)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    g0 = jax.grad(lambda p: model.loss(p, batch, remat=False))(params)
    g1 = jax.grad(lambda p: model.loss(p, batch, remat=True))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_adamw_step_and_schedule():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 0.5)}
    opt = adamw_init(params)
    cfg = OptConfig(lr=1e-2, warmup_steps=0, grad_clip=1e9,
                    weight_decay=0.0)
    p1, o1, met = adamw_update(grads, opt, params, cfg)
    assert int(o1["step"]) == 1
    assert float(met["grad_norm"]) == pytest.approx(0.5 * 4, rel=1e-5)
    # uniform grads → uniform update; direction is -lr·sign(g)
    upd = np.asarray(p1["w"] - params["w"])
    assert np.all(upd < 0)
    assert np.allclose(upd, upd.flat[0])


def test_grad_clipping_caps_update():
    params = {"w": jnp.ones((2,))}
    opt = adamw_init(params)
    cfg = OptConfig(lr=1e-2, warmup_steps=0, grad_clip=1.0,
                    weight_decay=0.0)
    huge = {"w": jnp.full((2,), 1e6)}
    p1, _, met = adamw_update(huge, opt, params, cfg)
    assert float(met["grad_norm"]) > 1e5
    assert np.all(np.isfinite(np.asarray(p1["w"])))
    assert np.max(np.abs(np.asarray(p1["w"] - params["w"]))) < 0.1


def test_data_pipeline_deterministic_and_resumable():
    cfg = get_config("deepseek_7b", "smoke")
    it1 = DataIterator(cfg, B=4, S=16)
    batches = [next(it1) for _ in range(5)]
    # restart from a saved state → identical continuation
    it2 = DataIterator(cfg, B=4, S=16)
    for _ in range(3):
        next(it2)
    saved = DataState.from_dict(it2.state.as_dict())
    it3 = DataIterator(cfg, B=4, S=16, state=saved)
    np.testing.assert_array_equal(np.asarray(next(it3)["tokens"]),
                                  np.asarray(batches[3]["tokens"]))
    np.testing.assert_array_equal(np.asarray(next(it3)["tokens"]),
                                  np.asarray(batches[4]["tokens"]))
    # different steps → different data
    assert not np.array_equal(np.asarray(batches[0]["tokens"]),
                              np.asarray(batches[1]["tokens"]))


def test_make_batch_shapes_all_frontends():
    for arch in ("internvl2_2b", "seamless_m4t_large_v2", "qwen3_32b"):
        cfg = get_config(arch, "smoke")
        b = make_batch(cfg, B=2, S=16, step=0)
        assert b["tokens"].dtype == jnp.int32
        assert int(jnp.max(b["tokens"])) < cfg.vocab_size
        if cfg.frontend == "vit":
            assert "image_embeds" in b
        if cfg.frontend == "speech":
            assert "speech_embeds" in b
