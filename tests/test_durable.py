"""Durable store, request journal, and kill-recovery (DESIGN.md §13).

Store level: a committed generation round-trips bit-exactly (awkward
dtypes included), torn or bit-flipped generations are detected by
checksum and fall back to the last clean one, and a fully-corrupt store
raises instead of returning torn state.  Journal level: appends are
replayable, a torn tail stops replay at the last acknowledged record.
Scheduler level: an acknowledged submit survives an immediate kill -9,
and recovery replays retires idempotently.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import build, get_config
from repro.configs.shapes import concrete_batch
from repro.core import durable
from repro.serving.durable import DurableScheduler, RequestJournal
from repro.serving.faults import (load_snapshot, save_snapshot, step_clock,
                                  _split_arrays)
from repro.serving.scheduler import Request, Scheduler

BLOCK = 4


# ---------------------------------------------------------------------------
# Generation store
# ---------------------------------------------------------------------------

def _awkward_arrays():
    import ml_dtypes
    return {
        "bf16": np.arange(6).reshape(2, 3).astype(ml_dtypes.bfloat16),
        "int8": (np.arange(7) - 3).astype(np.int8),
        "zero_d": np.asarray(2.5, np.float32),
        "empty_table": np.zeros((0, 4), np.int32),
        "big": np.arange(70_000, dtype=np.float32),   # spans chunks
    }


def test_write_read_roundtrip_awkward_leaves(tmp_path):
    arrays = _awkward_arrays()
    index = durable.write_arrays(str(tmp_path), arrays, chunk_bytes=1024)
    back = durable.read_arrays(str(tmp_path / "arrays.bin"), index,
                               chunk_bytes=1024)
    assert set(back) == set(arrays)
    for k, a in arrays.items():
        assert back[k].dtype == a.dtype and back[k].shape == a.shape
        assert back[k].tobytes() == a.tobytes()       # bit-exact


def test_generation_fallback_on_truncation_and_bitflip(tmp_path):
    root = str(tmp_path)
    for i in range(3):
        durable.write_generation(root, {"i": i},
                                 {"a": np.arange(100) + i})
    assert durable.committed_generations(root) == [1, 2, 3]
    # truncate gen 3 mid-file: checksummed load must fall back to gen 2
    with open(os.path.join(root, "gen_00000003", "arrays.bin"), "r+b") as f:
        f.truncate(37)
    gen, tree, arrays, _m, skipped = durable.load_latest_good(root)
    assert gen == 2 and tree == {"i": 1} and len(skipped) == 1
    assert "truncated" in skipped[0]
    # bit-flip gen 2: falls back again, to gen 1
    p = os.path.join(root, "gen_00000002", "arrays.bin")
    b = bytearray(open(p, "rb").read())
    b[11] ^= 0x10
    open(p, "wb").write(bytes(b))
    gen, tree, *_ = durable.load_latest_good(root)
    assert gen == 1 and tree == {"i": 0}


def test_all_generations_corrupt_raises_clear_error(tmp_path):
    root = str(tmp_path)
    durable.write_generation(root, {}, {"a": np.arange(10)})
    with open(os.path.join(root, "gen_00000001", "arrays.bin"), "r+b") as f:
        f.truncate(3)
    with pytest.raises(durable.CorruptGenerationError,
                       match="every generation .* corrupt"):
        durable.load_latest_good(root)


def test_empty_store_raises_filenotfound(tmp_path):
    with pytest.raises(FileNotFoundError):
        durable.load_latest_good(str(tmp_path))


def test_torn_tmp_dirs_are_invisible(tmp_path):
    """A crash before the atomic rename leaves only a .tmp dir, which a
    reader must never list as committed."""
    root = str(tmp_path)
    durable.write_generation(root, {"ok": True}, {"a": np.arange(4)})
    torn = os.path.join(root, "gen_00000002.tmp.999")
    os.makedirs(torn)
    with open(os.path.join(torn, "manifest.json"), "w") as f:
        json.dump({"schema": durable.DURABLE_SCHEMA}, f)
    assert durable.committed_generations(root) == [1]
    gen, tree, *_ = durable.load_latest_good(root)
    assert gen == 1 and tree == {"ok": True}


def test_wrong_schema_rejected(tmp_path):
    root = str(tmp_path)
    durable.write_generation(root, {}, {"a": np.arange(4)})
    mp = os.path.join(root, "gen_00000001", "manifest.json")
    m = json.load(open(mp))
    m["schema"] = durable.DURABLE_SCHEMA + 1
    json.dump(m, open(mp, "w"))
    with pytest.raises(durable.CorruptGenerationError, match="schema"):
        durable.load_generation(root, 1)


def test_prune_keeps_newest(tmp_path):
    root = str(tmp_path)
    for i in range(5):
        durable.write_generation(root, {"i": i}, {"a": np.arange(3)})
    durable.prune_generations(root, keep=2)
    assert durable.committed_generations(root) == [4, 5]


# ---------------------------------------------------------------------------
# Request journal
# ---------------------------------------------------------------------------

def test_journal_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "j.log")
    j = RequestJournal(path, fsync=False)
    for i in range(3):
        j.append({"type": "submit", "uid": i})
    j.close()
    # crash mid-append: an unterminated half-record at the tail
    with open(path, "ab") as f:
        f.write(b'{"type": "submit", "uid": 3, "se')
    records, good = RequestJournal.replay(path)
    assert [r["uid"] for r in records] == [0, 1, 2]
    assert good < os.path.getsize(path)
    # recovery truncates the torn tail, then appending continues cleanly
    with open(path, "r+b") as f:
        f.truncate(good)
    j2 = RequestJournal(path, fsync=False)
    j2.append({"type": "submit", "uid": 3})
    j2.close()
    records, good = RequestJournal.replay(path)
    assert [r["uid"] for r in records] == [0, 1, 2, 3]
    assert good == os.path.getsize(path)


def test_journal_corrupt_record_stops_replay(tmp_path):
    path = str(tmp_path / "j.log")
    j = RequestJournal(path, fsync=False)
    for i in range(3):
        j.append({"type": "submit", "uid": i})
    j.close()
    lines = open(path, "rb").read().splitlines(keepends=True)
    lines[1] = lines[1].replace(b'"uid": 1', b'"uid": 9')  # crc now wrong
    open(path, "wb").write(b"".join(lines))
    records, good = RequestJournal.replay(path)
    assert [r["uid"] for r in records] == [0]              # stops at damage
    assert good == len(lines[0])


# ---------------------------------------------------------------------------
# Snapshot validation + legacy layout
# ---------------------------------------------------------------------------

def test_load_snapshot_reports_missing_and_extra_keys(tmp_path):
    root = str(tmp_path)
    tree = {"version": 1,
            "a": {"__arr__": "snap/a"}, "b": {"__arr__": "snap/b"}}
    durable.write_generation(root, tree, {"snap/a": np.arange(3),
                                          "snap/zzz": np.arange(2)})
    with pytest.raises(RuntimeError) as ei:
        load_snapshot(root)
    msg = str(ei.value)
    assert "snap/b" in msg and "snap/zzz" in msg and "mismatch" in msg


def test_load_snapshot_rejects_non_snapshot_tree(tmp_path):
    root = str(tmp_path)
    durable.write_generation(root, {"not_a": "snapshot"}, {})
    with pytest.raises(RuntimeError, match="version"):
        load_snapshot(root)


def test_load_snapshot_legacy_layout(tmp_path):
    """The pre-PR-8 single-dir layout (arrays.npz + manifest.json) still
    loads; a truncated archive raises a clear error, not a zipfile one."""
    snap = {"version": 1, "x": np.arange(5, dtype=np.float32),
            "nested": {"y": np.ones((2, 2))}}
    d = tmp_path / "legacy"
    d.mkdir()
    arrays = {}
    tree = _split_arrays(snap, arrays, "snap")
    np.savez(str(d / "arrays.npz"), **arrays)
    with open(d / "manifest.json", "w") as f:
        json.dump(tree, f)
    back = load_snapshot(str(d))
    np.testing.assert_array_equal(back["x"], snap["x"])
    np.testing.assert_array_equal(back["nested"]["y"], snap["nested"]["y"])
    with open(d / "arrays.npz", "r+b") as f:
        f.truncate(10)
    with pytest.raises(RuntimeError, match="corrupt or truncated"):
        load_snapshot(str(d))


def test_save_snapshot_generations_accumulate(tmp_path):
    root = str(tmp_path / "snaps")
    save_snapshot(root, {"version": 1, "n": np.asarray([1])})
    save_snapshot(root, {"version": 1, "n": np.asarray([2])})
    assert durable.committed_generations(root) == [1, 2]
    assert int(load_snapshot(root)["n"][0]) == 2
    assert int(load_snapshot(root, generation=1)["n"][0]) == 1


# ---------------------------------------------------------------------------
# DurableScheduler: acknowledged work survives a kill
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen3_32b", "smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reqs(cfg, n, S, steps, temperature=0.0):
    toks = concrete_batch(cfg, n, S)["tokens"]
    key = jax.random.PRNGKey(11)
    return [Request(uid=u, inputs={"tokens": toks[u:u + 1]},
                    max_new_tokens=steps, key=jax.random.fold_in(key, u),
                    temperature=temperature)
            for u in range(n)]


def _kw(cache_len, **over):
    kw = dict(num_slots=2, cache_len=cache_len, paged=True,
              block_size=BLOCK, num_blocks=10, key=jax.random.PRNGKey(7))
    kw.update(over)
    return kw


def test_acknowledged_submit_survives_immediate_kill(served, tmp_path):
    """A submit is acknowledged once DurableScheduler.submit returns: a
    kill before ANY decode step (nothing in the snapshot but the empty
    boot generation) must still recover it from the journal alone."""
    cfg, model, params = served
    S, steps = 8, 4
    reqs = _reqs(cfg, 3, S, steps)
    ref = Scheduler(model, params, **_kw(S + steps + 2))
    for r in reqs:
        ref.submit(r)
    refout = ref.run()
    ref.allocator.assert_quiescent()

    root = str(tmp_path / "store")
    clk = {"t": 0.0}
    ds = DurableScheduler(
        Scheduler(model, params, clock=step_clock(clk), **_kw(S + steps + 2)),
        root)
    for r in reqs:
        ds.submit(r)
    ds.close()                            # kill -9: no step, no snapshot
    del ds

    rec = DurableScheduler.recover(root, model, params,
                                   clock=step_clock(clk))
    assert len(rec.queue) == 3
    while not rec.idle:
        clk["t"] += 1
        rec.step()
    rec.allocator.assert_quiescent()
    out = {f.uid: f for f in rec.finished}
    for u in range(3):
        np.testing.assert_array_equal(out[u].tokens, refout[u].tokens)
    rec.close()


def test_recovery_is_idempotent_after_drain(served, tmp_path):
    """Recovering a fully-drained store must replay retires without
    recomputing or duplicating them — the journaled results are
    authoritative."""
    cfg, model, params = served
    S, steps = 8, 4
    reqs = _reqs(cfg, 3, S, steps, temperature=0.5)
    root = str(tmp_path / "store")
    clk = {"t": 0.0}
    ds = DurableScheduler(
        Scheduler(model, params, clock=step_clock(clk), **_kw(S + steps + 2)),
        root, snapshot_every=2)
    for r in reqs:
        ds.submit(r)
    while not ds.idle:
        clk["t"] += 1
        ds.step()
    first = {f.uid: f.tokens.tolist() for f in ds.finished}
    ds.close()
    del ds

    rec = DurableScheduler.recover(root, model, params,
                                   clock=step_clock(clk))
    assert rec.idle
    again = {f.uid: f.tokens.tolist() for f in rec.finished}
    assert again == first                 # nothing lost, nothing doubled
    assert len(rec.finished) == 3
    rec.close()
