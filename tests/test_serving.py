"""Serving correctness: decode == teacher-forced prefill (the KV-cache /
SSM-state parity test), and the batched generate() engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build, get_config
from repro.configs.shapes import concrete_batch
from repro.serving.engine import generate

# Parity across attention families: dense GQA, local/global windowed,
# MLA+MoE, SSM, hybrid.
PARITY_ARCHS = ["qwen3_32b", "gemma3_4b", "deepseek_v2_lite_16b",
                "mamba2_2p7b", "jamba_v0_1_52b", "mixtral_8x7b"]


def _build(arch):
    cfg = get_config(arch, "smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_teacher_forced_prefill(arch):
    """prefill(tokens[:S]) then decode(tokens[S]) must produce the same
    logits as prefill(tokens[:S+1]) at the last position.  This is the
    strongest single test of cache layout, RoPE offsets, window masks,
    SSM state carries and MoE routing under decode."""
    cfg, model, params = _build(arch)
    B, S = 2, 12
    batch = concrete_batch(cfg, B, S + 1)
    toks = batch["tokens"]

    b_short = dict(batch, tokens=toks[:, :S], cache_len=S + 4)
    _, cache = model.prefill(params, b_short)
    logits_dec, _ = model.decode_step(params, cache, toks[:, S:S + 1])

    b_full = dict(batch, tokens=toks, cache_len=S + 4)
    logits_full, _ = model.prefill(params, b_full)

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", PARITY_ARCHS[:3])
def test_multi_step_decode_consistency(arch):
    """Three decode steps == teacher-forced prefill at each position."""
    cfg, model, params = _build(arch)
    B, S, K = 1, 8, 3
    batch = concrete_batch(cfg, B, S + K)
    toks = batch["tokens"]
    b0 = dict(batch, tokens=toks[:, :S], cache_len=S + K + 2)
    _, cache = model.prefill(params, b0)
    for k in range(K):
        logits, cache = model.decode_step(params, cache,
                                          toks[:, S + k:S + k + 1])
        bk = dict(batch, tokens=toks[:, :S + k + 2], cache_len=S + K + 2)
        ref, _ = model.prefill(params, dict(bk, tokens=toks[:, :S + k + 1]))
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(ref[:, -1], np.float32),
                                   rtol=3e-3, atol=3e-3)


def test_generate_greedy_deterministic():
    cfg, model, params = _build("deepseek_7b")
    batch = concrete_batch(cfg, 2, 8)
    batch = dict(batch, cache_len=8 + 6)
    r1 = generate(model, params, batch, steps=5, temperature=0.0)
    r2 = generate(model, params, batch, steps=5, temperature=0.0)
    assert r1.tokens.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(r1.tokens),
                                  np.asarray(r2.tokens))
    assert np.all(np.asarray(r1.tokens) >= 0)
    assert np.all(np.asarray(r1.tokens) < cfg.vocab_size)


def test_generate_greedy_matches_manual_loop():
    cfg, model, params = _build("deepseek_7b")
    batch = dict(concrete_batch(cfg, 1, 8), cache_len=8 + 4)
    res = generate(model, params, batch, steps=3, temperature=0.0)
    logits, cache = model.prefill(params, batch)
    toks = []
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    toks.append(tok)
    for _ in range(2):
        logits, cache = model.decode_step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        toks.append(tok)
    manual = jnp.concatenate(toks, axis=1)
    np.testing.assert_array_equal(np.asarray(res.tokens),
                                  np.asarray(manual))


def test_enc_dec_serving():
    """Seamless: cross-attention cache computed at prefill and reused."""
    cfg, model, params = _build("seamless_m4t_large_v2")
    B, S = 1, 8
    batch = dict(concrete_batch(cfg, B, S), cache_len=S + 4)
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, _ = model.decode_step(params, cache, tok)
    assert bool(jnp.all(jnp.isfinite(logits2)))
