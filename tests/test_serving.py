"""Serving correctness: decode == teacher-forced prefill (the KV-cache /
SSM-state parity test), the batched generate() engine, and the
continuous-batching scheduler (arrival/retirement order, slot reuse,
equivalence with sequential per-request decode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build, get_config
from repro.configs.shapes import concrete_batch
from repro.serving.engine import generate, generate_fixed
from repro.serving.scheduler import Request, Scheduler

# Parity across attention families: dense GQA, local/global windowed,
# MLA+MoE, SSM, hybrid.
PARITY_ARCHS = ["qwen3_32b", "gemma3_4b", "deepseek_v2_lite_16b",
                "mamba2_2p7b", "jamba_v0_1_52b", "mixtral_8x7b"]


def _build(arch):
    cfg = get_config(arch, "smoke")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_teacher_forced_prefill(arch):
    """prefill(tokens[:S]) then decode(tokens[S]) must produce the same
    logits as prefill(tokens[:S+1]) at the last position.  This is the
    strongest single test of cache layout, RoPE offsets, window masks,
    SSM state carries and MoE routing under decode."""
    cfg, model, params = _build(arch)
    B, S = 2, 12
    batch = concrete_batch(cfg, B, S + 1)
    toks = batch["tokens"]

    b_short = dict(batch, tokens=toks[:, :S], cache_len=S + 4)
    _, cache = model.prefill(params, b_short)
    logits_dec, _ = model.decode_step(params, cache, toks[:, S:S + 1])

    b_full = dict(batch, tokens=toks, cache_len=S + 4)
    logits_full, _ = model.prefill(params, b_full)

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", PARITY_ARCHS[:3])
def test_multi_step_decode_consistency(arch):
    """Three decode steps == teacher-forced prefill at each position."""
    cfg, model, params = _build(arch)
    B, S, K = 1, 8, 3
    batch = concrete_batch(cfg, B, S + K)
    toks = batch["tokens"]
    b0 = dict(batch, tokens=toks[:, :S], cache_len=S + K + 2)
    _, cache = model.prefill(params, b0)
    for k in range(K):
        logits, cache = model.decode_step(params, cache,
                                          toks[:, S + k:S + k + 1])
        bk = dict(batch, tokens=toks[:, :S + k + 2], cache_len=S + K + 2)
        ref, _ = model.prefill(params, dict(bk, tokens=toks[:, :S + k + 1]))
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(ref[:, -1], np.float32),
                                   rtol=3e-3, atol=3e-3)


def test_generate_greedy_deterministic():
    cfg, model, params = _build("deepseek_7b")
    batch = concrete_batch(cfg, 2, 8)
    batch = dict(batch, cache_len=8 + 6)
    r1 = generate(model, params, batch, steps=5, temperature=0.0)
    r2 = generate(model, params, batch, steps=5, temperature=0.0)
    assert r1.tokens.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(r1.tokens),
                                  np.asarray(r2.tokens))
    assert np.all(np.asarray(r1.tokens) >= 0)
    assert np.all(np.asarray(r1.tokens) < cfg.vocab_size)


def test_generate_greedy_matches_manual_loop():
    cfg, model, params = _build("deepseek_7b")
    batch = dict(concrete_batch(cfg, 1, 8), cache_len=8 + 4)
    res = generate(model, params, batch, steps=3, temperature=0.0)
    logits, cache = model.prefill(params, batch)
    toks = []
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    toks.append(tok)
    for _ in range(2):
        logits, cache = model.decode_step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        toks.append(tok)
    manual = jnp.concatenate(toks, axis=1)
    np.testing.assert_array_equal(np.asarray(res.tokens),
                                  np.asarray(manual))


def test_generate_steps_zero():
    """steps=0 must return empty [B, 0] results, not crash in jnp.stack."""
    cfg, model, params = _build("deepseek_7b")
    batch = dict(concrete_batch(cfg, 2, 8), cache_len=8 + 4)
    for fn in (generate, generate_fixed):
        res = fn(model, params, batch, steps=0)
        assert res.tokens.shape == (2, 0)
        assert res.logprobs.shape == (2, 0)


def test_generate_greedy_key_independent():
    """Greedy decoding must not consume PRNG splits: the result is the
    same whatever key is passed (and the key stream stays reserved for
    actual sampling)."""
    cfg, model, params = _build("deepseek_7b")
    batch = dict(concrete_batch(cfg, 2, 8), cache_len=8 + 5)
    for fn in (generate, generate_fixed):
        r1 = fn(model, params, batch, steps=4, temperature=0.0,
                key=jax.random.PRNGKey(1))
        r2 = fn(model, params, batch, steps=4, temperature=0.0,
                key=jax.random.PRNGKey(42))
        np.testing.assert_array_equal(np.asarray(r1.tokens),
                                      np.asarray(r2.tokens))


# ---------------------------------------------------------------------------
# Continuous-batching scheduler
# ---------------------------------------------------------------------------

def _sequential_reference(model, params, toks_row, steps, cache_len):
    """Per-request greedy decode, one request alone in the batch — the
    ground truth the scheduler must reproduce token-for-token."""
    res = generate_fixed(model, params,
                         {"tokens": toks_row[None], "cache_len": cache_len},
                         steps=steps)
    return np.asarray(res.tokens)[0], np.asarray(res.logprobs)[0]


def test_scheduler_staggered_equals_sequential():
    """3 requests with staggered arrivals and mixed budgets (so admission
    and retirement interleave) through a 2-slot pool must match sequential
    per-request greedy decode token-for-token."""
    cfg, model, params = _build("deepseek_7b")
    S, cache_len = 8, 8 + 8
    budgets = [6, 3, 5]
    toks = concrete_batch(cfg, 3, S)["tokens"]

    sched = Scheduler(model, params, num_slots=2, cache_len=cache_len)
    sched.submit(Request(uid=0, inputs={"tokens": toks[0:1]},
                         max_new_tokens=budgets[0]))
    sched.step()                               # r0 admitted + 1 decode step
    sched.submit(Request(uid=1, inputs={"tokens": toks[1:2]},
                         max_new_tokens=budgets[1]))
    sched.step()                               # r1 joins mid-flight
    sched.submit(Request(uid=2, inputs={"tokens": toks[2:3]},
                         max_new_tokens=budgets[2]))  # queues until a slot frees
    out = dict(sched.run())
    for f in sched.finished:
        out[f.uid] = f

    assert sorted(out) == [0, 1, 2]
    for uid in range(3):
        ref_toks, ref_lps = _sequential_reference(
            model, params, toks[uid], budgets[uid], cache_len)
        np.testing.assert_array_equal(out[uid].tokens, ref_toks)
        np.testing.assert_allclose(out[uid].logprobs, ref_lps,
                                   rtol=1e-5, atol=1e-5)
        assert out[uid].finish_reason == "length"


@pytest.mark.parametrize("arch", ["gemma3_4b", "deepseek_v2_lite_16b",
                                  "mamba2_2p7b", "jamba_v0_1_52b"])
def test_scheduler_staggered_across_families(arch):
    """The per-slot vector-pos decode branches (windowed ring GQA, MLA
    one-hot writes, SSM state, hybrid periods) with slots at *different*
    depths: a request admitted two steps late must still match its
    sequential reference.  (MoE routing is batch-coupled in general, but
    smoke capacities never drop tokens, so equality is exact here too.)"""
    cfg, model, params = _build(arch)
    S, cache_len = 8, 8 + 8
    budgets = [5, 3]
    toks = concrete_batch(cfg, 2, S)["tokens"]
    sched = Scheduler(model, params, num_slots=2, cache_len=cache_len)
    sched.submit(Request(uid=0, inputs={"tokens": toks[0:1]},
                         max_new_tokens=budgets[0]))
    sched.step()
    sched.step()                      # slot 0 is two tokens deep …
    sched.submit(Request(uid=1, inputs={"tokens": toks[1:2]},
                         max_new_tokens=budgets[1]))  # … when slot 1 joins
    out = dict(sched.run())
    for f in sched.finished:
        out[f.uid] = f
    for uid in range(2):
        ref, _ = _sequential_reference(model, params, toks[uid],
                                       budgets[uid], cache_len)
        np.testing.assert_array_equal(out[uid].tokens, ref)


def test_scheduler_slot_reuse_after_eos():
    """A request retiring on EOS frees its slot for a queued request; the
    late request's output is unaffected by what previously occupied the
    slot."""
    cfg, model, params = _build("deepseek_7b")
    S, cache_len, steps = 8, 8 + 8, 6
    toks = concrete_batch(cfg, 3, S)["tokens"]
    # pick an eos that greedy decode of request 0 emits mid-stream
    ref0, _ = _sequential_reference(model, params, toks[0], steps, cache_len)
    eos = int(ref0[1])

    sched = Scheduler(model, params, num_slots=1, cache_len=cache_len,
                      eos_id=eos)
    for uid in range(3):
        sched.submit(Request(uid=uid, inputs={"tokens": toks[uid:uid + 1]},
                             max_new_tokens=steps))
    out = sched.run()
    assert sorted(out) == [0, 1, 2]
    cut = list(ref0).index(eos) + 1
    np.testing.assert_array_equal(out[0].tokens, ref0[:cut])
    assert out[0].finish_reason == "eos"
    for uid in (1, 2):
        ref, _ = _sequential_reference(model, params, toks[uid], steps,
                                       cache_len)
        stop = (list(ref).index(eos) + 1) if eos in ref else steps
        np.testing.assert_array_equal(out[uid].tokens, ref[:stop])


def test_scheduler_single_slot_and_zero_budget():
    cfg, model, params = _build("deepseek_7b")
    S, cache_len = 8, 8 + 6
    toks = concrete_batch(cfg, 2, S)["tokens"]
    sched = Scheduler(model, params, num_slots=1, cache_len=cache_len)
    sched.submit(Request(uid=0, inputs={"tokens": toks[0:1]},
                         max_new_tokens=0))
    sched.submit(Request(uid=1, inputs={"tokens": toks[1:2]},
                         max_new_tokens=4))
    out = sched.run()
    assert out[0].tokens.shape == (0,)
    assert out[0].finish_reason == "length"
    ref, _ = _sequential_reference(model, params, toks[1], 4, cache_len)
    np.testing.assert_array_equal(out[1].tokens, ref)
    # over-budget submissions are rejected up front
    with pytest.raises(ValueError):
        sched.submit(Request(uid=9, inputs={"tokens": toks[0:1]},
                             max_new_tokens=cache_len))


def test_scheduler_starved_pool_raises_not_hangs():
    """Zero admittable slots with a non-empty queue: the head's block
    reservation can fit the pool eventually (so submit accepts it) but
    admission is gated; a step making no progress at all with nothing
    active must raise rather than spin forever."""
    from repro.serving.paging import logical_blocks

    cfg, model, params = _build("deepseek_7b")
    S, cache_len, steps = 8, 16, 4
    toks = concrete_batch(cfg, 1, S)["tokens"]
    need = logical_blocks(S + steps, 4)
    sched = Scheduler(model, params, num_slots=1, cache_len=cache_len,
                      paged=True, block_size=4, num_blocks=need,
                      prefix_cache=False)
    sched.submit(Request(uid=0, inputs={"tokens": toks},
                         max_new_tokens=steps))
    # simulate exhaustion that never clears: a leaked external reference
    held = [sched.allocator.alloc() for _ in range(need)]
    with pytest.raises(RuntimeError, match="no progress"):
        sched.run()
    for b in held:
        sched.allocator.decref(b)
    assert sched.run()[0].finish_reason == "length"  # recovers once freed
    sched.allocator.assert_quiescent()


def test_scheduler_cancel_while_queued():
    """cancel() of a request that never reached a slot retires it with
    zero tokens and reason "cancelled"; the rest of the queue drains
    normally."""
    cfg, model, params = _build("deepseek_7b")
    S, cache_len = 8, 8 + 6
    toks = concrete_batch(cfg, 3, S)["tokens"]
    sched = Scheduler(model, params, num_slots=1, cache_len=cache_len)
    for uid in range(3):
        sched.submit(Request(uid=uid, inputs={"tokens": toks[uid:uid + 1]},
                             max_new_tokens=4))
    sched.step()                          # uid 0 active; 1, 2 queued
    assert sched.cancel(1)
    assert not sched.cancel(99)           # unknown uid
    sched.run()
    out = {f.uid: f for f in sched.finished}
    assert out[1].finish_reason == "cancelled"
    assert out[1].tokens.shape == (0,)
    assert out[0].finish_reason == out[2].finish_reason == "length"
    ref, _ = _sequential_reference(model, params, toks[2], 4, cache_len)
    np.testing.assert_array_equal(out[2].tokens, ref)


def test_scheduler_deadline_expires_before_prefill():
    """A queued request whose TTL lapses while it waits for a slot is
    retired with zero tokens — the deadline check runs before admission,
    so no prefill compute (or block allocation) is ever spent on it."""
    cfg, model, params = _build("deepseek_7b")
    S, cache_len = 8, 16
    toks = concrete_batch(cfg, 2, S)["tokens"]
    clk = {"t": 0.0}
    sched = Scheduler(model, params, num_slots=1, cache_len=cache_len,
                      paged=True, block_size=4, clock=lambda: clk["t"])
    sched.submit(Request(uid=0, inputs={"tokens": toks[0:1]},
                         max_new_tokens=6))
    sched.submit(Request(uid=1, inputs={"tokens": toks[1:2]},
                         max_new_tokens=6, deadline_s=2.0))
    while not sched.idle:
        clk["t"] += 1.0                   # uid 1's TTL lapses in the queue
        sched.step()
    out = {f.uid: f for f in sched.finished}
    assert out[1].finish_reason == "deadline"
    assert out[1].tokens.shape == (0,)
    assert out[0].finish_reason == "length"
    assert sched.expired == 1
    sched.allocator.assert_quiescent()


def test_scheduler_resize_smaller_while_busy():
    """resize() below the live slot/block footprint defers: nothing is
    dropped, admission respects the new limits immediately, the arrays
    shrink once the tail drains, and outputs match the reference."""
    cfg, model, params = _build("deepseek_7b")
    S, cache_len, steps = 8, 16, 5
    toks = concrete_batch(cfg, 3, S)["tokens"]

    def submit_all(s):
        for uid in range(3):
            s.submit(Request(uid=uid, inputs={"tokens": toks[uid:uid + 1]},
                             max_new_tokens=steps))

    ref = Scheduler(model, params, num_slots=3, cache_len=cache_len,
                    paged=True, block_size=4, num_blocks=12)
    submit_all(ref)
    refout = ref.run()

    sched = Scheduler(model, params, num_slots=3, cache_len=cache_len,
                      paged=True, block_size=4, num_blocks=12)
    submit_all(sched)
    sched.step()                          # all three slots busy
    assert sched.num_active == 3
    geo = sched.resize(num_slots=1, num_blocks=4)
    assert geo["pending_slots"] == 1 and geo["pending_blocks"] == 4
    assert sched.num_slots == 3           # deferred, nothing dropped
    out = sched.run()
    assert sched.num_slots == 1 and sched.num_blocks == 4
    assert sched.cache["block_tables"].shape[0] == 1
    sched.allocator.assert_quiescent()
    for uid in range(3):
        np.testing.assert_array_equal(out[uid].tokens, refout[uid].tokens)


def test_jit_cache_lru_bounded():
    """Distinct cache_len values must not grow Model._jit_cache without
    bound (a long-running server leaks traces otherwise); hot entries
    survive churn."""
    cfg, model, params = _build("deepseek_7b")
    model.jit_cache_size = 4
    model.jitted_decode_step()
    for L in range(12, 24):
        model.jitted_prefill(L)
        model.jitted_decode_step()            # keep the hot entry fresh
    assert len(model._jit_cache) <= 4
    assert "decode_step" in model._jit_cache


def test_enc_dec_serving():
    """Seamless: cross-attention cache computed at prefill and reused."""
    cfg, model, params = _build("seamless_m4t_large_v2")
    B, S = 1, 8
    batch = dict(concrete_batch(cfg, B, S), cache_len=S + 4)
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, _ = model.decode_step(params, cache, tok)
    assert bool(jnp.all(jnp.isfinite(logits2)))


# ---------------------------------------------------------------------------
# Quantized TT models through the serving stack (DESIGN.md §8)
# ---------------------------------------------------------------------------

def _build_tt(weights: str):
    from repro.configs.base import TTConfig
    cfg = get_config(
        "deepseek_7b", "smoke",
        tt=TTConfig(enabled=True, families=("ffn",), rank=4, min_factor=2,
                    backend="auto", weights=weights))
    return cfg, build(cfg)


def test_quantized_model_serves_prefill_and_decode():
    """int8-quantized params are a drop-in tree for the same Model: the
    prefill/decode logits stay within the chain error budget of fp32, and
    stored quantization agrees bit-exactly with the on-the-fly ':int8'
    backend suffix (same quantization grid)."""
    cfg_fp, model_fp = _build_tt("fp32")
    cfg_q, model_q = _build_tt("int8")
    params = model_fp.init(jax.random.PRNGKey(0))
    qparams = model_q.quantize_params(params)
    batch = dict(concrete_batch(cfg_fp, 2, 8), cache_len=8 + 4)

    lg_fp, _ = model_fp.prefill(params, batch)
    lg_q, cache = model_q.prefill(qparams, batch)
    rel = float(jnp.linalg.norm(lg_q - lg_fp) / jnp.linalg.norm(lg_fp))
    assert 0 < rel < 5e-2, rel

    # stored int8 == on-the-fly quantization of the float cores
    lg_fly, _ = model_q.prefill(params, batch)
    np.testing.assert_array_equal(np.asarray(lg_q), np.asarray(lg_fly))

    tok = jnp.argmax(lg_q[:, -1], -1).astype(jnp.int32)[:, None]
    lg_d, _ = model_q.decode_step(qparams, cache, tok)
    assert bool(jnp.all(jnp.isfinite(lg_d)))


def test_quantize_params_is_idempotent():
    """Re-quantizing an already-quantized tree must be a no-op — deriving
    fresh scales from the int8 codes would silently drop the real ones."""
    _, model = _build_tt("int8")
    params = model.init(jax.random.PRNGKey(0))
    q1 = model.quantize_params(params)
    q2 = model.quantize_params(q1)
    flat1 = jax.tree.leaves(q1)
    flat2 = jax.tree.leaves(q2)
    assert len(flat1) == len(flat2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantized_moe_expert_cores_serve():
    """MoE expert FFN cores are stacked [layers, experts, r0, n, m, r1]:
    quantization must peel every leading stack axis (per-layer AND
    per-expert scales) and still serve prefill + decode."""
    from repro.configs.base import TTConfig
    cfg = get_config(
        "mixtral_8x7b", "smoke",
        tt=TTConfig(enabled=True, families=("ffn",), rank=4, min_factor=2,
                    backend="auto", weights="int8"))
    model = build(cfg)
    qparams = model.quantize_params(model.init(jax.random.PRNGKey(0)))
    int8_ndims = []

    def walk(node):
        if isinstance(node, dict):
            for v in node.values():
                walk(v)
        elif hasattr(node, "dtype") and node.dtype == jnp.int8:
            int8_ndims.append(node.ndim)

    walk(qparams)
    assert int8_ndims and max(int8_ndims) == 6   # layers x experts x core
    batch = dict(concrete_batch(cfg, 2, 8), cache_len=8 + 4)
    lg, cache = model.prefill(qparams, batch)
    assert bool(jnp.all(jnp.isfinite(lg)))
    tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
    lg2, _ = model.decode_step(qparams, cache, tok)
    assert bool(jnp.all(jnp.isfinite(lg2)))


def test_scheduler_serves_quantized_model():
    """The continuous-batching scheduler accepts a quantized param tree:
    scheduler output is token-identical to the fixed-batch loop on the
    same quantized params (the scheduler determinism contract is dtype-
    independent)."""
    cfg, model = _build_tt("int8")
    params = model.quantize_params(model.init(jax.random.PRNGKey(0)))
    batch = dict(concrete_batch(cfg, 3, 8), cache_len=8 + 5)
    r_sched = generate(model, params, batch, steps=4, temperature=0.0)
    r_fixed = generate_fixed(model, params, batch, steps=4, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(r_sched.tokens),
                                  np.asarray(r_fixed.tokens))
    assert r_sched.tokens.shape == (3, 4)
