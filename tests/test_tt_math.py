"""TT core math: decompose / reconstruct / apply round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flops import prod
from repro.core.tt import (TTPlan, make_plan, tt_apply, tt_apply_chain,
                           tt_decompose, tt_init, tt_reconstruct)


def test_make_plan_scalar_rank_clipping():
    p = make_plan((4, 3), (2, 4), 999)
    assert p.ranks == (1, 8, 1)          # min(4·2, 3·4) = 8
    p2 = make_plan((8, 8, 8), (8, 8, 8), 16)
    assert p2.ranks == (1, 16, 16, 1)


def test_decompose_full_rank_exact():
    """TT-SVD at max feasible rank reconstructs W exactly."""
    rng = np.random.default_rng(0)
    plan = make_plan((4, 3), (2, 4), 8)          # full rank at the only cut
    W = rng.standard_normal((plan.M, plan.N)).astype(np.float32)
    cores = tt_decompose(W, plan)
    W2 = np.asarray(tt_reconstruct([jnp.asarray(c) for c in cores]))
    np.testing.assert_allclose(W2, W, rtol=1e-4, atol=1e-4)


def test_decompose_d3_full_rank_exact():
    rng = np.random.default_rng(1)
    plan = make_plan((4, 2, 2), (2, 2, 3), 100)  # clipped to feasible max
    W = rng.standard_normal((plan.M, plan.N)).astype(np.float32)
    cores = tt_decompose(W, plan)
    W2 = np.asarray(tt_reconstruct([jnp.asarray(c) for c in cores]))
    np.testing.assert_allclose(W2, W, rtol=1e-4, atol=1e-4)


def test_truncated_rank_reduces_error_monotonically():
    """Higher TT rank → no worse reconstruction (SVD truncation)."""
    rng = np.random.default_rng(2)
    W = rng.standard_normal((12, 8)).astype(np.float32)
    errs = []
    for r in (1, 2, 4, 8):
        plan = make_plan((4, 3), (2, 4), r)
        cores = tt_decompose(W, plan)
        W2 = np.asarray(tt_reconstruct([jnp.asarray(c) for c in cores]))
        errs.append(float(np.linalg.norm(W2 - W)))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 1e-3


def test_apply_matches_dense_matvec():
    """tt_apply(cores, x) == x @ W.T for exactly-decomposed W (y = Wx)."""
    rng = np.random.default_rng(3)
    plan = make_plan((4, 3), (2, 4), 8)
    W = rng.standard_normal((plan.M, plan.N)).astype(np.float32)
    cores = [jnp.asarray(c) for c in tt_decompose(W, plan)]
    x = jnp.asarray(rng.standard_normal((5, plan.N)).astype(np.float32))
    y = tt_apply(cores, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ W.T,
                               rtol=2e-4, atol=2e-4)


def test_apply_chain_matches_reconstructed_dense():
    """For random cores (not from SVD) the chain must equal the dense
    product with the reconstructed W — validates the Listing-1 execution
    order and the final [m, b] → [b, m] layout fix."""
    key = jax.random.PRNGKey(0)
    plan = make_plan((5, 3, 2), (2, 3, 4), 4)
    cores = tt_init(key, plan)
    W = tt_reconstruct(cores)
    x = jax.random.normal(jax.random.PRNGKey(1), (7, plan.N))
    y = tt_apply_chain(cores, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ W.T),
                               rtol=1e-4, atol=1e-4)


def test_apply_bias_and_leading_dims():
    key = jax.random.PRNGKey(0)
    plan = make_plan((4, 3), (2, 4), 4)
    cores = tt_init(key, plan)
    bias = jnp.arange(plan.M, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, plan.N))
    y = tt_apply(cores, x, bias)
    assert y.shape == (2, 3, plan.M)
    y0 = tt_apply(cores, x.reshape(-1, plan.N))
    np.testing.assert_allclose(np.asarray(y.reshape(-1, plan.M)),
                               np.asarray(y0 + bias), rtol=1e-5, atol=1e-5)


def test_init_variance_targets_glorot():
    """tt_init: reconstructed dense W has elementwise std ≈ sqrt(2/(M+N))."""
    key = jax.random.PRNGKey(42)
    plan = make_plan((16, 8), (8, 16), 8)
    cores = tt_init(key, plan)
    W = np.asarray(tt_reconstruct(cores))
    target = np.sqrt(2.0 / (plan.M + plan.N))
    assert 0.4 * target < W.std() < 2.5 * target


def test_plan_properties():
    plan = make_plan((100, 10), (32, 64), 8)
    assert plan.M == 1000 and plan.N == 2048 and plan.d == 2
    assert plan.compression > 50
    assert "TT[" in plan.describe()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_apply_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    plan = make_plan((4, 3), (2, 4), 4)
    cores = [c.astype(dtype) for c in tt_init(key, plan)]
    x = jax.random.normal(jax.random.PRNGKey(1), (3, plan.N)).astype(dtype)
    y = tt_apply(cores, x)
    assert y.dtype == dtype
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))


def test_batched_chain_matches_paper_chain():
    """tt_apply_batched (token axis kept leading, SPMD-friendly) must equal
    the paper-faithful folded chain exactly — same contraction, different
    loop nesting (EXPERIMENTS §Perf it. 3)."""
    from repro.core.tt import tt_apply_batched
    key = jax.random.PRNGKey(0)
    for ms, ns, r in [((4, 3), (2, 4), 4), ((5, 3, 2), (2, 3, 4), 4),
                      ((8, 4, 2, 2), (2, 2, 4, 4), 3), ((12,), (18,), 1)]:
        plan = make_plan(ms, ns, r)
        cores = tt_init(jax.random.fold_in(key, plan.M), plan)
        x = jax.random.normal(jax.random.fold_in(key, 1), (6, plan.N))
        np.testing.assert_allclose(
            np.asarray(tt_apply_batched(cores, x)),
            np.asarray(tt_apply_chain(cores, x)), rtol=1e-5, atol=1e-5)
