"""int8 TT cores: size, error bounds, end-to-end drift, round-trip
properties (hypothesis) and the all-zero-core guard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import (chain_error_bound, dequantize_cores,
                              quantize_core, quantize_cores,
                              quantized_bytes, roundtrip_bound,
                              tt_apply_int8)
from repro.core.tt import make_plan, tt_apply, tt_init


def _setup(ms, ns, r, seed=0):
    plan = make_plan(ms, ns, r)
    cores = tt_init(jax.random.PRNGKey(seed), plan)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, plan.N))
    return plan, cores, x


def test_quantize_roundtrip_error():
    plan, cores, _ = _setup((16, 8), (8, 16), 8)
    qs, ss = quantize_cores(cores)
    deq = dequantize_cores(qs, ss, jnp.float32)
    for G, D, s in zip(cores, deq, ss):
        assert np.abs(np.asarray(D - G)).max() <= float(s) * 0.5 + 1e-7


def test_memory_is_quarter_of_fp32():
    plan, cores, _ = _setup((16, 8), (8, 16), 8)
    qs, ss = quantize_cores(cores)
    fp32 = sum(4 * G.size for G in cores)
    assert quantized_bytes(qs, ss) < fp32 / 3.5


def test_end_to_end_output_drift_small():
    """int8 chain output within ~1% relative of the fp32 chain, across
    chain lengths (error grows ~linearly in d)."""
    for ms, ns, r in [((16, 8), (8, 16), 8), ((8, 4, 4), (4, 4, 8), 4),
                      ((8, 4, 2, 2), (2, 2, 4, 8), 4)]:
        plan, cores, x = _setup(ms, ns, r, seed=plan_seed(ms))
        y = tt_apply(cores, x)
        qs, ss = quantize_cores(cores)
        yq = tt_apply_int8(qs, ss, x)
        rel = float(jnp.linalg.norm(yq - y) / (jnp.linalg.norm(y) + 1e-9))
        assert rel < 0.015 * len(ms), (ms, rel)


def plan_seed(ms):
    return sum(ms)


def test_int8_cores_dtype_and_bias():
    plan, cores, x = _setup((16, 8), (8, 16), 8)
    qs, ss = quantize_cores(cores)
    assert all(q.dtype == jnp.int8 for q in qs)
    bias = jnp.ones((plan.M,))
    y = tt_apply_int8(qs, ss, x, bias)
    y0 = tt_apply_int8(qs, ss, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0) + 1.0,
                               rtol=1e-5, atol=1e-5)


def test_all_zero_core_roundtrips_to_exact_zeros():
    """The guard against epsilon scales: a zero core must quantize with a
    finite O(1) scale and round-trip to EXACT zeros — no denormal noise,
    and a zero chain output stays exactly zero."""
    G = jnp.zeros((2, 4, 4, 2))
    q, s = quantize_core(G)
    assert float(s) == 1.0
    assert np.all(np.asarray(q) == 0)
    deq, = dequantize_cores([q], [s], jnp.float32)
    assert np.all(np.asarray(deq) == 0.0)
    # end-to-end: a chain containing a zero core outputs exact zeros
    plan, cores, x = _setup((8, 4), (4, 8), 4)
    cores = [cores[0], jnp.zeros_like(cores[1])]
    qs, ss = quantize_cores(cores)
    y = tt_apply_int8(qs, ss, x)
    assert np.all(np.asarray(y) == 0.0)
    assert np.isfinite(np.asarray(ss, np.float32)).all()


def test_roundtrip_bound_holds():
    plan, cores, _ = _setup((16, 8), (8, 16), 8)
    for G in cores:
        q, s = quantize_core(G)
        deq, = dequantize_cores([q], [s], jnp.float32)
        err = float(jnp.max(jnp.abs(deq - G)))
        assert err <= float(roundtrip_bound(G)) + 1e-7


# ---------------------------------------------------------------------------
# Round-trip / chain-growth properties on a deterministic grid; the same
# properties run under hypothesis search in tests/test_quant_props.py
# ---------------------------------------------------------------------------

def check_roundtrip_property(ms, ns, rank, seed, mag):
    """∀ cores (any magnitude): per-element round-trip error ≤ scale/2."""
    plan = make_plan(ms, ns, rank)
    cores = [c * mag for c in tt_init(jax.random.PRNGKey(seed), plan)]
    for G in cores:
        q, s = quantize_core(G)
        deq, = dequantize_cores([q], [s], jnp.float32)
        err = float(jnp.max(jnp.abs(deq - G)))
        assert err <= float(s) * 0.5 * (1 + 1e-6) + 1e-12


def check_chain_error_growth(ms, ns, rank, seed, mag):
    """Measured relative chain error stays below the first-order bound
    ``chain_error_bound`` (which grows ~linearly in d) — the property the
    DSE error proxy and the 5e-2 serving budget rely on."""
    plan = make_plan(ms, ns, rank)
    cores = [c * mag for c in tt_init(jax.random.PRNGKey(seed), plan)]
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, plan.N))
    y = tt_apply(cores, x)
    qs, ss = quantize_cores(cores)
    yq = tt_apply_int8(qs, ss, x.astype(jnp.float32))
    denom = float(jnp.linalg.norm(y))
    if denom == 0.0:
        assert float(jnp.linalg.norm(yq)) == 0.0
        return
    rel = float(jnp.linalg.norm(yq - y)) / denom
    bound = chain_error_bound(cores)
    assert rel <= bound + 1e-6, (rel, bound, ms, ns, rank)
    # and the bound itself certifies linear-in-d growth at this rank/shape
    assert bound <= len(ms) * (np.sqrt(max(G.size for G in cores)) / 254.0
                               + 1e-6) * 1.01


GRID = [
    ((16, 8), (8, 16), 8), ((8, 4, 4), (4, 4, 8), 4),
    ((2, 2, 2), (8, 8, 8), 2), ((8, 4, 2, 2), (2, 2, 4, 8), 4),
    ((4, 4, 4, 4), (4, 4, 4, 4), 8),
]


@pytest.mark.parametrize("ms,ns,rank", GRID)
@pytest.mark.parametrize("mag", [1e-3, 1.0, 1e3])
def test_roundtrip_property_grid(ms, ns, rank, mag):
    check_roundtrip_property(ms, ns, rank, seed=sum(ms), mag=mag)


@pytest.mark.parametrize("ms,ns,rank", GRID)
@pytest.mark.parametrize("mag", [1e-3, 1.0, 1e3])
def test_chain_error_growth_bounded_in_d_grid(ms, ns, rank, mag):
    check_chain_error_growth(ms, ns, rank, seed=sum(ns), mag=mag)
