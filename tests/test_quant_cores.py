"""int8 TT cores: size, error bounds, end-to-end drift."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import (dequantize_cores, quantize_cores,
                              quantized_bytes, tt_apply_int8)
from repro.core.tt import make_plan, tt_apply, tt_init


def _setup(ms, ns, r, seed=0):
    plan = make_plan(ms, ns, r)
    cores = tt_init(jax.random.PRNGKey(seed), plan)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, plan.N))
    return plan, cores, x


def test_quantize_roundtrip_error():
    plan, cores, _ = _setup((16, 8), (8, 16), 8)
    qs, ss = quantize_cores(cores)
    deq = dequantize_cores(qs, ss, jnp.float32)
    for G, D, s in zip(cores, deq, ss):
        assert np.abs(np.asarray(D - G)).max() <= float(s) * 0.5 + 1e-7


def test_memory_is_quarter_of_fp32():
    plan, cores, _ = _setup((16, 8), (8, 16), 8)
    qs, ss = quantize_cores(cores)
    fp32 = sum(4 * G.size for G in cores)
    assert quantized_bytes(qs, ss) < fp32 / 3.5


def test_end_to_end_output_drift_small():
    """int8 chain output within ~1% relative of the fp32 chain, across
    chain lengths (error grows ~linearly in d)."""
    for ms, ns, r in [((16, 8), (8, 16), 8), ((8, 4, 4), (4, 4, 8), 4),
                      ((8, 4, 2, 2), (2, 2, 4, 8), 4)]:
        plan, cores, x = _setup(ms, ns, r, seed=plan_seed(ms))
        y = tt_apply(cores, x)
        qs, ss = quantize_cores(cores)
        yq = tt_apply_int8(qs, ss, x)
        rel = float(jnp.linalg.norm(yq - y) / (jnp.linalg.norm(y) + 1e-9))
        assert rel < 0.015 * len(ms), (ms, rel)


def plan_seed(ms):
    return sum(ms)


def test_int8_cores_dtype_and_bias():
    plan, cores, x = _setup((16, 8), (8, 16), 8)
    qs, ss = quantize_cores(cores)
    assert all(q.dtype == jnp.int8 for q in qs)
    bias = jnp.ones((plan.M,))
    y = tt_apply_int8(qs, ss, x, bias)
    y0 = tt_apply_int8(qs, ss, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0) + 1.0,
                               rtol=1e-5, atol=1e-5)
