"""Fused arbitrary-depth chain kernel + measured block-plan autotuner.

Sweeps ``tt_fused_chain_pallas`` (d ∈ {2, 3, 4}, odd/non-pow2 factor
shapes, bf16 and fp32, batches that do not divide the tile) against the
``tt_apply`` XLA reference; asserts the ``auto`` backend dispatches
VMEM-resident d≥3 chains to a SINGLE pallas_call; and round-trips the
autotuner's JSON cache (second lookup must not re-time)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import (chain_state_sizes, chain_weight_elems,
                                fused_chain_batch_tile, pack_core,
                                select_blocks_candidates)
from repro.core.tt import make_plan, tt_apply, tt_init
from repro.kernels import autotune, tt_contract
from repro.kernels.ops import chain_dims, tt_forward
from repro.kernels.tt_contract import tt_fused_chain_pallas

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _cores_and_x(ms, ns, rank, B, dtype):
    plan = make_plan(ms, ns, rank)
    cores = [c.astype(dtype) for c in tt_init(KEY, plan)]
    x = _rand(jax.random.PRNGKey(7), (B, plan.N), dtype)
    return plan, cores, x


# (ms, ns, rank, B) — d 2–4, odd / non-pow2 factors, ragged batches
CHAIN_CASES = [
    ((16, 8), (4, 16), 8, 33),          # d=2, B % tile != 0
    ((10, 5), (5, 10), 4, 7),           # d=2 odd factors, tiny batch
    ((8, 4, 4), (4, 4, 8), 4, 19),      # d=3, ragged batch
    ((9, 5, 7), (3, 7, 5), 4, 12),      # d=3 all-odd factors
    ((4, 4, 4, 2), (2, 4, 4, 4), 4, 21),  # d=4, ragged batch
    ((6, 3, 5, 2), (2, 5, 3, 6), 3, 10),  # d=4 non-pow2 everything
]


@pytest.mark.parametrize("ms,ns,rank,B", CHAIN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_chain_vs_tt_apply(ms, ns, rank, B, dtype):
    plan, cores, x = _cores_and_x(ms, ns, rank, B, dtype)
    packed = [pack_core(G) for G in reversed(cores)]
    got = tt_fused_chain_pallas(x, packed, (plan.ns, plan.ms, plan.ranks),
                                block_b=8, interpret=True)
    want = tt_apply([c.astype(jnp.float32) for c in cores],
                    x.astype(jnp.float32))
    assert got.shape == (B, plan.M)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("backend", ["pallas_step", "pallas_fused", "auto"])
@pytest.mark.parametrize("ms,ns,rank,B",
                         [((8, 4, 4), (4, 4, 8), 4, 13),
                          ((4, 4, 4, 2), (2, 4, 4, 4), 4, 9)])
def test_tt_forward_deep_backends_agree(backend, ms, ns, rank, B):
    plan, cores, x = _cores_and_x(ms, ns, rank, B, jnp.float32)
    base = tt_forward(cores, x, backend="xla")
    got = tt_forward(cores, x, backend=backend, interpret=True, tune="off")
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-4, atol=1e-4)


def test_auto_dispatches_d3_to_single_fused_launch():
    """The acceptance bar: backend='auto' on a VMEM-resident d=3 chain must
    issue exactly ONE pallas_call (no per-step HBM intermediates)."""
    plan, cores, x = _cores_and_x((8, 4, 4), (4, 4, 8), 4, 16, jnp.float32)
    assert fused_chain_batch_tile(plan.ns, plan.ms, plan.ranks) is not None
    tt_contract.reset_launch_counts()
    tt_forward(cores, x, backend="auto", interpret=True, tune="off")
    counts = tt_contract.launch_counts()
    assert counts == {"fused_chain": 1}, counts
    # the per-step path on the same chain launches one kernel per core
    tt_contract.reset_launch_counts()
    tt_forward(cores, x, backend="pallas_step", interpret=True, tune="off")
    assert tt_contract.launch_counts() == {"step": 3}


def test_auto_falls_back_when_chain_busts_vmem(monkeypatch):
    """A chain whose states cannot double-buffer even at the minimum tile
    must route through auto to the per-step kernel."""
    plan, cores, x = _cores_and_x((8, 4, 4), (4, 4, 8), 4, 16, jnp.float32)
    sizes = chain_state_sizes(plan.ns, plan.ms, plan.ranks)
    weights = chain_weight_elems(plan.ns, plan.ms, plan.ranks)
    budget = (max(a + b for a, b in zip(sizes, sizes[1:])) * 8 * 2
              + weights * 4) // 2
    assert fused_chain_batch_tile(plan.ns, plan.ms, plan.ranks,
                                  vmem_budget=budget) is None
    # shrink the VMEM budget seen by the plan resolver's fit verdict so
    # the test fails for real, then drive the public auto path
    import repro.kernels.plan as ttplan
    from repro.core.packing import chain_fit_report
    monkeypatch.setattr(
        ttplan, "chain_fit_report",
        lambda ns, ms, ranks, **kw: chain_fit_report(
            ns, ms, ranks, **dict(kw, vmem_budget=budget)))
    tt_contract.reset_launch_counts()
    got = tt_forward(cores, x, backend="auto", interpret=True, tune="off")
    base = tt_forward(cores, x, backend="xla")
    assert tt_contract.launch_counts() == {"step": 3}, \
        "auto must fall back to the per-step kernel when VMEM-fit fails"
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-4, atol=1e-4)


def test_chain_state_sizes_match_kernel_invariant():
    plan = make_plan((8, 4, 4), (4, 4, 8), 4)
    sizes = chain_state_sizes(plan.ns, plan.ms, plan.ranks)
    assert sizes[0] == plan.N and sizes[-1] == plan.M
    assert len(sizes) == plan.d + 1


# ---------------------------------------------------------------------------
# Autotuner cache behaviour
# ---------------------------------------------------------------------------

def test_autotune_cache_miss_then_hit(tmp_path):
    """measure-mode: first call times candidates and persists; the second
    call (even after dropping in-memory state) returns the identical plan
    without running a single new measurement."""
    cache = str(tmp_path / "tune.json")
    ns, ms, ranks = (4, 4, 8), (8, 4, 4), (1, 4, 4, 1)
    n0 = autotune.N_MEASUREMENTS
    bb1 = autotune.fused_tile(ns, ms, ranks, jnp.float32, 32,
                              mode="measure", interpret=True,
                              cache_path=cache)
    n1 = autotune.N_MEASUREMENTS
    assert n1 > n0, "miss must measure"
    autotune.clear_memory_caches()          # force the disk round-trip
    bb2 = autotune.fused_tile(ns, ms, ranks, jnp.float32, 32,
                              mode="measure", interpret=True,
                              cache_path=cache)
    assert bb2 == bb1
    assert autotune.N_MEASUREMENTS == n1, "hit must not re-time"
    entry = json.loads((tmp_path / "tune.json").read_text())
    (key, val), = entry.items()
    assert key.startswith("fused_chain|") and val["block_b"] == bb1
    assert val["source"] == "measured"


def test_autotune_cached_mode_reads_but_never_writes(tmp_path):
    cache = str(tmp_path / "tune.json")
    ns, ms, ranks = (4, 16), (16, 8), (1, 8, 1)
    n0 = autotune.N_MEASUREMENTS
    bb = autotune.fused_tile(ns, ms, ranks, jnp.float32, 16,
                             mode="cached", interpret=True, cache_path=cache)
    assert bb is not None
    assert autotune.N_MEASUREMENTS == n0
    assert not (tmp_path / "tune.json").exists()


def test_autotune_step_plan_roundtrip(tmp_path):
    cache = str(tmp_path / "tune.json")
    p1 = autotune.step_plan(64, 48, 32, 8, 8, jnp.float32, mode="measure",
                            interpret=True, cache_path=cache)
    n1 = autotune.N_MEASUREMENTS
    autotune.clear_memory_caches()
    p2 = autotune.step_plan(64, 48, 32, 8, 8, jnp.float32, mode="measure",
                            interpret=True, cache_path=cache)
    assert (p1.bm, p1.bb, p1.bn) == (p2.bm, p2.bb, p2.bn)
    assert autotune.N_MEASUREMENTS == n1
    # the winner is one of the analytical top-k candidates
    cands = select_blocks_candidates(64, 48, 32, 8, 8, k=4)
    assert (p1.bm, p1.bb, p1.bn) in [(c.bm, c.bb, c.bn) for c in cands]


def test_tt_forward_measure_mode_end_to_end(tmp_path, monkeypatch):
    """backend='auto:measure' must produce the XLA answer AND persist a
    fused-chain winner for the layer's exact signature."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "t.json"))
    autotune.clear_memory_caches()
    plan, cores, x = _cores_and_x((8, 4, 4), (4, 4, 8), 4, 16, jnp.float32)
    base = tt_forward(cores, x, backend="xla")
    got = tt_forward(cores, x, backend="auto:measure", interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-4, atol=1e-4)
    entries = json.loads((tmp_path / "t.json").read_text())
    key = autotune.plan_key("fused_chain", *chain_dims(cores),
                            jnp.float32, 16)
    assert key in entries
    autotune.clear_memory_caches()


# ---------------------------------------------------------------------------
# Serving engine: jitted callables are cached across generate() calls
# ---------------------------------------------------------------------------

def test_model_jit_cache_reused():
    from repro.configs import build, get_config
    cfg = get_config("deepseek_7b", "smoke")
    model = build(cfg)
    f1 = model.jitted_decode_step()
    f2 = model.jitted_decode_step()
    assert f1 is f2
    p1 = model.jitted_prefill(16)
    p2 = model.jitted_prefill(16)
    p3 = model.jitted_prefill(32)
    assert p1 is p2 and p1 is not p3
