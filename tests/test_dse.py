"""The 4-stage DSE pipeline (paper §4, Fig. 4, Tables 1–2) + the
mixed-precision memory model (DESIGN.md §8)."""
import pytest

from repro.core.dse import (DSEConfig, TPU_DSE, aligned_combination_shapes,
                            best_plan, count_stages, explore,
                            multiplicative_partitions, pareto_front,
                            select_threads, weight_bytes)
from repro.core.flops import dense_flops, dense_params, prod, tt_params


def test_multiplicative_partitions():
    parts = multiplicative_partitions(12)
    assert set(parts) == {(12,), (2, 6), (3, 4), (2, 2, 3)}
    assert multiplicative_partitions(7) == ((7,),)
    # every partition multiplies back and is ascending
    for p in multiplicative_partitions(512):
        assert prod(p) == 512
        assert list(p) == sorted(p)


def test_aligned_combination_shapes_cover_paper_example():
    """The §2 LeNet300 shapes (M=300, N=784, d=5) must appear."""
    shapes = aligned_combination_shapes(300, 784, max_d=5, min_d=5)
    assert ((5, 5, 3, 2, 2), (2, 2, 2, 7, 14)) in shapes


def test_stage_counts_monotone_decreasing():
    """Each pruning stage only removes solutions (Tables 1–2 columns)."""
    c = count_stages(120, 84, DSEConfig())            # paper Fig. 2 layer
    assert c["all_initial"] >= c["aligned"] >= c["vectorized"] > 0


def test_table1_lenet5_magnitudes():
    """Table 1 row [120, 84]: all≈5.4e6, aligned≈1.1e5, vectorized≈3.3e2.
    We assert the order of magnitude (the paper prints 2 significant
    digits)."""
    c = count_stages(84, 120, DSEConfig(vl=8))
    import math
    assert 5.5 <= math.log10(c["all_initial"]) <= 7.5
    assert 4.0 <= math.log10(c["aligned"]) <= 6.0
    assert 2.0 <= math.log10(c["vectorized"]) <= 3.5


def test_vectorization_constraint():
    """§4.2.1: all surviving ranks are multiples of vl."""
    res = explore(300, 784, DSEConfig(vl=8, rank_step=8, rank_cap=64))
    assert res.solutions
    for s in res.solutions:
        for r in s.plan.ranks[1:-1]:
            assert r % 8 == 0


def test_initial_layer_constraint():
    """§4.2.2: every survivor beats the dense layer on FLOPs AND params."""
    M, N = 300, 784
    res = explore(M, N, DSEConfig(vl=8, rank_step=8, rank_cap=64))
    for s in res.solutions:
        assert s.flops < dense_flops(M, N)
        assert s.params < dense_params(M, N)


def test_scalability_constraint():
    """§4.2.3: no survivor has d > max_scalable_d with heaviest einsum below
    the workload floor."""
    cfg = DSEConfig(vl=8, rank_step=8, rank_cap=32)
    res = explore(2048, 2048, cfg)
    for s in res.solutions:
        if s.d > cfg.max_scalable_d:
            assert s.max_einsum_flops >= cfg.heavy_flops_min


def test_thread_table_fig9():
    """Fig. 9 workload → thread-count boundaries."""
    cfg = DSEConfig()
    assert select_threads(1e6, cfg) == 1
    assert select_threads(3e6, cfg) == 2
    assert select_threads(6e6, cfg) == 3
    assert select_threads(9e6, cfg) == 4


def test_solutions_sorted_and_best_filters():
    res = explore(512, 512, DSEConfig(vl=8, rank_step=8, rank_cap=32))
    flops = [s.flops for s in res.solutions]
    assert flops == sorted(flops)
    b2 = res.best(length=2)
    assert b2 is not None and b2.d == 2
    b8 = res.best(rank=8)
    assert all(r in (1, 8) for r in b8.plan.ranks)


def test_paper_64_picks_are_survivors():
    """§6.4's deployed factorizations are *among* our survivors (the paper
    emits a list, not a single solution).  Note: the quoted picks are not
    the Eq.(11) minimum — our min-FLOPs survivor is strictly cheaper, which
    we also assert (EXPERIMENTS.md discusses the gap)."""
    from repro.core.flops import tt_flops, clip_ranks
    cases = [
        # (M, N, paper ns, paper ms)  — "FC [N_in, M_out] factorized into
        # [n1×n2, m1×m2]" per §6.4 listing, rank 8
        (1000, 2048, (32, 64), (100, 10)),       # ResNet
        (512, 512, (16, 32), (32, 16)),          # VGG fc
        (1000, 1024, (16, 64), (40, 25)),        # GoogleNet
        (2048, 4096, (64, 64), (64, 32)),        # AlexNet fc1
    ]
    for M, N, ns, ms in cases:
        res = explore(M, N, DSEConfig(vl=8, rank_step=8, rank_cap=8))
        found = [s for s in res.solutions
                 if s.plan.ms == ms and s.plan.ns == ns]
        assert found, f"paper pick {ms}x{ns} pruned for [{M},{N}]"
        paper_flops = tt_flops(ms, ns, clip_ranks(ms, ns, (1, 8, 1)),
                               bias=False)
        assert res.solutions[0].flops <= paper_flops + M


def test_best_plan_entry_point():
    p = best_plan(1000, 2048, rank=8, length=2)
    assert p is not None and p.d == 2
    assert p.M == 1000 and p.N == 2048
    assert p.params < dense_params(1000, 2048, bias=False)


def test_tpu_mode_min_factor():
    """TPU DSE mode: every factor ≥ 8 so each einsum dim can fill the
    8-sublane register file (DESIGN.md §2)."""
    cfg = TPU_DSE
    res = explore(4096, 4096,
                  DSEConfig(vl=128, rank_step=128, rank_cap=256,
                            min_factor=8))
    assert res.solutions
    for s in res.solutions:
        assert min(s.plan.ms) >= 8 and min(s.plan.ns) >= 8
        for r in s.plan.ranks[1:-1]:
            assert r % 128 == 0
    assert cfg.vl == 128


def test_int8_candidates_reduce_memory_footprint():
    """Mixed-precision enumeration: every surviving plan gets an int8 twin
    whose byte footprint is exactly ``core.quant.quantized_bytes`` of its
    quantized cores (1 B/elem + one fp32 scale per core)."""
    import jax

    from repro.core.quant import quantize_cores, quantized_bytes
    from repro.core.tt import tt_init

    cfg = DSEConfig(vl=8, rank_step=8, rank_cap=16,
                    weight_dtypes=("fp32", "int8"))
    res = explore(256, 256, cfg, with_counts=False)
    int8 = [s for s in res.solutions if s.weight_dtype == "int8"]
    fp32 = [s for s in res.solutions if s.weight_dtype == "fp32"]
    assert int8 and len(int8) == len(fp32)
    for s in int8[:5]:
        qs, ss = quantize_cores(tt_init(jax.random.PRNGKey(0), s.plan))
        assert s.bytes == quantized_bytes(qs, ss)
        core_p = tt_params(s.plan.ms, s.plan.ns, s.plan.ranks, bias=False)
        assert s.bytes == weight_bytes(core_p, s.plan.d, "int8")
    # the fp32 twin of the same plan is exactly 4x the core bytes
    by_plan = {(s.plan.ms, s.plan.ns, s.plan.ranks): s for s in fp32}
    for s in int8[:5]:
        twin = by_plan[(s.plan.ms, s.plan.ns, s.plan.ranks)]
        assert twin.bytes == 4 * (s.bytes - 4 * s.plan.d)
        assert twin.flops == s.flops
        assert twin.quant_rel_err == 0.0 < s.quant_rel_err


def test_pareto_front_mixes_precisions():
    """The (flops, bytes, error) front must contain ALL precisions: lower
    dtypes win the memory axis at equal FLOPs but carry a nonzero error
    proxy (bf16 included — half-ulp 2^-8/core), so none dominates
    another."""
    cfg = DSEConfig(vl=8, rank_step=8, rank_cap=16,
                    weight_dtypes=("fp32", "bf16", "int8"))
    res = explore(256, 256, cfg, with_counts=False)
    front = pareto_front(res.solutions)
    kinds = {s.weight_dtype for s in front}
    assert kinds == {"fp32", "bf16", "int8"}
    # no member of the front is dominated by any solution
    for s in front:
        for o in res.solutions:
            assert not (o.flops < s.flops and o.bytes < s.bytes
                        and o.quant_rel_err <= s.quant_rel_err)


def test_scalability_count_is_plan_count_not_dtype_twins():
    """The Fig.-4 funnel counts PLANS surviving the scalability prune;
    weight-dtype twins are memory-model variants, tallied separately."""
    cfg = DSEConfig(vl=8, rank_step=8, rank_cap=16,
                    weight_dtypes=("fp32", "int8"))
    res = explore(256, 256, cfg, with_counts=True)
    assert res.counts["dtype_enumerated"] == len(res.solutions)
    assert res.counts["scalability"] * 2 == res.counts["dtype_enumerated"]
    base = explore(256, 256, DSEConfig(vl=8, rank_step=8, rank_cap=16),
                   with_counts=True)
    assert res.counts["scalability"] == base.counts["scalability"]


def test_weight_bytes_model():
    assert weight_bytes(1000, 3, "fp32") == 4000
    assert weight_bytes(1000, 3, "bf16") == 2000
    assert weight_bytes(1000, 3, "int8") == 1012
    with pytest.raises(ValueError):
        weight_bytes(1000, 3, "fp8")


def test_rerank_measured_times_int8_kernel_path():
    """Stage 4b must run int8 candidates through the int8 kernels and
    keep the (plan, dtype) identity of every reranked solution."""
    from repro.core.dse import rerank_measured

    cfg = DSEConfig(vl=8, rank_step=8, rank_cap=8,
                    weight_dtypes=("fp32", "int8"))
    res = explore(128, 128, cfg, with_counts=False)
    res2 = rerank_measured(res, batch=8, limit=4, interpret=True)
    assert res2.counts["measured_rerank"] == 4
    assert sorted(id(s) for s in res2.solutions) == \
        sorted(id(s) for s in res.solutions)
    assert {s.weight_dtype for s in res2.solutions[:4]} == {"fp32", "int8"}


def test_ds_reduction_factor_bounds():
    """Alignment reduces the DS by (d!)²/Πk! per shape — overall reduction
    for a realistic layer must be in the paper's x2.1–x92 band (Tables
    1–2 report the *aggregate* over shapes; we check the aggregate)."""
    c = count_stages(1024, 1024, DSEConfig())
    red = c["all_initial"] / c["aligned"]
    assert red > 2.0


# ---------------------------------------------------------------------------
# ISSUE 7 satellites: funnel invariants, err_proxy, best() edge cases
# ---------------------------------------------------------------------------

def test_count_enumerated_matches_explored_grid():
    """The analytic stage-2 grid count must agree with what explore()
    actually enumerates, across several small shapes and grid configs."""
    from repro.core.dse import count_enumerated

    for M, N in [(64, 64), (128, 64), (256, 128), (120, 36)]:
        for cfg in (DSEConfig(vl=4, rank_step=4, rank_cap=16, max_d=3),
                    DSEConfig(vl=8, rank_step=8, rank_cap=64, max_d=4,
                              min_factor=4),
                    DSEConfig(vl=2, rank_step=6, rank_cap=10, max_d=2)):
            res = explore(M, N, cfg, with_counts=False)
            assert res.counts["vectorized_enumerated"] == \
                count_enumerated(M, N, cfg), (M, N, cfg)


def test_best_no_match_raises_clear_valueerror():
    res = explore(64, 64, DSEConfig(vl=8, rank_step=8, rank_cap=8),
                  with_counts=False)
    with pytest.raises(ValueError, match=r"length=99.*64x64"):
        res.best(length=99)
    # the sentinel default restores the legacy None-on-miss contract
    assert res.best(length=99, default=None) is None
    assert res.best(length=99, default="fallback") == "fallback"


def test_err_proxy_is_computed_not_constant():
    """fp32 cores contribute zero; int8 error grows with core size (the
    old per-dtype constant missed this); unknown dtypes are rejected."""
    import math

    from repro.core.dse import core_err_bound, plan_err_proxy
    from repro.core.tt import make_plan

    assert core_err_bound((1, 8, 8, 4), "fp32") == 0.0
    small = core_err_bound((1, 4, 4, 2), "int8")
    big = core_err_bound((8, 64, 64, 8), "int8")
    assert 0 < small < big < 1
    assert big == pytest.approx(
        math.sqrt(2 * math.log(8 * 64 * 64 * 8)) / 254.0)
    with pytest.raises(ValueError):
        core_err_bound((1, 4, 4, 1), "fp16")
    plan = make_plan((16, 8), (8, 16), 8)
    assert plan_err_proxy(plan, "fp32") == 0.0
    assert plan_err_proxy(plan, "int8") == pytest.approx(
        sum(core_err_bound(s, "int8") for s in plan.core_shapes))


def test_quant_rel_err_deprecated_alias():
    res = explore(64, 64, DSEConfig(vl=8, rank_step=8, rank_cap=8,
                                    weight_dtypes=("int8",)),
                  with_counts=False)
    s = res.solutions[0]
    with pytest.warns(DeprecationWarning, match="err_proxy"):
        assert s.quant_rel_err == s.err_proxy


def test_generate_candidates_matches_explore():
    """explore() is now a thin wrapper: the generator must yield exactly
    the solutions explore returns (as a set; explore sorts)."""
    from repro.core.dse import generate_candidates

    cfg = DSEConfig(vl=4, rank_step=4, rank_cap=8, max_d=3,
                    weight_dtypes=("fp32", "int8"))
    counts = {}
    gen = list(generate_candidates(128, 64, cfg, counts=counts))
    res = explore(128, 64, cfg, with_counts=False)
    key = lambda s: (s.plan.ms, s.plan.ns, s.plan.ranks, s.weight_dtype)
    assert sorted(map(key, gen)) == sorted(map(key, res.solutions))
    assert counts["dtype_enumerated"] == len(gen)
    assert res.counts["scalability"] * 2 == counts["dtype_enumerated"]


def test_measured_front_requires_metrics():
    import dataclasses as dc

    res = explore(128, 64, DSEConfig(vl=4, rank_step=4, rank_cap=8),
                  with_counts=False)
    # nothing evaluated → empty measured front, and a direct pareto call
    # over missing axes fails loudly
    assert res.measured_front() == []
    with pytest.raises(ValueError, match="no measured"):
        pareto_front(res.solutions, axes=("flops", "tok_s"))
    # attach metrics to two: they become the front's only competitors
    a = dc.replace(res.solutions[0], tok_s=100.0, ppl_delta=0.5)
    b = dc.replace(res.solutions[1], tok_s=50.0, ppl_delta=0.9)
    res2 = type(res)(res.M, res.N, res.counts,
                     [a, b] + res.solutions[2:])
    front = res2.measured_front()
    assert a in front
    # b is dominated on tok_s+ppl_delta but may win flops/bytes; both
    # must at least carry full metrics
    assert all(s.tok_s is not None for s in front)


def test_pareto_front_nondomination_hypothesis():
    """Property: no member of the front is dominated by any solution;
    every excluded solution is dominated by some front member; the front
    is deterministic under input permutation."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    from repro.core.dse import Solution
    from repro.core.tt import make_plan

    plan = make_plan((16, 8), (8, 16), 8)

    def sol(flops, nbytes, err):
        return Solution(plan, flops, 0, (1,), flops, bytes=nbytes,
                        err_proxy=float(err))

    triples = st.lists(
        st.tuples(st.integers(1, 50), st.integers(1, 50),
                  st.integers(0, 50)),
        min_size=1, max_size=40)

    def dominated(x, y):
        ax = (x.flops, x.bytes, x.err_proxy)
        ay = (y.flops, y.bytes, y.err_proxy)
        return all(a <= b for a, b in zip(ay, ax)) and ay != ax

    @given(triples, st.randoms())
    @settings(max_examples=60, deadline=None)
    def check(ts, rng):
        sols = [sol(*t) for t in ts]
        front = pareto_front(sols)
        for f in front:
            assert not any(dominated(f, o) for o in sols)
        for s in sols:
            if (s.flops, s.bytes, s.err_proxy) not in \
                    [(f.flops, f.bytes, f.err_proxy) for f in front]:
                assert any(dominated(s, f) for f in front)
        shuffled = list(sols)
        rng.shuffle(shuffled)
        assert [(f.flops, f.bytes, f.err_proxy)
                for f in pareto_front(shuffled)] == \
            [(f.flops, f.bytes, f.err_proxy) for f in front]

    check()
