"""The 4-stage DSE pipeline (paper §4, Fig. 4, Tables 1–2) + the
mixed-precision memory model (DESIGN.md §8)."""
import pytest

from repro.core.dse import (DSEConfig, TPU_DSE, aligned_combination_shapes,
                            best_plan, count_stages, explore,
                            multiplicative_partitions, pareto_front,
                            select_threads, weight_bytes)
from repro.core.flops import dense_flops, dense_params, prod, tt_params


def test_multiplicative_partitions():
    parts = multiplicative_partitions(12)
    assert set(parts) == {(12,), (2, 6), (3, 4), (2, 2, 3)}
    assert multiplicative_partitions(7) == ((7,),)
    # every partition multiplies back and is ascending
    for p in multiplicative_partitions(512):
        assert prod(p) == 512
        assert list(p) == sorted(p)


def test_aligned_combination_shapes_cover_paper_example():
    """The §2 LeNet300 shapes (M=300, N=784, d=5) must appear."""
    shapes = aligned_combination_shapes(300, 784, max_d=5, min_d=5)
    assert ((5, 5, 3, 2, 2), (2, 2, 2, 7, 14)) in shapes


def test_stage_counts_monotone_decreasing():
    """Each pruning stage only removes solutions (Tables 1–2 columns)."""
    c = count_stages(120, 84, DSEConfig())            # paper Fig. 2 layer
    assert c["all_initial"] >= c["aligned"] >= c["vectorized"] > 0


def test_table1_lenet5_magnitudes():
    """Table 1 row [120, 84]: all≈5.4e6, aligned≈1.1e5, vectorized≈3.3e2.
    We assert the order of magnitude (the paper prints 2 significant
    digits)."""
    c = count_stages(84, 120, DSEConfig(vl=8))
    import math
    assert 5.5 <= math.log10(c["all_initial"]) <= 7.5
    assert 4.0 <= math.log10(c["aligned"]) <= 6.0
    assert 2.0 <= math.log10(c["vectorized"]) <= 3.5


def test_vectorization_constraint():
    """§4.2.1: all surviving ranks are multiples of vl."""
    res = explore(300, 784, DSEConfig(vl=8, rank_step=8, rank_cap=64))
    assert res.solutions
    for s in res.solutions:
        for r in s.plan.ranks[1:-1]:
            assert r % 8 == 0


def test_initial_layer_constraint():
    """§4.2.2: every survivor beats the dense layer on FLOPs AND params."""
    M, N = 300, 784
    res = explore(M, N, DSEConfig(vl=8, rank_step=8, rank_cap=64))
    for s in res.solutions:
        assert s.flops < dense_flops(M, N)
        assert s.params < dense_params(M, N)


def test_scalability_constraint():
    """§4.2.3: no survivor has d > max_scalable_d with heaviest einsum below
    the workload floor."""
    cfg = DSEConfig(vl=8, rank_step=8, rank_cap=32)
    res = explore(2048, 2048, cfg)
    for s in res.solutions:
        if s.d > cfg.max_scalable_d:
            assert s.max_einsum_flops >= cfg.heavy_flops_min


def test_thread_table_fig9():
    """Fig. 9 workload → thread-count boundaries."""
    cfg = DSEConfig()
    assert select_threads(1e6, cfg) == 1
    assert select_threads(3e6, cfg) == 2
    assert select_threads(6e6, cfg) == 3
    assert select_threads(9e6, cfg) == 4


def test_solutions_sorted_and_best_filters():
    res = explore(512, 512, DSEConfig(vl=8, rank_step=8, rank_cap=32))
    flops = [s.flops for s in res.solutions]
    assert flops == sorted(flops)
    b2 = res.best(length=2)
    assert b2 is not None and b2.d == 2
    b8 = res.best(rank=8)
    assert all(r in (1, 8) for r in b8.plan.ranks)


def test_paper_64_picks_are_survivors():
    """§6.4's deployed factorizations are *among* our survivors (the paper
    emits a list, not a single solution).  Note: the quoted picks are not
    the Eq.(11) minimum — our min-FLOPs survivor is strictly cheaper, which
    we also assert (EXPERIMENTS.md discusses the gap)."""
    from repro.core.flops import tt_flops, clip_ranks
    cases = [
        # (M, N, paper ns, paper ms)  — "FC [N_in, M_out] factorized into
        # [n1×n2, m1×m2]" per §6.4 listing, rank 8
        (1000, 2048, (32, 64), (100, 10)),       # ResNet
        (512, 512, (16, 32), (32, 16)),          # VGG fc
        (1000, 1024, (16, 64), (40, 25)),        # GoogleNet
        (2048, 4096, (64, 64), (64, 32)),        # AlexNet fc1
    ]
    for M, N, ns, ms in cases:
        res = explore(M, N, DSEConfig(vl=8, rank_step=8, rank_cap=8))
        found = [s for s in res.solutions
                 if s.plan.ms == ms and s.plan.ns == ns]
        assert found, f"paper pick {ms}x{ns} pruned for [{M},{N}]"
        paper_flops = tt_flops(ms, ns, clip_ranks(ms, ns, (1, 8, 1)),
                               bias=False)
        assert res.solutions[0].flops <= paper_flops + M


def test_best_plan_entry_point():
    p = best_plan(1000, 2048, rank=8, length=2)
    assert p is not None and p.d == 2
    assert p.M == 1000 and p.N == 2048
    assert p.params < dense_params(1000, 2048, bias=False)


def test_tpu_mode_min_factor():
    """TPU DSE mode: every factor ≥ 8 so each einsum dim can fill the
    8-sublane register file (DESIGN.md §2)."""
    cfg = TPU_DSE
    res = explore(4096, 4096,
                  DSEConfig(vl=128, rank_step=128, rank_cap=256,
                            min_factor=8))
    assert res.solutions
    for s in res.solutions:
        assert min(s.plan.ms) >= 8 and min(s.plan.ns) >= 8
        for r in s.plan.ranks[1:-1]:
            assert r % 128 == 0
    assert cfg.vl == 128


def test_int8_candidates_reduce_memory_footprint():
    """Mixed-precision enumeration: every surviving plan gets an int8 twin
    whose byte footprint is exactly ``core.quant.quantized_bytes`` of its
    quantized cores (1 B/elem + one fp32 scale per core)."""
    import jax

    from repro.core.quant import quantize_cores, quantized_bytes
    from repro.core.tt import tt_init

    cfg = DSEConfig(vl=8, rank_step=8, rank_cap=16,
                    weight_dtypes=("fp32", "int8"))
    res = explore(256, 256, cfg, with_counts=False)
    int8 = [s for s in res.solutions if s.weight_dtype == "int8"]
    fp32 = [s for s in res.solutions if s.weight_dtype == "fp32"]
    assert int8 and len(int8) == len(fp32)
    for s in int8[:5]:
        qs, ss = quantize_cores(tt_init(jax.random.PRNGKey(0), s.plan))
        assert s.bytes == quantized_bytes(qs, ss)
        core_p = tt_params(s.plan.ms, s.plan.ns, s.plan.ranks, bias=False)
        assert s.bytes == weight_bytes(core_p, s.plan.d, "int8")
    # the fp32 twin of the same plan is exactly 4x the core bytes
    by_plan = {(s.plan.ms, s.plan.ns, s.plan.ranks): s for s in fp32}
    for s in int8[:5]:
        twin = by_plan[(s.plan.ms, s.plan.ns, s.plan.ranks)]
        assert twin.bytes == 4 * (s.bytes - 4 * s.plan.d)
        assert twin.flops == s.flops
        assert twin.quant_rel_err == 0.0 < s.quant_rel_err


def test_pareto_front_mixes_precisions():
    """The (flops, bytes, error) front must contain ALL precisions: lower
    dtypes win the memory axis at equal FLOPs but carry a nonzero error
    proxy (bf16 included — half-ulp 2^-8/core), so none dominates
    another."""
    cfg = DSEConfig(vl=8, rank_step=8, rank_cap=16,
                    weight_dtypes=("fp32", "bf16", "int8"))
    res = explore(256, 256, cfg, with_counts=False)
    front = pareto_front(res.solutions)
    kinds = {s.weight_dtype for s in front}
    assert kinds == {"fp32", "bf16", "int8"}
    # no member of the front is dominated by any solution
    for s in front:
        for o in res.solutions:
            assert not (o.flops < s.flops and o.bytes < s.bytes
                        and o.quant_rel_err <= s.quant_rel_err)


def test_scalability_count_is_plan_count_not_dtype_twins():
    """The Fig.-4 funnel counts PLANS surviving the scalability prune;
    weight-dtype twins are memory-model variants, tallied separately."""
    cfg = DSEConfig(vl=8, rank_step=8, rank_cap=16,
                    weight_dtypes=("fp32", "int8"))
    res = explore(256, 256, cfg, with_counts=True)
    assert res.counts["dtype_enumerated"] == len(res.solutions)
    assert res.counts["scalability"] * 2 == res.counts["dtype_enumerated"]
    base = explore(256, 256, DSEConfig(vl=8, rank_step=8, rank_cap=16),
                   with_counts=True)
    assert res.counts["scalability"] == base.counts["scalability"]


def test_weight_bytes_model():
    assert weight_bytes(1000, 3, "fp32") == 4000
    assert weight_bytes(1000, 3, "bf16") == 2000
    assert weight_bytes(1000, 3, "int8") == 1012
    with pytest.raises(ValueError):
        weight_bytes(1000, 3, "fp8")


def test_rerank_measured_times_int8_kernel_path():
    """Stage 4b must run int8 candidates through the int8 kernels and
    keep the (plan, dtype) identity of every reranked solution."""
    from repro.core.dse import rerank_measured

    cfg = DSEConfig(vl=8, rank_step=8, rank_cap=8,
                    weight_dtypes=("fp32", "int8"))
    res = explore(128, 128, cfg, with_counts=False)
    res2 = rerank_measured(res, batch=8, limit=4, interpret=True)
    assert res2.counts["measured_rerank"] == 4
    assert sorted(id(s) for s in res2.solutions) == \
        sorted(id(s) for s in res.solutions)
    assert {s.weight_dtype for s in res2.solutions[:4]} == {"fp32", "int8"}


def test_ds_reduction_factor_bounds():
    """Alignment reduces the DS by (d!)²/Πk! per shape — overall reduction
    for a realistic layer must be in the paper's x2.1–x92 band (Tables
    1–2 report the *aggregate* over shapes; we check the aggregate)."""
    c = count_stages(1024, 1024, DSEConfig())
    red = c["all_initial"] / c["aligned"]
    assert red > 2.0
