"""System-level behaviour tests.

1. The dry-run deliverable: every recorded (arch × shape × mesh) cell must
   be 'ok' or a documented 'skipped' — never 'failed'.  (The sweep itself is
   produced by ``python -m repro.launch.dryrun --all``; this test audits its
   output so a regression in sharding shows up in pytest.)
2. End-to-end mini-run: train a tiny TT model for 40 steps with checkpoint
   + simulated preemption + restart; the restarted run must continue
   bit-identically.
"""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

EXPECTED_SKIPS = {
    # pure full-attention archs skip long_500k (DESIGN.md §5)
    ("qwen3_32b", "long_500k"), ("deepseek_7b", "long_500k"),
    ("granite_8b", "long_500k"), ("deepseek_v2_lite_16b", "long_500k"),
    ("internvl2_2b", "long_500k"), ("seamless_m4t_large_v2", "long_500k"),
}


def _cells():
    return sorted(glob.glob(os.path.join(RESULTS, "*__base.json")))


def test_dryrun_cells_all_green():
    cells = _cells()
    if len(cells) < 40:
        pytest.skip(f"dry-run sweep incomplete ({len(cells)} cells recorded)"
                    " — run python -m repro.launch.dryrun --all")
    failed, bad_skip = [], []
    for path in cells:
        with open(path) as f:
            d = json.load(f)
        if d["status"] == "failed":
            failed.append(os.path.basename(path))
        elif d["status"] == "skipped":
            if (d["arch"], d["shape"]) not in EXPECTED_SKIPS:
                bad_skip.append(os.path.basename(path))
    assert not failed, f"failed dry-run cells: {failed}"
    assert not bad_skip, f"unexpected skips: {bad_skip}"


def test_dryrun_ok_cells_have_roofline_terms():
    cells = _cells()
    if not cells:
        pytest.skip("no dry-run results yet")
    for path in cells:
        with open(path) as f:
            d = json.load(f)
        if d["status"] != "ok":
            continue
        r = d["roofline"]
        assert r["t_compute_s"] > 0, path
        assert r["t_memory_s"] > 0, path
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert 0 < r["roofline_fraction"] <= 1.001, path
        assert d["chips"] in (256, 512)


def test_multipod_cells_cover_both_meshes():
    cells = _cells()
    if len(cells) < 80:
        pytest.skip(f"sweep incomplete ({len(cells)}/80)")
    meshes = {}
    for path in cells:
        with open(path) as f:
            d = json.load(f)
        meshes.setdefault((d["arch"], d["shape"]), set()).add(d["mesh"])
    for key, ms in meshes.items():
        assert ms == {"16x16", "2x16x16"}, (key, ms)


def test_train_restart_bit_identical(tmp_path):
    """Fault-tolerance end-to-end: run 6 steps saving every 2, kill, restart
    from step 4, and verify steps 5–6 produce identical params."""
    from repro.configs import build, get_config
    from repro.data.pipeline import DataIterator, DataState
    from repro.training.fault import CheckpointManager, restore_or_init
    from repro.training.optimizer import OptConfig, adamw_init
    from repro.training.train_loop import TrainConfig, make_train_step

    cfg = get_config("deepseek_7b", "smoke")
    model = build(cfg)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0), remat=False,
                       compute_dtype=jnp.float32)
    step_fn = jax.jit(make_train_step(model, tcfg))

    def init_fn():
        params = model.init(jax.random.PRNGKey(0))
        return {"params": params, "opt": adamw_init(params)}

    def run(n_steps, ckpt_dir, crash_after=None):
        mgr = CheckpointManager(str(ckpt_dir), save_every=2)
        template = init_fn()
        state, start, dstate = restore_or_init(mgr, lambda: template, template)
        it = DataIterator(cfg, B=2, S=16,
                          state=DataState.from_dict(dstate or {}))
        for step in range(start + 1, n_steps + 1):
            state, _ = step_fn(state, next(it))
            if mgr.should_save(step):
                mgr.save(state, step, data_state=it.state.as_dict())
            if crash_after is not None and step == crash_after:
                return None
        return state

    d1, d2 = tmp_path / "a", tmp_path / "b"
    full = run(6, d1)                      # uninterrupted
    assert run(6, d2, crash_after=5) is None   # crash at step 5 (ckpt @4)
    resumed = run(6, d2)                   # restart → steps 5..6 again
    for a, b in zip(jax.tree.leaves(full["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
