"""Roofline accounting: HLO collective parser + the three-term model."""
import numpy as np

from repro.analysis.roofline import (Roofline, collective_bytes_from_hlo,
                                     model_flops_estimate)
from repro.core import hw


HLO = """
ENTRY main {
  %ag = f32[16,1024]{1,0} all-gather(f32[2,1024]{1,0} %p0), replica_groups=[2,8]<=[16], dimensions={0}
  %ar = bf16[4096]{0} all-reduce(bf16[4096]{0} %p1), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %rs = f32[512]{0} reduce-scatter(f32[4096]{0} %p2), replica_groups=[1,8]<=[8], dimensions={0}
  %cp = bf16[128,256]{1,0} collective-permute(bf16[128,256]{1,0} %p3), source_target_pairs={{0,1},{1,0}}
  %aa = f32[64,64]{1,0} all-to-all(f32[64,64]{1,0} %p4), replica_groups=[2,4]<=[8], dimensions={0}
  %ags = (f32[8]{0}, f32[64]{0}) all-gather-start(f32[8]{0} %p5), replica_groups=[1,8]<=[8], dimensions={0}
}
"""


def test_collective_parser_kinds_and_ring_factors():
    got = collective_bytes_from_hlo(HLO)
    # all-gather: out 16*1024*4 bytes, ring (g-1)/g with g=8; the -start op
    # has tuple type (operand f32[8], result f32[64]) → max = 256 B
    assert got["all-gather"] == (16 * 1024 * 4) * 7 / 8 + 64 * 4 * 7 / 8
    # all-reduce: 2·(g-1)/g·bytes, g=4
    assert got["all-reduce"] == 2 * (3 / 4) * 4096 * 2
    # reduce-scatter: ring moves (g-1)·out == (g-1)/g·in; out f32[512], g=8
    assert got["reduce-scatter"] == 7 * 512 * 4
    # permute: factor 1
    assert got["collective-permute"] == 128 * 256 * 2
    assert got["all-to-all"] == (3 / 4) * 64 * 64 * 4
    assert got["total"] == sum(v for k, v in got.items() if k != "total")


def test_parser_ignores_group_of_one():
    hlo = ('%ar = f32[64]{0} all-reduce(f32[64]{0} %x), '
           'replica_groups=[64,1]<=[64]')
    got = collective_bytes_from_hlo(hlo)
    assert got.get("all-reduce", 0.0) == 0.0


def test_parser_on_real_lowered_hlo():
    """Parse actual XLA output: a psum over a 1-device mesh lowers to an
    all-reduce with a singleton group (→ 0 bytes), proving the regexes
    match real HLO syntax, not just our synthetic lines."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    try:
        shard_map = jax.shard_map                  # jax >= 0.6
    except AttributeError:
        from jax.experimental.shard_map import shard_map
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    f = shard_map(lambda a: jax.lax.psum(a, "x"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    txt = jax.jit(f).lower(jnp.ones((8, 8))).compile().as_text()
    assert "all-reduce" in txt
    got = collective_bytes_from_hlo(txt)
    assert got["total"] == 0.0            # group size 1 → free


def test_roofline_terms_and_bottleneck():
    rl = Roofline(chips=256, flops_per_device=197e12,       # exactly 1 s
                  bytes_per_device=819e9 * 2,               # 2 s ← dominant
                  collective_per_device=50e9 * 0.5,         # 0.5 s
                  model_flops=197e12 * 256)
    assert rl.t_compute == 1.0
    assert rl.t_memory == 2.0
    assert rl.t_collective == 0.5
    assert rl.bottleneck == "memory"
    assert rl.t_bound == 2.0
    assert np.isclose(rl.useful_flops_ratio, 1.0)
    assert np.isclose(rl.roofline_fraction, 0.5)      # 1 s useful / 2 s bound
    d = rl.to_dict()
    assert d["bottleneck"] == "memory"


def test_model_flops_estimate():
    assert model_flops_estimate(100, 0, 10, "train") == 6.0 * 100 * 10
    assert model_flops_estimate(100, 40, 10, "train") == 6.0 * 40 * 10
    assert model_flops_estimate(100, 0, 10, "decode") == 2.0 * 100 * 10


def test_hw_constants_match_brief():
    assert hw.PEAK_FLOPS_BF16 == 197e12
    assert hw.HBM_BW == 819e9
    assert hw.ICI_BW == 50e9
