"""Checkpoint atomicity + fault-tolerance manager (restart / elastic)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt
from repro.training.fault import CheckpointManager, restore_or_init


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 3)),
                       "tt": {"c0": jnp.arange(6.0).reshape(2, 3)}},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), s, step=7)
    restored, manifest = ckpt.restore(str(tmp_path), s)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_and_specific(tmp_path):
    s = _state()
    for step in (1, 5, 9):
        s["opt"]["step"] = jnp.asarray(step, jnp.int32)
        ckpt.save(str(tmp_path), s, step=step)
    assert ckpt.available_steps(str(tmp_path)) == [1, 5, 9]
    r, m = ckpt.restore(str(tmp_path), s)
    assert m["step"] == 9
    r, m = ckpt.restore(str(tmp_path), s, step=5)
    assert int(r["opt"]["step"]) == 5


def test_structure_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), _state(), step=1)
    wrong = {"params": {"w": jnp.zeros((4, 3))}}
    with pytest.raises(ValueError, match="mismatch"):
        ckpt.restore(str(tmp_path), wrong)


def test_torn_write_never_restored(tmp_path):
    """A crashed save (leftover .tmp dir) must be invisible to restore."""
    s = _state()
    ckpt.save(str(tmp_path), s, step=1)
    torn = os.path.join(str(tmp_path), "step_00000002.tmp.999")
    os.makedirs(torn)
    with open(os.path.join(torn, "manifest.json"), "w") as f:
        json.dump({"step": 2}, f)
    assert ckpt.available_steps(str(tmp_path)) == [1]
    _, m = ckpt.restore(str(tmp_path), s)
    assert m["step"] == 1


def test_corrupt_checkpoint_falls_back_to_good_step(tmp_path):
    """restore(step=None) skips a bit-flipped newest checkpoint (checksum
    caught while streaming) and restores the previous good step."""
    s = _state()
    for step in (1, 2):
        s["opt"]["step"] = jnp.asarray(step, jnp.int32)
        ckpt.save(str(tmp_path), s, step=step)
    p = os.path.join(str(tmp_path), "step_00000002", "arrays.bin")
    b = bytearray(open(p, "rb").read())
    b[5] ^= 0x08
    open(p, "wb").write(bytes(b))
    restored, m = ckpt.restore(str(tmp_path), s)
    assert m["step"] == 1 and int(restored["opt"]["step"]) == 1


def test_corrupt_checkpoint_explicit_step_names_file_and_good_steps(
        tmp_path):
    """An explicit step never falls back: truncation raises a RuntimeError
    naming the damaged path and listing the steps that are still good."""
    s = _state()
    for step in (3, 8):
        ckpt.save(str(tmp_path), s, step=step)
    p = os.path.join(str(tmp_path), "step_00000008", "arrays.bin")
    with open(p, "r+b") as f:
        f.truncate(7)
    with pytest.raises(RuntimeError) as ei:
        ckpt.restore(str(tmp_path), s, step=8)
    msg = str(ei.value)
    assert "step_00000008" in msg and "[3]" in msg
    # and everything corrupt raises, never returns torn state
    p3 = os.path.join(str(tmp_path), "step_00000003", "arrays.bin")
    with open(p3, "r+b") as f:
        f.truncate(7)
    with pytest.raises(RuntimeError, match="every checkpoint .* corrupt"):
        ckpt.restore(str(tmp_path), s)


def test_legacy_npz_checkpoint_still_restores(tmp_path):
    """Pre-PR-8 checkpoints (arrays.npz, no per-array index) restore
    unchanged; a truncated legacy archive raises a named RuntimeError
    instead of a raw zipfile error."""
    import numpy as _np
    s = _state()
    d = os.path.join(str(tmp_path), "step_00000004")
    os.makedirs(d)
    flat = {k: _np.asarray(v) for k, v in ckpt._flatten(s).items()}
    _np.savez(os.path.join(d, "arrays.npz"), **flat)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"step": 4, "fingerprint": ckpt.tree_fingerprint(s),
                   "extra": {}}, f)
    restored, m = ckpt.restore(str(tmp_path), s)
    assert m["step"] == 4
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with open(os.path.join(d, "arrays.npz"), "r+b") as f:
        f.truncate(12)
    with pytest.raises(RuntimeError, match="legacy archive"):
        ckpt.restore(str(tmp_path), s, step=4)


def test_prune_keeps_newest(tmp_path):
    s = _state()
    for step in range(6):
        ckpt.save(str(tmp_path), s, step=step)
    ckpt.prune(str(tmp_path), keep=3)
    assert ckpt.available_steps(str(tmp_path)) == [3, 4, 5]


def test_manager_save_every_and_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=10, keep=2)
    s = _state()
    saved = []
    for step in range(25):
        if mgr.should_save(step):
            mgr.save(s, step)
            saved.append(step)
    assert saved == [10, 20]          # step 0 never saved (nothing learned)
    assert mgr.latest_step() == 20
    restored, data_state = mgr.restore(s)
    assert data_state is not None or True   # manifest extra may be empty
    assert ckpt.available_steps(str(tmp_path)) == [10, 20]


def test_restore_or_init_cold_and_warm(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1)
    calls = []

    def init_fn():
        calls.append(1)
        return _state()

    template = _state()
    # cold start: no checkpoint → init_fn used
    state, step, _ = restore_or_init(mgr, init_fn, template)
    assert step == 0 and len(calls) == 1
    # save then warm start: restored, init_fn NOT called again
    mgr.save(state, 42)
    state2, step2, _ = restore_or_init(mgr, init_fn, template)
    assert step2 == 42 and len(calls) == 1
    np.testing.assert_array_equal(np.asarray(state2["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_includes_data_iterator_state(tmp_path):
    """Fault tolerance covers the input pipeline: iterator state rides in
    the manifest so a restart resumes the exact batch sequence."""
    from repro.configs import get_config
    from repro.data.pipeline import DataIterator, DataState
    mgr = CheckpointManager(str(tmp_path), save_every=1)
    cfg = get_config("deepseek_7b", "smoke")
    it = DataIterator(cfg, B=2, S=8)
    b3 = [next(it) for _ in range(3)][-1]           # consume 3 batches
    mgr.save(_state(), 3, data_state=it.state.as_dict())
    _, data_state = mgr.restore(_state())
    it2 = DataIterator(cfg, B=2, S=8,
                       state=DataState.from_dict(data_state))
    # continues after batch 3 — matches a fresh iterator's 4th batch
    it_ref = DataIterator(cfg, B=2, S=8)
    for _ in range(3):
        next(it_ref)
    np.testing.assert_array_equal(np.asarray(next(it2)["tokens"]),
                                  np.asarray(next(it_ref)["tokens"]))


def test_quantize_on_save_roundtrip_bit_exact(tmp_path):
    """``save(..., quantize_tt=True)`` writes the int8 serving transform:
    the restored tree is bit-identical to ``Model.quantize_params`` of
    the fp32 tree (codes, per-layer scales and untouched dense leaves),
    and re-saving the already-quantized tree is a no-op transform."""
    from repro.configs import get_config, build
    from repro.configs.base import TTConfig

    cfg = get_config("deepseek_7b", "smoke",
                     tt=TTConfig(enabled=True, families=("ffn", "attn"),
                                 rank=4, min_factor=2))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ref = model.quantize_params(params)

    d = ckpt.save(str(tmp_path), params, step=1, quantize_tt=True)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["extra"]["quantized_tt"] is True
    # the artifact's structure is the *quantized* structure
    assert manifest["fingerprint"] == ckpt.tree_fingerprint(ref)

    restored, _ = ckpt.restore(str(tmp_path), ref)
    flat_ref = jax.tree_util.tree_flatten_with_path(ref)[0]
    flat_got = jax.tree_util.tree_flatten_with_path(restored)[0]
    assert len(flat_ref) == len(flat_got) > len(jax.tree.leaves(params))
    for (pa, a), (pb, b) in zip(flat_ref, flat_got):
        assert pa == pb
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # idempotent: saving the int8 tree again with the flag changes nothing
    d2 = ckpt.save(str(tmp_path), restored, step=2, quantize_tt=True)
    with open(os.path.join(d2, "manifest.json")) as f:
        assert json.load(f)["fingerprint"] == ckpt.tree_fingerprint(ref)
    again, _ = ckpt.restore(str(tmp_path), ref, step=2)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(again)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
