"""Hypothesis property tests for int8 TT-core quantization.

Searches the (shape, rank, seed, magnitude) space for violations of the
two quantization invariants that the deterministic grid in
``test_quant_cores.py`` spot-checks:

  * round-trip: per element |dequant(quant(G)) − G| ≤ scale/2, at any
    core magnitude (including the all-zero guard path);
  * chain growth: the measured relative chain error stays below the
    first-order ``chain_error_bound``, which itself grows ~linearly in d.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_quant_cores import (check_chain_error_growth,  # noqa: E402
                              check_roundtrip_property)


@st.composite
def chain_case(draw):
    d = draw(st.integers(min_value=2, max_value=4))
    ms = tuple(draw(st.sampled_from([2, 4, 8])) for _ in range(d))
    ns = tuple(draw(st.sampled_from([2, 4, 8])) for _ in range(d))
    rank = draw(st.sampled_from([2, 4, 8]))
    seed = draw(st.integers(min_value=0, max_value=2 ** 16))
    mag = draw(st.sampled_from([1e-3, 1.0, 1e3]))
    return ms, ns, rank, seed, mag


@settings(max_examples=25, deadline=None)
@given(chain_case())
def test_roundtrip_property(case):
    check_roundtrip_property(*case)


@settings(max_examples=20, deadline=None)
@given(chain_case())
def test_chain_error_growth_bounded_in_d(case):
    check_chain_error_growth(*case)
