"""Plan-compile-execute pipeline (kernels.plan, DESIGN.md §10).

Covers the plan-resolution contract: deterministic re-resolution, JSON
serialize/deserialize roundtrips, every concrete backend reachable from
``auto`` on some shape/dtype, the legacy string-spec shim compiling to
plans identical to explicit kwargs, malformed-spec rejection, the
versioned autotune cache (stale entries ignored, whole plans persisted),
and the serving contract: model build resolves each TT layer's plan
exactly once — a scheduler decode run performs ZERO re-planning.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.configs.shapes import concrete_batch
from repro.core.tt import make_plan, tt_init
from repro.kernels import autotune, plan as ttplan
from repro.kernels.ops import BACKENDS, tt_forward
from repro.kernels.plan import (PlanBook, TTExecutionPlan, plan_tt_forward,
                                resolve_plan)
from repro.serving.engine import generate
from repro.serving.scheduler import Request, Scheduler

KEY = jax.random.PRNGKey(0)

# d=3 chain whose fp32 packed cores alone bust the 32 MiB VMEM budget
# (bench_quant's showcase): step-fallback in fp32, fused under int8
BIG = ((32, 32, 4), (4, 32, 32), 128)          # (ms, ns, rank)
SMALL3 = ((8, 4, 4), (4, 4, 8), 4)


def _chain(ms, ns, rank):
    tp = make_plan(ms, ns, rank)
    return tp.ns, tp.ms, tp.ranks


# ---------------------------------------------------------------------------
# Resolution determinism + serialization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["auto", "pallas_step", "xla"])
def test_same_inputs_resolve_identical_plan(tmp_path, backend):
    ns, ms, ranks = _chain(*SMALL3)
    kw = dict(batch=16, dtype=jnp.float32, backend=backend, tune="off")
    p1 = plan_tt_forward(ns, ms, ranks, **kw)
    p2 = plan_tt_forward(ns, ms, ranks, **kw)
    assert p1 == p2
    # the memoized resolver returns the same OBJECT without re-resolving
    n0 = ttplan.plan_resolutions()
    m1 = resolve_plan(ns, ms, ranks, **kw)
    n1 = ttplan.plan_resolutions()
    m2 = resolve_plan(ns, ms, ranks, **kw)
    assert m1 is m2 and ttplan.plan_resolutions() == n1 > n0


@pytest.mark.parametrize("backend", ["auto", "pallas_step", "xla"])
def test_plan_json_roundtrip(backend):
    ns, ms, ranks = _chain(*SMALL3)
    p = plan_tt_forward(ns, ms, ranks, batch=16, backend=backend,
                        tune="off")
    rt = TTExecutionPlan.from_json_dict(p.to_json_dict())
    assert rt == p
    # through an actual JSON string (the cache file format)
    rt2 = TTExecutionPlan.from_json_dict(json.loads(
        json.dumps(p.to_json_dict())))
    assert rt2 == p


def test_json_rejects_unknown_schema():
    ns, ms, ranks = _chain(*SMALL3)
    obj = plan_tt_forward(ns, ms, ranks, tune="off").to_json_dict()
    obj["schema"] = 999
    with pytest.raises(ValueError, match="schema"):
        TTExecutionPlan.from_json_dict(obj)


# ---------------------------------------------------------------------------
# auto routing: every concrete backend reachable
# ---------------------------------------------------------------------------

def test_every_backend_reachable_from_auto():
    got = {}
    # d=1: a single core is a plain matmul — XLA
    got["xla"] = plan_tt_forward((4,), (8,), (1, 1), backend="auto")
    # d=2 → the fused2 fast path
    ns, ms, ranks = _chain((16, 8), (4, 16), 8)
    got["pallas_fused2"] = plan_tt_forward(ns, ms, ranks, backend="auto")
    # small d=3, VMEM-resident → fused chain
    ns, ms, ranks = _chain(*SMALL3)
    got["pallas_fused"] = plan_tt_forward(ns, ms, ranks, backend="auto")
    # huge d=3 in fp32 → step fallback
    ns, ms, ranks = _chain(*BIG)
    got["pallas_step"] = plan_tt_forward(ns, ms, ranks, backend="auto")
    for want, p in got.items():
        assert p.backend == want, (want, p.describe())
        assert p.requested == "auto"
    concrete = set(BACKENDS) - {"auto"}
    assert {p.backend for p in got.values()} == concrete
    # the int8 twin of the huge chain re-enters the fused set (DESIGN.md §8)
    p8 = plan_tt_forward(ns, ms, ranks, backend="auto", weights="int8")
    assert p8.backend == "pallas_fused" and p8.fused_eligible
    assert not got["pallas_step"].fused_eligible


def test_fit_verdict_is_priced():
    ns, ms, ranks = _chain(*BIG)
    fp = plan_tt_forward(ns, ms, ranks, backend="auto")
    q = plan_tt_forward(ns, ms, ranks, backend="auto", weights="int8")
    assert fp.fit_weight_bytes == 4 * q.fit_weight_bytes
    assert fp.fit_peak_state_bytes == q.fit_peak_state_bytes > 0


# ---------------------------------------------------------------------------
# String-spec shim
# ---------------------------------------------------------------------------

def test_string_shim_produces_identical_plans():
    ns, ms, ranks = _chain(*SMALL3)
    explicit = plan_tt_forward(ns, ms, ranks, batch=16,
                               backend="auto", tune="off", weights="int8")
    via_spec = plan_tt_forward(ns, ms, ranks, batch=16,
                               backend="auto:off:int8")
    assert via_spec == explicit


def test_string_shim_tt_forward_matches_plan_path():
    tp = make_plan(*SMALL3)
    cores = tt_init(KEY, tp)
    x = jax.random.normal(jax.random.PRNGKey(1), (9, tp.N))
    plan = plan_tt_forward(tp.ns, tp.ms, tp.ranks, batch=9, tune="off")
    y_plan = tt_forward(cores, x, plan=plan, interpret=True)
    with pytest.deprecated_call():
        y_str = tt_forward(cores, x, backend="auto:off", interpret=True)
    np.testing.assert_array_equal(np.asarray(y_plan), np.asarray(y_str))


@pytest.mark.parametrize("spec", ["xla::int8", "xla:", ":int8", "auto::",
                                  "pallas_step:cached:"])
def test_malformed_specs_with_empty_tokens_rejected(spec):
    with pytest.raises(ValueError, match="empty token"):
        ttplan.compile_spec(spec)


def test_spec_errors_list_all_valid_tokens():
    """The rejection message names every token class in one place."""
    for spec in ("xla::", "auto:bogus", "nonsense"):
        with pytest.raises(ValueError) as ei:
            ttplan.compile_spec(spec)
        msg = str(ei.value)
        for frag in ("backends", "tune modes", "weight modes"):
            assert frag in msg, (spec, msg)


def test_tt_forward_rejects_mismatched_plan():
    tp = make_plan(*SMALL3)
    cores = tt_init(KEY, tp)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, tp.N))
    other = plan_tt_forward(*_chain((16, 8), (4, 16), 8), tune="off")
    with pytest.raises(ValueError, match="plan/chain mismatch"):
        tt_forward(cores, x, plan=other, interpret=True)
    good = plan_tt_forward(tp.ns, tp.ms, tp.ranks, tune="off")
    with pytest.raises(ValueError, match="conflicts with the plan"):
        tt_forward(cores, x, plan=good, weights="int8", interpret=True)


# ---------------------------------------------------------------------------
# Versioned autotune cache
# ---------------------------------------------------------------------------

def test_stale_cache_entries_silently_ignored(tmp_path):
    """Entries without a schema field (pre-plan caches), with a stale
    schema, or in unknown formats must be dropped at load — never crash,
    never served."""
    path = tmp_path / "tune.json"
    path.write_text(json.dumps({
        "legacy|no-schema": {"block_b": 512},
        "stale|old-schema": {"schema": 0, "block_b": 256},
        "weird|not-a-dict": [1, 2, 3],
        "ok|current": {"schema": autotune.CACHE_SCHEMA, "block_b": 64},
    }))
    cache = autotune.AutotuneCache.load(str(path))
    assert set(cache.entries) == {"ok|current"}
    # a garbage file (not even a dict) is an empty cache, not a crash
    path.write_text(json.dumps([1, 2]))
    assert autotune.AutotuneCache.load(str(path)).entries == {}


def test_put_stamps_schema(tmp_path):
    cache = autotune.AutotuneCache.load(str(tmp_path / "t.json"))
    cache.put("k", {"block_b": 8})
    on_disk = json.loads((tmp_path / "t.json").read_text())
    assert on_disk["k"]["schema"] == autotune.CACHE_SCHEMA


def test_measure_mode_persists_whole_plan(tmp_path):
    """tune='measure' stores the WHOLE resolved plan (versioned JSON);
    a later cached-mode resolution deserializes it — identical plan, zero
    new measurements, zero analytic re-derivation."""
    cache = str(tmp_path / "tune.json")
    ns, ms, ranks = _chain(*SMALL3)
    p1 = plan_tt_forward(ns, ms, ranks, batch=16, backend="auto",
                         tune="measure", interpret=True, cache_path=cache)
    assert p1.source == "measured"
    entries = json.loads((tmp_path / "tune.json").read_text())
    pkeys = [k for k in entries if k.startswith("plan.auto|")]
    assert len(pkeys) == 1 and entries[pkeys[0]]["kind"] == "plan"
    autotune.clear_memory_caches()          # force the disk round-trip
    n = autotune.N_MEASUREMENTS
    p2 = plan_tt_forward(ns, ms, ranks, batch=16, backend="auto",
                         tune="cached", interpret=True, cache_path=cache)
    assert p2 == p1
    assert autotune.N_MEASUREMENTS == n, "cached plan hit must not re-time"


# ---------------------------------------------------------------------------
# PlanBook + serving: build-time resolution, zero re-planning
# ---------------------------------------------------------------------------

def _tt_model(backend="auto"):
    cfg = get_config("deepseek_7b", "smoke",
                     tt=TTConfig(enabled=True, families=("ffn", "attn"),
                                 rank=4, min_factor=2, backend=backend))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_planbook_primes_all_tt_layers():
    cfg, model, params = _tt_model()
    n0 = ttplan.plan_resolutions()
    book = model.plan_book
    assert len(book) > 0
    assert ttplan.plan_resolutions() > n0
    for p in book.plans.values():
        assert p.backend in ("xla", "pallas_step", "pallas_fused2",
                             "pallas_fused")
        assert p.requested == "auto"
    # the book is built exactly once per model
    assert model.plan_book is book


def test_scheduler_decode_performs_zero_replanning():
    """The acceptance counter: after model build + one warm-up request,
    a continuous-batching run over NEW requests (including new prompt
    lengths, which retrace prefill) resolves ZERO plans."""
    cfg, model, params = _tt_model()
    sched = Scheduler(model, params, num_slots=2, cache_len=24)
    warm = concrete_batch(cfg, 1, 6)
    sched.submit(Request(uid=-1, inputs={"tokens": warm["tokens"]},
                         max_new_tokens=3))
    sched.run()
    n0 = ttplan.plan_resolutions()
    for uid, S in enumerate((6, 9, 4)):     # 9 and 4 are NEW prefill shapes
        b = concrete_batch(cfg, 1, S, seed=uid)
        sched.submit(Request(uid=uid, inputs={"tokens": b["tokens"]},
                             max_new_tokens=4))
    out = sched.run()
    assert len(out) == 3
    assert ttplan.plan_resolutions() == n0, \
        "serving must execute build-time plans only (zero re-planning)"


def test_quantized_params_served_with_int8_plans_once():
    """Quantizing a checkpoint introduces each layer's int8 twin plan —
    resolved once on first use, then never again."""
    cfg, model, params = _tt_model()
    qparams = model.quantize_params(params)
    batch = dict(concrete_batch(cfg, 2, 6), cache_len=12)
    r1 = generate(model, qparams, batch, steps=3)
    n0 = ttplan.plan_resolutions()
    r2 = generate(model, qparams, batch, steps=3)
    assert ttplan.plan_resolutions() == n0
    np.testing.assert_array_equal(np.asarray(r1.tokens),
                                  np.asarray(r2.tokens))
    # int8 storage forced int8 plans through the same book
    assert any(p.weights == "int8" for p in model.plan_book.plans.values())
