"""The DSE study engine (core/study.py, DESIGN.md §12): persistence,
seeded resume determinism, activation-aware scoring, the calibration tap,
and the rank-adaptive TT finetune (training/finetune.py)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dse import DSEConfig, generate_candidates
from repro.core.study import (EvaluatorConfig, STUDY_SCHEMA, Study,
                              activation_score, make_model_evaluator,
                              solution_from_plan, trial_seed)
from repro.core.tt import make_plan, tt_reconstruct

DSE = DSEConfig(vl=4, rank_step=4, rank_cap=8, max_d=3, min_factor=2,
                weight_dtypes=("fp32", "int8"))


def stub_evaluate(sol, seed=0):
    """Deterministic fake trial: metrics are a pure function of the
    (solution, seed) pair, like the real evaluator."""
    h = (sol.flops * 31 + seed) % 997
    return {"act_err": h / 997.0, "ppl_delta": sol.plan.d + h / 997.0,
            "tok_s": 1000.0 - sol.flops / 100.0}


# ---------------------------------------------------------------------------
# Study engine
# ---------------------------------------------------------------------------

def test_study_create_persists_static_sorted_trials(tmp_path):
    p = str(tmp_path / "study.json")
    st = Study.create(p, 128, 64, DSE, seed=3, max_trials=5)
    assert os.path.exists(p)
    assert len(st.trials) == 5
    flops = [t.solution.flops for t in st.trials]
    assert flops == sorted(flops)
    assert all(t.status == "pending" for t in st.trials)
    assert [t.seed for t in st.trials] == \
        [trial_seed(3, i) for i in range(5)]
    with pytest.raises(FileExistsError):
        Study.create(p, 128, 64, DSE)


def test_study_refuses_unknown_schema(tmp_path):
    p = str(tmp_path / "study.json")
    with open(p, "w") as f:
        json.dump({"schema": STUDY_SCHEMA + 41, "trials": []}, f)
    with pytest.raises(ValueError, match="schema"):
        Study.load(p)


def test_study_run_and_reload_roundtrip(tmp_path):
    p = str(tmp_path / "study.json")
    st = Study.create(p, 128, 64, DSE, seed=0, max_trials=4)
    n = st.run(stub_evaluate, batch_size=2)
    assert n == 4 and not st.pending()
    again = Study.load(p, DSE)
    assert [(t.tid, t.status, t.metrics) for t in again.trials] == \
        [(t.tid, t.status, t.metrics) for t in st.trials]
    assert [t.tid for t in again.ranking()] == \
        [t.tid for t in st.ranking()]


def test_study_interrupted_resume_is_deterministic(tmp_path):
    """The ISSUE 7 acceptance contract: interrupt after trial k, resume
    from persisted state → identical final ranking and metrics."""
    pa, pb = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    ref = Study.create(pa, 128, 64, DSE, seed=7, max_trials=4)
    ref.run(stub_evaluate, batch_size=4)

    interrupted = Study.create(pb, 128, 64, DSE, seed=7, max_trials=4)
    interrupted.run(stub_evaluate, batch_size=1, max_trials=2)
    del interrupted
    resumed = Study.load(pb, DSE)
    assert len(resumed.completed()) == 2
    resumed.run(stub_evaluate, batch_size=2)
    assert [(t.tid, t.metrics) for t in resumed.trials] == \
        [(t.tid, t.metrics) for t in ref.trials]
    assert [t.tid for t in resumed.ranking()] == \
        [t.tid for t in ref.ranking()]


def test_study_failed_trial_is_contained(tmp_path):
    def flaky(sol, seed=0):
        if sol.weight_dtype == "int8":
            raise RuntimeError("int8 eval exploded")
        return stub_evaluate(sol, seed)

    p = str(tmp_path / "study.json")
    st = Study.create(p, 128, 64, DSE, seed=0, max_trials=4)
    st.run(flaky, batch_size=2)
    failed = [t for t in st.trials if t.status == "failed"]
    done = st.completed()
    assert failed and done
    assert all("int8 eval exploded" in t.metrics["error"] for t in failed)
    # failed trials never enter rankings or the result front
    assert all(t.status == "done" for t in st.ranking())
    assert len(st.result().solutions) == len(done)


def test_study_atomic_save_leaves_no_temp_files(tmp_path):
    p = str(tmp_path / "study.json")
    st = Study.create(p, 128, 64, DSE, max_trials=2)
    st.run(stub_evaluate, batch_size=1)
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []


def test_solution_from_plan_prices_like_generator():
    """Load-path pricing must agree with generate_candidates exactly —
    otherwise a study's static costs drift from the funnel's."""
    for sol in list(generate_candidates(128, 64, DSE))[:8]:
        rebuilt = solution_from_plan(sol.plan.ms, sol.plan.ns,
                                     sol.plan.ranks, sol.weight_dtype,
                                     DSE)
        assert (rebuilt.flops, rebuilt.params, rebuilt.bytes,
                rebuilt.err_proxy, rebuilt.threads) == \
            (sol.flops, sol.params, sol.bytes, sol.err_proxy, sol.threads)


def test_trial_seed_is_pure_and_spread():
    seeds = [trial_seed(5, i) for i in range(50)]
    assert seeds == [trial_seed(5, i) for i in range(50)]
    assert len(set(seeds)) == 50
    assert seeds != [trial_seed(6, i) for i in range(50)]


# ---------------------------------------------------------------------------
# Activation-aware scoring
# ---------------------------------------------------------------------------

def test_activation_score_zero_at_full_rank():
    rng = np.random.default_rng(0)
    W = rng.normal(size=(16, 16))
    plan = make_plan((4, 4), (4, 4), 16)   # ranks clip to exact
    sigma = np.eye(16)
    assert activation_score(W, plan, sigma) < 1e-5   # fp32 SVD residual
    # int8 round-trip adds real quantization error on the same plan
    assert activation_score(W, plan, sigma, "int8") > 1e-4


def test_activation_score_identity_sigma_is_frobenius():
    rng = np.random.default_rng(1)
    W = rng.normal(size=(16, 16))
    plan = make_plan((4, 4), (4, 4), 2)    # lossy
    got = activation_score(W, plan, np.eye(16))
    from repro.core.tt import tt_decompose
    W_hat = np.asarray(tt_reconstruct(
        [np.asarray(c, np.float64) for c in tt_decompose(W, plan)]))
    want = np.linalg.norm(W - W_hat) / np.linalg.norm(W)
    assert got == pytest.approx(want, rel=1e-6)


def test_activation_score_weighs_by_input_covariance():
    """Error that lives in a direction the data never excites must not
    count; error aligned with the dominant input direction must."""
    rng = np.random.default_rng(2)
    W = rng.normal(size=(8, 8))
    plan = make_plan((4, 2), (2, 4), 2)
    # data concentrated on the first input coordinate vs the last
    e = np.zeros((8, 8))
    sig_a, sig_b = e.copy(), e.copy()
    sig_a[0, 0] = 1.0
    sig_b[7, 7] = 1.0
    s_a = activation_score(W, plan, sig_a)
    s_b = activation_score(W, plan, sig_b)
    assert s_a != pytest.approx(s_b, rel=1e-3)  # data-dependence is real
    with pytest.raises(ValueError, match="shape"):
        activation_score(W[:4], plan, sig_a)


def test_capture_activation_stats_tap():
    """The linear_apply tap must stream exact Gram sums, keyed by
    projection signature, aggregated across calls — and stay inert when
    no capture is active."""
    from repro.models.layers import capture_activation_stats, linear_apply

    w = jnp.asarray(np.random.default_rng(3).normal(size=(6, 10)),
                    jnp.float32)
    x1 = jnp.asarray(np.random.default_rng(4).normal(size=(2, 5, 6)),
                     jnp.float32)
    x2 = jnp.asarray(np.random.default_rng(5).normal(size=(3, 6)),
                     jnp.float32)
    with capture_activation_stats() as store:
        linear_apply({"w": w}, x1)
        linear_apply({"w": w}, x2)
        jax.effects_barrier()
    assert set(store) == {(6, 10)}
    flat = np.concatenate([np.asarray(x1).reshape(-1, 6),
                           np.asarray(x2).reshape(-1, 6)])
    np.testing.assert_allclose(store[(6, 10)]["gram"], flat.T @ flat,
                               rtol=1e-5)
    assert store[(6, 10)]["count"] == flat.shape[0]
    # no active capture → no accumulation, no error
    linear_apply({"w": w}, x2)


def test_capture_tap_sums_vmap_batches():
    from repro.models.layers import capture_activation_stats, linear_apply

    w = jnp.ones((4, 3), jnp.float32)
    xs = jnp.asarray(np.random.default_rng(6).normal(size=(5, 2, 4)),
                     jnp.float32)
    with capture_activation_stats() as store:
        jax.vmap(lambda x: linear_apply({"w": w}, x))(xs)
        jax.effects_barrier()
    flat = np.asarray(xs).reshape(-1, 4)
    np.testing.assert_allclose(store[(4, 3)]["gram"], flat.T @ flat,
                               rtol=1e-5)
    assert store[(4, 3)]["count"] == 10


def test_calibration_batches_deterministic_and_disjoint():
    from repro.configs import get_config
    from repro.data.pipeline import calibration_batches

    cfg = get_config("deepseek-7b", "smoke")
    a = calibration_batches(cfg, 2, 16, 3)
    b = calibration_batches(cfg, 2, 16, 3)
    for ba, bb in zip(a, b):
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    c = calibration_batches(cfg, 2, 16, 3, seed=1234)  # training seed
    assert any(not np.array_equal(x["tokens"], y["tokens"])
               for x, y in zip(a, c))


# ---------------------------------------------------------------------------
# TT finetune (training/finetune.py)
# ---------------------------------------------------------------------------

def _tt_model_and_params(seed=0):
    from repro.configs import get_config
    from repro.configs.base import TTConfig
    import dataclasses as dc

    cfg = get_config("deepseek-7b", "smoke")
    cfg = dc.replace(cfg, tt=TTConfig(enabled=True, families=("ffn",),
                                      rank=4, min_factor=2))
    from repro.configs import build
    model = build(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def test_split_merge_tt_roundtrip():
    from repro.training.finetune import merge_tt, split_tt

    _, params = _tt_model_and_params()
    tt, rest = split_tt(params)
    assert jax.tree.leaves(tt), "smoke TT model must have TT bundles"
    # no leaf appears on both sides, and the merge is the identity
    merged = merge_tt(tt, rest)
    ref_leaves = jax.tree.leaves(params)
    out_leaves = jax.tree.leaves(merged)
    assert len(ref_leaves) == len(out_leaves)
    for a, b in zip(ref_leaves, out_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(jax.tree.leaves(tt)) + len(jax.tree.leaves(rest)) == \
        len(ref_leaves)


def test_finetune_raises_on_dense_tree():
    from repro.configs import build, get_config
    from repro.training.finetune import FinetuneConfig, finetune_tt

    import dataclasses as dc
    cfg = get_config("deepseek-7b", "smoke")
    cfg = dc.replace(cfg, tt=dc.replace(cfg.tt, enabled=False))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="no TT core bundles"):
        finetune_tt(model, params, [], FinetuneConfig(steps=1))


def test_tt_params_from_dense_full_rank_reconstructs():
    """At exact (clipped-to-full) ranks the decompose-init twin must
    reproduce the dense weight bit-for-bit up to SVD tolerance."""
    from repro.core.tt import tt_decompose
    from repro.training.finetune import tt_params_from_dense

    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(size=(16, 12)), jnp.float32)  # [N, M]
    plan = make_plan((4, 3), (4, 4), 64)       # clips to exact rank
    bundle = {"tt": {f"c{t}": jnp.zeros(s, jnp.float32)
                     for t, s in enumerate(plan.core_shapes)}}
    out = tt_params_from_dense({"proj": bundle}, {"proj": {"w": w}})
    cores = [np.asarray(out["proj"]["tt"][f"c{t}"], np.float64)
             for t in range(plan.d)]
    W_hat = np.asarray(tt_reconstruct(cores))
    np.testing.assert_allclose(W_hat, np.asarray(w).T, atol=1e-4)


def test_finetune_trains_cores_only_backbone_frozen():
    from repro.data.pipeline import calibration_batches
    from repro.training.finetune import (FinetuneConfig, finetune_tt,
                                         split_tt)
    from repro.training.optimizer import OptConfig

    model, params = _tt_model_and_params()
    batches = calibration_batches(model.cfg, 2, 16, 2)
    fcfg = FinetuneConfig(steps=4, opt=OptConfig(
        lr=1e-2, warmup_steps=1, total_steps=4, weight_decay=0.0))
    out, history = finetune_tt(model, params, batches, fcfg)
    assert len(history) == 4
    # TT cores moved …
    tt_before, rest_before = split_tt(params)
    tt_after, rest_after = split_tt(out)
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(tt_before),
                                jax.tree.leaves(tt_after)))
    assert moved, "finetune must update TT cores"
    # … and the backbone did NOT (the tree-split freeze contract: no
    # grads, no optimizer state, no weight decay on frozen leaves)
    for a, b in zip(jax.tree.leaves(rest_before),
                    jax.tree.leaves(rest_after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# End-to-end: the model evaluator through a tiny study
# ---------------------------------------------------------------------------

def test_model_evaluator_study_end_to_end(tmp_path):
    """Two real trials on the smoke model: activation score + perplexity
    delta through the frozen-plan TT twin, zero plan re-resolutions, and
    persisted-state resume equality."""
    from repro.configs import get_config

    cfg = get_config("deepseek-7b", "smoke")
    ecfg = EvaluatorConfig(n_calib=1, n_eval=1, batch=2, seq=16,
                           measure_tok_s=False)
    evaluate = make_model_evaluator(cfg, ecfg, seed=0)
    p = str(tmp_path / "study.json")
    st = Study.create(p, cfg.d_ff, cfg.d_model, DSE, seed=0,
                      max_trials=2)
    st.run(evaluate, batch_size=1)
    assert {t.status for t in st.trials} == {"done"}
    for t in st.trials:
        assert t.metrics["plan_resolutions"] == 0
        assert 0.0 < t.metrics["act_err"] < 2.0
        assert np.isfinite(t.metrics["ppl_delta"])
    # int8 twin of the same plan must score worse on the data-aware axis
    by = {t.solution.weight_dtype: t.metrics for t in st.trials
          if t.solution.plan == st.trials[0].solution.plan}
    if {"fp32", "int8"} <= set(by):
        assert by["int8"]["act_err"] >= by["fp32"]["act_err"]
    # a fresh evaluator re-derives identical measurements (the resume
    # contract end-to-end, not just for the stub)
    again = make_model_evaluator(cfg, ecfg, seed=0)
    t0 = st.trials[0]
    redo = again(t0.solution, t0.seed)
    assert redo["ppl_delta"] == pytest.approx(
        t0.metrics["ppl_delta"], abs=1e-9)
    assert redo["act_err"] == pytest.approx(
        t0.metrics["act_err"], abs=1e-12)
