"""End-to-end driver: train a TT-compressed LM on the synthetic pipeline,
then serve the trained model through the plan-compiled autotuned path.

Presets:
  tiny  (default)  ~0.5M params, 100 steps — finishes in ~1 min on CPU
  100m             ~100M params, 300 steps — the brief's end-to-end run

Both train a deepseek-7b-family decoder with the paper's technique on the
FFN projections, checkpointing every 50 steps (kill it mid-run and rerun:
it resumes bit-identically).  Training runs the XLA plan path (Pallas
kernels have no autodiff rule); the post-train serving step rebuilds the
model with ``backend='auto'`` so decoding executes the resolved
fused/step Pallas plans (DESIGN.md §10) on the trained weights.

    PYTHONPATH=src python examples/train_tt_lm.py --preset tiny
    PYTHONPATH=src python examples/train_tt_lm.py --preset 100m
"""
import argparse
import dataclasses
import sys

from repro.configs import get_config
from repro.configs.base import ModelConfig, TTConfig
from repro.launch import train as train_cli


def preset_cfg(preset: str) -> list[str]:
    if preset == "tiny":
        return ["--arch", "deepseek-7b", "--variant", "smoke",
                "--steps", "100", "--batch", "8", "--seq", "64",
                "--lr", "3e-3", "--tt", "ffn", "--tt-rank", "4",
                "--ckpt-dir", "/tmp/tt_lm_tiny"]
    if preset == "100m":
        # ~100M params: register a scaled config on the fly
        import repro.configs.deepseek_7b as ds
        base = ds.SMOKE
        ds.SMOKE = dataclasses.replace(
            base, name="deepseek-100m", num_layers=8, d_model=512,
            num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=50257,
            tt=TTConfig(enabled=True, families=("ffn",), rank=16,
                        min_factor=2))
        return ["--arch", "deepseek-7b", "--variant", "smoke",
                "--steps", "300", "--batch", "8", "--seq", "256",
                "--lr", "1e-3", "--micro-batches", "2",
                "--ckpt-dir", "/tmp/tt_lm_100m", "--save-every", "50"]
    raise SystemExit(f"unknown preset {preset}")


def serve_trained(out, steps: int = 8) -> None:
    """Decode a few tokens from the trained weights through the
    plan-compiled ``auto`` backend: the rebuilt model resolves every TT
    layer's execution plan once at build time (Model.plan_book) and the
    engine executes those plans — the autotuned serving path, not the
    bare-string ``backend='xla'`` one."""
    import jax
    from repro.configs.shapes import concrete_batch
    from repro.kernels import plan as ttplan
    from repro.models.model import Model
    from repro.serving.engine import generate

    trained = out["model"]
    serve_cfg = dataclasses.replace(
        trained.cfg, tt=dataclasses.replace(trained.cfg.tt, backend="auto"))
    model = Model(serve_cfg, trained.groups, trained.enc_groups,
                  trained.param_dtype)
    n0 = ttplan.plan_resolutions()
    batch = dict(concrete_batch(serve_cfg, 2, 16), cache_len=16 + steps)
    res = generate(model, out["trained_params"], batch, steps=steps,
                   key=jax.random.PRNGKey(0))
    plans = model.plan_book.plans
    print(f"serving via {len(plans)} resolved plan(s) "
          f"({ttplan.plan_resolutions() - n0} resolutions):")
    for p in plans.values():
        print("  ", p.describe())
    print("decoded tokens[0]:", res.tokens[0].tolist())


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    args = ap.parse_args()
    out = train_cli.main(preset_cfg(args.preset))
    if out.get("steps_run", 0) > 0:
        print(f"preset={args.preset} params={out['params']/1e6:.1f}M "
              f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f}")
    else:
        # resumed against a finished checkpoint: no new steps, no losses
        print(f"preset={args.preset} params={out['params']/1e6:.1f}M "
              f"(checkpoint already at the final step — nothing to train)")
    # A resumed segment can be a few noisy steps — only gate fresh runs
    # with enough steps to see the trend (a full fresh 300-step 100m run
    # goes ~10.8 → 9.6 on the synthetic stream).
    if out.get("steps_run", 0) >= 50:
        assert out["final_loss"] < out["first_loss"], "loss did not improve"
    else:
        print(f"(resumed segment of {out.get('steps_run', 0)} steps — "
              "trend gate skipped)")
    serve_trained(out)
