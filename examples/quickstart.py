"""Quickstart: the paper's pipeline on one FC layer, end to end.

1. Run the DSE (alignment → vectorization → initial-layer → scalability)
   for an AlexNet-sized FC layer.
2. Pick a surviving factorization, TT-decompose a trained weight matrix.
3. Compile each backend choice into a resolved ``TTExecutionPlan``
   (the plan-compile-execute pipeline, DESIGN.md §10) and check all
   executors agree — including the autotuned ``auto`` routing.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dse import DSEConfig, explore
from repro.core.flops import dense_flops, dense_params
from repro.core.tt import make_plan, tt_apply, tt_decompose
from repro.kernels.ops import tt_forward
from repro.kernels.plan import plan_tt_forward

M, N = 1000, 2048                       # ResNet final FC (paper §6.4)

# --- 1. design-space exploration ------------------------------------------
res = explore(M, N, DSEConfig(vl=8, rank_step=8, rank_cap=64))
print(f"FC [{N} -> {M}]  dense: {dense_params(M, N):,} params, "
      f"{dense_flops(M, N):,} FLOPs")
print(f"DS counts: {res.counts['all_initial']:.1e} initial -> "
      f"{res.counts['aligned']:.1e} aligned -> "
      f"{res.counts['vectorized']:.1e} vectorized -> "
      f"{res.counts['initial_layer']} -> {res.counts['scalability']} "
      f"survivors")
print("\ntop-5 solutions by FLOPs:")
for s in res.solutions[:5]:
    print("  ", s.plan.describe(), "threads:", s.threads)

# --- 2. decompose a 'trained' weight matrix --------------------------------
# A random dense W is full-rank — truncated TT-SVD approximates it, exact
# TT-SVD (rank = the unfolding bound, here 640) reproduces it.  Real trained
# weights have decaying spectra, which is why the paper fine-tunes.
rng = np.random.default_rng(0)
W = rng.standard_normal((M, N)).astype(np.float32) / np.sqrt(N)
x = jnp.asarray(rng.standard_normal((4, N)).astype(np.float32))
y_ref = x @ W.T
for rank in (64, 640):
    plan = make_plan((100, 10), (32, 64), rank)   # paper's §6.4 shape
    cores = [jnp.asarray(c) for c in tt_decompose(W, plan)]
    err = float(jnp.linalg.norm(tt_apply(cores, x) - y_ref)
                / jnp.linalg.norm(y_ref))
    kind = "exact" if plan.ranks[1] == 640 else "truncated"
    print(f"TT-SVD rank {plan.ranks[1]:4d} ({kind}): "
          f"rel ‖TT(x) − Wx‖ = {err:.2e}")

# --- 3. plan-compile-execute: resolve once, execute everywhere -------------
# Each backend choice is compiled ONCE into a TTExecutionPlan (routing,
# VMEM fit verdict, block/tile selection, autotune lookup all happen
# here); tt_forward(plan=...) is then a pure executor — this is what the
# model stack does per layer at build time.
B = x.shape[0]
plans = {b: plan_tt_forward(plan.ns, plan.ms, plan.ranks, batch=B,
                            backend=b, interpret=True)
         for b in ("xla", "pallas_step", "pallas_fused2", "auto")}
for name, p in plans.items():
    print(f"  {name:14s} -> {p.describe()}")
y_xla = tt_forward(cores, x, plan=plans["xla"])
y_step = tt_forward(cores, x, plan=plans["pallas_step"], interpret=True)
y_fused = tt_forward(cores, x, plan=plans["pallas_fused2"], interpret=True)
y_auto = tt_forward(cores, x, plan=plans["auto"], interpret=True)
assert plans["auto"].backend == "pallas_fused2"   # d=2 routes to fused2
print("backend max diffs vs xla:",
      float(jnp.max(jnp.abs(y_step - y_xla))),
      float(jnp.max(jnp.abs(y_fused - y_xla))),
      float(jnp.max(jnp.abs(y_auto - y_xla))))
print("OK")
