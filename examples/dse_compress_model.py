"""The paper's design tool, model-wise: run the layer-level DSE over every
FC projection of an assigned architecture and report the chosen plans +
whole-model compression.

    PYTHONPATH=src python examples/dse_compress_model.py --arch qwen3-32b \
        --rank 16 --families ffn,attn

With ``--calibrate`` the analytic table is followed by a data-aware study
(DESIGN.md §12) of the FFN projection on the smoke twin of the same
architecture: each candidate plan is scored against calibration
activations and measured for end-to-end perplexity delta through a
frozen-plan TT twin — the step that catches statically-cheap plans the
proxy ranking would wrongly crown.

    PYTHONPATH=src python examples/dse_compress_model.py \
        --arch deepseek-7b --calibrate --trials 4
"""
import argparse

from repro.configs import get_config
from repro.core.dse import best_plan
from repro.core.flops import dense_flops, dense_params


def fc_layers_of(cfg):
    """(name, M_out, N_in, family) of every FC projection family."""
    out = []
    d = cfg.d_model
    q = cfg.num_heads * cfg.head_dim
    kv = cfg.num_kv_heads * cfg.head_dim
    out += [("attn.q", q, d, "attn"), ("attn.k", kv, d, "attn"),
            ("attn.v", kv, d, "attn"), ("attn.o", d, q, "attn")]
    ff = cfg.moe.expert_ff if (cfg.moe and cfg.moe.num_experts) else cfg.d_ff
    if ff:
        out += [("ffn.gate", ff, d, "ffn"), ("ffn.up", ff, d, "ffn"),
                ("ffn.down", d, ff, "ffn")]
    out += [("lm_head", cfg.vocab_size, d, "lm_head")]
    return out


def calibrate(args):
    """Data-aware pass: a small study on the smoke twin's FFN shape."""
    import tempfile

    from repro.core.dse import DSEConfig
    from repro.core.study import (EvaluatorConfig, Study,
                                  make_model_evaluator)

    cfg = get_config(args.arch, "smoke")
    M, N = cfg.d_ff, cfg.d_model
    dse = DSEConfig(vl=4, rank_step=4, rank_cap=16, max_d=2, min_factor=2,
                    weight_dtypes=("fp32", "int8"))
    ecfg = EvaluatorConfig(train_steps=40, n_calib=2, n_eval=2,
                           batch=2, seq=32)
    print(f"\ncalibrated study on {cfg.name} smoke twin "
          f"[{N}->{M}], {args.trials} trials:")
    with tempfile.TemporaryDirectory() as tmp:
        study = Study.create(f"{tmp}/study.json", M, N, dse,
                             max_trials=args.trials)
        study.run(make_model_evaluator(cfg, ecfg), batch_size=2)
        print(f"{'plan':46s} {'dtype':5s} {'act_err':>8s} {'ppl_d':>8s}")
        for t in study.ranking():
            print(f"{t.solution.plan.describe():46s} "
                  f"{t.solution.weight_dtype:5s} "
                  f"{t.metrics['act_err']:8.4f} "
                  f"{t.metrics['ppl_delta']:+8.4f}")
        best = study.best()
        cheap = study.trials[0]
        if (best.tid != cheap.tid):
            print(f"-> measured best (tid {best.tid}) is NOT the "
                  f"statically cheapest (tid {cheap.tid}) — the proxy "
                  f"ranking would have picked the wrong plan")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--length", type=int, default=2)
    ap.add_argument("--min-factor", type=int, default=8)
    ap.add_argument("--families", default="ffn,attn,lm_head")
    ap.add_argument("--calibrate", action="store_true",
                    help="follow up with a data-aware study of the FFN "
                         "shape on the smoke twin (DESIGN.md §12)")
    ap.add_argument("--trials", type=int, default=4,
                    help="trials for --calibrate")
    args = ap.parse_args()

    cfg = get_config(args.arch, "full")
    families = set(args.families.split(","))
    print(f"{cfg.name}: d_model={cfg.d_model} layers={cfg.num_layers} "
          f"rank={args.rank} length={args.length}")
    print(f"{'layer':12s} {'shape':>16s} {'plan':>24s} "
          f"{'params_x':>9s} {'flops_x':>8s}")
    tot_dense = tot_tt = 0
    for name, M, N, fam in fc_layers_of(cfg):
        dp = dense_params(M, N, bias=False)
        if fam not in families:
            tot_dense += dp
            tot_tt += dp
            print(f"{name:12s} {f'[{N}->{M}]':>16s} {'(dense)':>24s}")
            continue
        plan = best_plan(M, N, rank=args.rank, length=args.length,
                         min_factor=args.min_factor)
        tot_dense += dp
        if plan is None:
            tot_tt += dp
            print(f"{name:12s} {f'[{N}->{M}]':>16s} {'no survivor':>24s}")
            continue
        tot_tt += plan.params
        desc = f"{'x'.join(map(str, plan.ms))}|{'x'.join(map(str, plan.ns))}"
        print(f"{name:12s} {f'[{N}->{M}]':>16s} {desc:>24s} "
              f"{dp/plan.params:9.1f} {dense_flops(M, N, False)/plan.flops:8.1f}")
    print(f"\nper-layer FC params: {tot_dense:,} -> {tot_tt:,} "
          f"({tot_dense/tot_tt:.1f}x compression of factorized families)")
    if args.calibrate:
        calibrate(args)


if __name__ == "__main__":
    main()
