"""Serve a TT-compressed model through the continuous-batching scheduler:
prompts of *different lengths* are submitted as individual requests — no
left-padding into a rectangular batch — admitted into a fixed slot pool as
slots free up, and retired independently on their own token budgets.

    PYTHONPATH=src python examples/serve_tt_lm.py --arch gemma3-4b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.serving.scheduler import Request, Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--decode", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--paged", action="store_true",
                    help="serve from the block-paged KV pool (DESIGN.md §7)")
    ap.add_argument("--block-size", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, "smoke",
                     tt=TTConfig(enabled=True, families=("ffn",), rank=4,
                                 min_factor=2))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    sched = Scheduler(model, params, num_slots=args.slots,
                      cache_len=args.max_prompt + args.decode,
                      key=jax.random.PRNGKey(1), paged=args.paged,
                      block_size=args.block_size)
    key = jax.random.PRNGKey(2)
    lens = []
    for uid in range(args.requests):
        key, sub = jax.random.split(key)
        S = int(jax.random.randint(sub, (), args.max_prompt // 3,
                                   args.max_prompt + 1))
        key, sub = jax.random.split(key)
        toks = jax.random.randint(sub, (1, S), 0, cfg.vocab_size, jnp.int32)
        sched.submit(Request(uid=uid, inputs={"tokens": toks},
                             max_new_tokens=args.decode,
                             temperature=args.temperature))
        lens.append(S)

    t0 = time.time()
    out = sched.run()
    dt = time.time() - t0
    n = sched.tokens_out
    print(f"{cfg.name}: {args.requests} requests (prompts {lens}) on "
          f"{args.slots} slots -> {n} tokens in {dt:.2f}s "
          f"({n/dt:.1f} tok/s, incl. compile)")
    for uid in sorted(out):
        f = out[uid]
        lp = float(jnp.mean(jnp.asarray(f.logprobs))) if len(f.logprobs) \
            else 0.0
        print(f"req[{uid}] prompt={f.prompt_len:3d} -> "
              f"{f.tokens.tolist()} (mean logprob {lp:.2f}, "
              f"{f.finish_reason})")


if __name__ == "__main__":
    main()
