"""Serve a TT-compressed model with batched requests: prefill a batch of
prompts of *different lengths* (left-padded into one batch), then decode.

    PYTHONPATH=src python examples/serve_tt_lm.py --arch gemma3-4b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.data.pipeline import make_batch
from repro.serving.engine import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--decode", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, "smoke",
                     tt=TTConfig(enabled=True, families=("ffn",), rank=4,
                                 min_factor=2))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batch = make_batch(cfg, args.batch, args.max_prompt, step=0)
    batch = dict(batch, cache_len=args.max_prompt + args.decode)

    t0 = time.time()
    res = generate(model, params, batch, steps=args.decode, temperature=0.8,
                   key=jax.random.PRNGKey(1))
    dt = time.time() - t0
    n = args.batch * args.decode
    print(f"{cfg.name}: {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s, "
          f"incl. compile)")
    for b in range(args.batch):
        print(f"req[{b}] -> {res.tokens[b].tolist()} "
              f"(mean logprob {float(jnp.mean(res.logprobs[b])):.2f})")


if __name__ == "__main__":
    main()
