import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract the roofline terms from the compiled artifact.

The two lines above MUST stay the first statements of this module — jax
locks the device count at first init (brief §MULTI-POD DRY-RUN).

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all            # 40 cells × both meshes
  python -m repro.launch.dryrun --all --mesh single
  python -m repro.launch.dryrun --arch ... --variant tt_r16

Results are written incrementally to results/dryrun/<cell>.json so the sweep
is restartable (already-done cells are skipped unless --force).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis.roofline import (Roofline, collective_bytes_from_hlo,
                                     model_flops_estimate)
from repro.configs import build, get_config
from repro.configs.base import SHAPES, TTConfig, shape_applicable
from repro.configs.shapes import input_specs
from repro.distributed import sharding as shd
from repro.models.spec import abstract_tree, count_params, is_spec
from repro.training.train_loop import TrainConfig, make_train_step
from repro.training.optimizer import OptConfig
from .mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


# ---------------------------------------------------------------------------
# Variants (perf hillclimbing — EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Variant:
    name: str
    tt: TTConfig | None = None          # None → arch default (dense)
    remat: bool = True
    act_rules: dict | None = None       # overrides on the activation rules
    notes: str = ""


VARIANTS = {
    "base": Variant("base", notes="paper-faithful dense baseline"),
    "tt_r16": Variant(
        "tt_r16",
        tt=TTConfig(enabled=True, families=("ffn",), rank=16, length=2,
                    min_factor=8, backend="xla"),
        notes="paper technique: TT(R=16, d=2) on FFN projections"),
    "tt_r16_attn": Variant(
        "tt_r16_attn",
        tt=TTConfig(enabled=True, families=("ffn", "attn"), rank=16,
                    length=2, min_factor=8, backend="xla"),
        notes="TT on FFN + attention projections"),
    "norem": Variant("norem", remat=False,
                     notes="no activation rematerialization"),
    "seqshard": Variant(
        "seqshard",
        act_rules={"act_kv_seq": "model", "act_heads": None},
        notes="decode: shard KV sequence instead of heads"),
    "headshard": Variant(
        "headshard",
        act_rules={"act_kv_seq": None},
        notes="decode: shard heads only, replicate KV sequence"),
}


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------

def abstract_params_sharded(spec_tree, mesh, fsdp: bool):
    shard_tree = shd.param_shardings(spec_tree, mesh, fsdp=fsdp)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        spec_tree, shard_tree, is_leaf=is_spec)


def _batch_sds(specs: dict, mesh) -> dict:
    out = {}
    daxes = shd._resolve_axis(mesh, ("pod", "data"))
    dsize = shd._axis_size(mesh, daxes)
    for name, s in specs.items():
        parts = [daxes if s.shape[0] % dsize == 0 else None]
        parts += [None] * (len(s.shape) - 1)
        sh = jax.sharding.NamedSharding(mesh,
                                        jax.sharding.PartitionSpec(*parts))
        out[name] = jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
    return out


def _cache_sds(cache_tree, mesh, rules: dict) -> dict:
    """Name/shape-aware cache shardings (leading dim is the stacked layer
    axis).  kv heads → model if divisible, else sequence → model."""
    daxes = shd._resolve_axis(mesh, ("pod", "data"))
    dsize = shd._axis_size(mesh, daxes)
    msize = shd._axis_size(mesh, "model")
    seq_over_model = rules.get("act_kv_seq") == "model"

    def one(path, s):
        name = str(getattr(path[-1], "key", ""))
        nd = len(s.shape)
        parts = [None] * nd
        if nd >= 2 and s.shape[1] % dsize == 0 and s.shape[1] >= dsize:
            parts[1] = daxes                             # batch
        if name in ("k", "v", "xk", "xv"):               # [L,B,T,KV,hd]
            if s.shape[3] % msize == 0:
                parts[3] = "model"
            elif seq_over_model and s.shape[2] % msize == 0:
                parts[2] = "model"
        elif name in ("ckv", "krope"):                   # [L,B,T,d]
            if seq_over_model and s.shape[2] % msize == 0:
                parts[2] = "model"
        elif name == "state":                            # [L,B,H,N,P]
            if s.shape[2] % msize == 0:
                parts[2] = "model"
        elif name == "conv":                             # [L,B,K,D]
            if s.shape[3] % msize == 0:
                parts[3] = "model"
        sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(*parts))
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, s) for p, s in flat])


def active_param_count(spec_tree, cfg) -> int:
    """Active parameters per token (MoE experts scaled by (k+shared)/E)."""
    total = 0
    for path, s in jax.tree_util.tree_flatten_with_path(
            spec_tree, is_leaf=is_spec)[0]:
        import numpy as np
        n = int(np.prod(s.shape))
        keys = [str(getattr(p, "key", "")) for p in path]
        if "experts" in keys and cfg.moe:
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------

def _compile_step(cfg, model, shape, mesh, rules, variant):
    """Lower + compile one model instance; return (compiled, lower_s,
    compile_s)."""
    kind = shape.kind
    spec_tree = model.param_specs()
    inputs = input_specs(cfg, shape, model)
    t0 = time.time()
    if kind == "train":
        params_sds = abstract_params_sharded(spec_tree, mesh, fsdp=True)
        state_sds = {
            "params": params_sds,
            "opt": {"m": params_sds, "v": params_sds,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)},
        }
        tcfg = TrainConfig(opt=OptConfig(), remat=variant.remat)
        step = make_train_step(model, tcfg)
        batch_sds = _batch_sds(inputs["batch"], mesh)
        lowered = jax.jit(step, donate_argnums=(0,)).lower(
            state_sds, batch_sds)
    elif kind == "prefill":
        params_sds = abstract_params_sharded(spec_tree, mesh, fsdp=False)
        batch_sds = _batch_sds(inputs["batch"], mesh)
        lowered = jax.jit(model.prefill).lower(params_sds, batch_sds)
    else:  # decode
        params_sds = abstract_params_sharded(spec_tree, mesh, fsdp=False)
        cache_sds = _cache_sds(inputs["cache"], mesh, rules)
        tok_sds = _batch_sds({"t": inputs["token"]}, mesh)["t"]
        lowered = jax.jit(model.decode_step, donate_argnums=(1,)).lower(
            params_sds, cache_sds, tok_sds)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    return compiled, t_lower, time.time() - t0 - t_lower


_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _cost_of(compiled) -> dict:
    ca = compiled.cost_analysis()
    ca = ca if isinstance(ca, dict) else ca[0]
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    counts = {k: hlo.count(f" {k}(") + hlo.count(f" {k}-start(")
              for k in _COLL_KINDS}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll, "coll_counts": counts}


def _combine(base: dict, bumps: dict[str, dict], real: dict[str, int]
             ) -> dict:
    """Linear extrapolation: total = F(all counts=1) + Σ_g (c_g-1)·b_g where
    b_g = F(1+e_g) − F(1).  Exact because per-layer cost is count-invariant."""
    out = {"flops": base["flops"], "bytes": base["bytes"],
           "coll": dict(base["coll"]),
           "coll_counts": dict(base["coll_counts"])}
    for g, bump in bumps.items():
        k = real[g] - 1
        out["flops"] += k * max(bump["flops"] - base["flops"], 0.0)
        out["bytes"] += k * max(bump["bytes"] - base["bytes"], 0.0)
        for kind in set(base["coll"]) | set(bump["coll"]):
            d = max(bump["coll"].get(kind, 0.0)
                    - base["coll"].get(kind, 0.0), 0.0)
            out["coll"][kind] = out["coll"].get(kind, 0.0) + k * d
        for kind in _COLL_KINDS:
            d = max(bump["coll_counts"][kind]
                    - base["coll_counts"][kind], 0)
            out["coll_counts"][kind] = out["coll_counts"].get(kind, 0) + k * d
    out["coll"]["total"] = sum(v for kk, v in out["coll"].items()
                               if kk != "total")
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: Variant) -> dict:
    from repro.models import transformer as tf
    from repro.configs import make_layer_plan

    shape = SHAPES[shape_name]
    cfg = get_config(arch, "full", tt=variant.tt)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = shape.kind
    param_dtype = jnp.float32 if kind == "train" else jnp.bfloat16

    rules = dict(shd.ACT_RULES_TRAIN if kind == "train"
                 else shd.ACT_RULES_DECODE)
    if variant.act_rules:
        rules.update(variant.act_rules)
    shd.set_ctx(shd.ShardCtx(mesh, rules, ("pod", "data")))
    try:
        # ---- 1. the dry-run deliverable: full-depth scanned compile -------
        model = build(cfg, param_dtype=param_dtype)
        spec_tree = model.param_specs()
        n_params = count_params(spec_tree)
        n_active = active_param_count(spec_tree, cfg)
        compiled, t_lower, t_compile = _compile_step(
            cfg, model, shape, mesh, rules, variant)
        ma = compiled.memory_analysis()
        mem = {}
        if ma is not None:
            mem = {k: int(getattr(ma, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "alias_size_in_bytes")}
        del compiled

        # ---- 2. roofline accounting: unrolled reduced-depth compiles ------
        groups, enc = make_layer_plan(cfg)
        real = {f"g{i}": c for i, (_, c) in enumerate(groups)}
        if enc is not None:
            real.update({f"e{i}": c for i, (_, c) in enumerate(enc)})

        def reduced_model(bump_key=None):
            counts = {i: (2 if bump_key == f"g{i}" else 1)
                      for i in range(len(groups))}
            ecounts = ({i: (2 if bump_key == f"e{i}" else 1)
                        for i in range(len(enc))} if enc is not None else None)
            return build(cfg, param_dtype=param_dtype, counts=counts,
                         enc_counts=ecounts)

        tf.SCAN_UNROLL = True
        try:
            c0, _, _ = _compile_step(cfg, reduced_model(), shape, mesh,
                                     rules, variant)
            base_cost = _cost_of(c0)
            del c0
            bumps = {}
            for g, c in real.items():
                if c > 1:
                    cg, _, _ = _compile_step(cfg, reduced_model(g), shape,
                                             mesh, rules, variant)
                    bumps[g] = _cost_of(cg)
                    del cg
        finally:
            tf.SCAN_UNROLL = False
        cost = _combine(base_cost, bumps, real)

        chips = mesh.devices.size
        tokens = (shape.global_batch * shape.seq_len
                  if kind in ("train", "prefill") else shape.global_batch)
        rl = Roofline(
            chips=chips,
            flops_per_device=cost["flops"],
            bytes_per_device=cost["bytes"],
            collective_per_device=cost["coll"].get("total", 0.0),
            model_flops=model_flops_estimate(n_params, n_active, tokens,
                                             kind),
        )
        return {
            "status": "ok",
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "variant": variant.name,
            "chips": chips,
            "num_params": n_params,
            "active_params": n_active,
            "tokens_per_step": tokens,
            "roofline": rl.to_dict(),
            "collective_bytes": cost["coll"],
            "collective_counts": cost["coll_counts"],
            "memory_analysis": mem,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
        }
    finally:
        shd.set_ctx(None)


def cell_path(arch, shape, multi_pod, variant) -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh}__{variant}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.configs import ALIASES, ARCH_IDS
    archs = ([ALIASES.get(args.arch, args.arch)] if args.arch else ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    variant = VARIANTS[args.variant]

    os.makedirs(RESULTS_DIR, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                path = cell_path(arch, shape, mp, variant.name)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") != "failed":
                        print(f"[cached] {path}")
                        continue
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}" \
                      f" × {variant.name}"
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    res = run_cell(arch, shape, mp, variant)
                except Exception as e:
                    res = {"status": "failed", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-3000:]}
                res.setdefault("arch", arch)
                res.setdefault("shape", shape)
                res.setdefault("mesh", "2x16x16" if mp else "16x16")
                res.setdefault("variant", variant.name)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                st = res["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "failed"
                if st == "ok":
                    r = res["roofline"]
                    print(f"  ok: bottleneck={r['bottleneck']} "
                          f"frac={r['roofline_fraction']:.3f} "
                          f"compile={res['compile_s']}s", flush=True)
                else:
                    print(f"  {st}: {res.get('reason', res.get('error'))}",
                          flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")


if __name__ == "__main__":
    main()
