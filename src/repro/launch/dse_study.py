"""DSE study launcher: data-aware trial evaluation over one projection.

Runs the ``core.study`` engine (DESIGN.md §12) for a model's projection
shape: enumerate the funnel's survivors, evaluate each trial end-to-end
(activation-aware score, perplexity delta vs the dense reference through
a frozen-plan TT twin, optional serving tok/s), persist every outcome to
a schema-versioned JSON state file, and print the measured ranking plus
the gated pareto front.  Interrupt it any time — rerunning the same
command resumes from the state file and re-derives identical results.

  PYTHONPATH=src python -m repro.launch.dse_study --arch deepseek-7b \
      --variant smoke --max-trials 8 --measure-tok-s

Smoke mode (CI): a 2-trial study on the smoke config's FFN shape, run
once straight through and once interrupted-after-trial-0 + resumed from
the persisted state — asserts the two produce bit-identical rankings and
metrics (the resume-determinism contract), and that every trial measured
zero plan re-resolutions.

  PYTHONPATH=src python -m repro.launch.dse_study --smoke
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import get_config
from repro.core.dse import DSEConfig, pareto_front
from repro.core.study import (EvaluatorConfig, Study, make_model_evaluator)


def _dse_config(args) -> DSEConfig:
    return DSEConfig(vl=args.vl, rank_step=args.rank_step,
                     rank_cap=args.rank_cap, max_d=args.max_d,
                     min_factor=args.min_factor,
                     weight_dtypes=tuple(args.dtypes.split(",")))


def _trial_rows(study: Study) -> list[dict]:
    return [{"tid": t.tid, "status": t.status,
             "plan": t.solution.plan.describe(),
             "weight_dtype": t.solution.weight_dtype,
             "flops": t.solution.flops, "bytes": t.solution.bytes,
             "err_proxy": t.solution.err_proxy, **t.metrics}
            for t in study.trials]


def run_study(args) -> dict:
    cfg = get_config(args.arch, args.variant)
    M = args.M if args.M else cfg.d_ff
    N = args.N if args.N else cfg.d_model
    dse = _dse_config(args)
    state = args.state or os.path.join(
        "results", f"dse_study_{args.arch}_{M}x{N}.json")
    ecfg = EvaluatorConfig(n_calib=args.calib_batches,
                           n_eval=args.eval_batches,
                           batch=args.calib_batch, seq=args.calib_seq,
                           measure_tok_s=args.measure_tok_s,
                           serve_steps=args.serve_steps,
                           finetune_steps=args.finetune_steps)
    study = Study.open(state, M, N, dse, seed=args.seed,
                       max_trials=args.max_trials)
    print(f"study {state}: [{M}x{N}] {len(study.trials)} trials, "
          f"{len(study.pending())} pending")
    evaluate = make_model_evaluator(cfg, ecfg, seed=args.seed)
    study.run(evaluate, batch_size=args.batch_size, log=print)

    ranked = study.ranking()
    print(f"\n  {'tid':>3} {'plan':<46} {'dtype':<5} {'act_err':>8} "
          f"{'ppl_delta':>9} {'tok/s':>8}")
    for t in ranked:
        print(f"  {t.tid:>3} {t.solution.plan.describe():<46} "
              f"{t.solution.weight_dtype:<5} "
              f"{t.metrics.get('act_err', float('nan')):>8.4f} "
              f"{t.metrics.get('ppl_delta', float('nan')):>9.4f} "
              f"{t.metrics.get('tok_s', float('nan')):>8.1f}")
    res = study.result()
    axes = ("flops", "bytes", "ppl_delta")
    front = pareto_front(res.solutions, axes=axes) if res.solutions else []
    print(f"\nmeasured front over {axes}:")
    for s in front:
        print(f"  {s.plan.describe()} {s.weight_dtype} "
              f"ppl_delta={s.ppl_delta:+.4f}")
    return {"state": state, "trials": _trial_rows(study),
            "front": [s.plan.describe() for s in front]}


def run_smoke(args) -> dict:
    """CI resume-determinism assertion (ISSUE 7 acceptance criterion)."""
    cfg = get_config(args.arch, "smoke")
    M, N = cfg.d_ff, cfg.d_model
    dse = DSEConfig(vl=4, rank_step=4, rank_cap=8, max_d=3, min_factor=2,
                    weight_dtypes=("fp32", "int8"))
    ecfg = EvaluatorConfig(n_calib=1, n_eval=1, batch=2, seq=16,
                           measure_tok_s=False)
    evaluate = make_model_evaluator(cfg, ecfg, seed=args.seed)
    os.makedirs("results", exist_ok=True)
    p_ref = os.path.join("results", "dse_study_smoke_ref.json")
    p_int = os.path.join("results", "dse_study_smoke_resume.json")
    for p in (p_ref, p_int):
        if os.path.exists(p):
            os.unlink(p)

    # uninterrupted reference run
    ref = Study.create(p_ref, M, N, dse, seed=args.seed, max_trials=2)
    ref.run(evaluate, batch_size=2)

    # interrupted run: evaluate trial 0, drop the in-memory object …
    interrupted = Study.create(p_int, M, N, dse, seed=args.seed,
                               max_trials=2)
    interrupted.run(evaluate, batch_size=1, max_trials=1)
    del interrupted
    # … resume purely from the persisted state and finish
    resumed = Study.load(p_int, dse)
    already = len(resumed.completed())
    if already != 1:
        raise AssertionError(f"resume should see exactly 1 completed "
                             f"trial, saw {already}")
    resumed.run(evaluate, batch_size=1)

    def record(study: Study) -> list[tuple]:
        return [(t.tid, t.status, json.dumps(t.metrics, sort_keys=True))
                for t in study.trials]

    if record(ref) != record(resumed):
        raise AssertionError(
            "resume is not deterministic:\n"
            f"  reference: {record(ref)}\n  resumed:   {record(resumed)}")
    ranks_equal = ([t.tid for t in ref.ranking()]
                   == [t.tid for t in resumed.ranking()])
    if not ranks_equal:
        raise AssertionError("resumed ranking differs from reference")
    for t in ref.completed():
        if t.metrics.get("plan_resolutions") != 0:
            raise AssertionError(
                f"trial {t.tid} measured {t.metrics['plan_resolutions']} "
                f"plan re-resolutions (must be 0)")
    print(f"dse-study smoke OK: {len(ref.trials)} trials, "
          f"interrupted-after-1 resume bit-identical, "
          f"0 plan re-resolutions, best tid={ref.best().tid}")
    return {"smoke": "ok", "trials": _trial_rows(ref)}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--variant", default="smoke",
                    choices=["smoke", "full"])
    ap.add_argument("--M", type=int, default=0,
                    help="projection out-dim (default: the arch's d_ff)")
    ap.add_argument("--N", type=int, default=0,
                    help="projection in-dim (default: the arch's d_model)")
    ap.add_argument("--state", default=None,
                    help="study state JSON (default: results/"
                         "dse_study_<arch>_<M>x<N>.json); resumed if "
                         "present")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-trials", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=2,
                    help="trials evaluated in parallel per checkpoint")
    # funnel knobs
    ap.add_argument("--vl", type=int, default=4)
    ap.add_argument("--rank-step", type=int, default=4)
    ap.add_argument("--rank-cap", type=int, default=16)
    ap.add_argument("--max-d", type=int, default=3)
    ap.add_argument("--min-factor", type=int, default=2)
    ap.add_argument("--dtypes", default="fp32,int8")
    # evaluator knobs
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--eval-batches", type=int, default=2)
    ap.add_argument("--calib-batch", type=int, default=2)
    ap.add_argument("--calib-seq", type=int, default=32)
    ap.add_argument("--measure-tok-s", action="store_true",
                    help="measure scheduler decode tok/s per trial")
    ap.add_argument("--serve-steps", type=int, default=16)
    ap.add_argument("--finetune-steps", type=int, default=0,
                    help=">0: rank-adaptive TT-core finetune before the "
                         "perplexity measurement (training/finetune.py)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: 2 trials, interrupted + resumed, "
                         "bit-determinism asserted")
    ap.add_argument("--compile-cache", default=None,
                    help="persistent XLA compilation cache dir (also via "
                         "$REPRO_COMPILE_CACHE): a resumed study re-jits "
                         "none of the trial programs a previous process "
                         "already compiled")
    args = ap.parse_args(argv)
    from .cache import enable_compile_cache
    enable_compile_cache(args.compile_cache)
    if args.smoke:
        return run_smoke(args)
    return run_study(args)


if __name__ == "__main__":
    main()
