"""Persistent XLA compilation cache wiring (ROADMAP item 4, DESIGN.md §13).

A restarted serving process pays its biggest cold-start cost re-jitting
programs that an identical previous process already compiled.
:func:`enable_compile_cache` points ``jax.experimental.compilation_cache``
at a durable directory so the second process start performs ZERO new
compilations — the CI cold-start smoke asserts exactly that via
:func:`cache_entries`.

Two rules make the zero-recompile guarantee hold:

  * call this BEFORE the first trace (serve.py / dse_study.py do it at
    the top of ``main()``), and
  * use identical jax config across runs — config knobs are folded into
    the cache key, so a run that flips any compilation-affecting option
    misses every entry the previous run wrote.

The thresholds are forced to "cache everything" (min entry size -1, min
compile time 0) because serving decode/prefill programs on CPU smoke
shapes compile fast but numerous — exactly the programs a restart
re-pays.
"""
from __future__ import annotations

import os

ENV_VAR = "REPRO_COMPILE_CACHE"


def enable_compile_cache(path: str | None = None) -> str | None:
    """Enable jax's persistent compilation cache at ``path`` (default:
    ``$REPRO_COMPILE_CACHE``; no-op returning None when neither is set).
    Returns the cache directory in use."""
    path = path or os.environ.get(ENV_VAR)
    if not path:
        return None
    import jax
    path = os.path.abspath(path)
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    return path


def cache_entries(path: str) -> int:
    """Number of committed compilation-cache entries under ``path``.
    Unchanged across a run == that run compiled nothing new."""
    if not path or not os.path.isdir(path):
        return 0
    return sum(1 for name in os.listdir(path) if name.endswith("-cache"))
