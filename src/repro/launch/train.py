"""Production training launcher.

On a real cluster every host runs this same script (jax.distributed
initializes from env); on this CPU container it drives the identical code
path on a (1, 1) mesh — the point of expressing everything through GSPMD
shardings is that the program is mesh-size-agnostic.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b \
      --variant smoke --steps 100 --batch 8 --seq 128 \
      --tt ffn --tt-rank 16 --ckpt-dir /tmp/run1

Fault tolerance: atomic checkpoints every --save-every steps (+ on
SIGTERM), restart resumes bit-identically (tests/test_system.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.data.pipeline import DataIterator, DataState
from repro.distributed import sharding as shd
from repro.training.fault import CheckpointManager, restore_or_init
from repro.training.optimizer import OptConfig, adamw_init
from repro.training.train_loop import TrainConfig, make_train_step


def make_mesh_from_devices():
    """Largest (data, model) mesh the available devices support."""
    n = len(jax.devices())
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0 and m <= n:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--tt", default=None,
                    help="comma list of families to TT-factorize (e.g. "
                         "'ffn' or 'ffn,attn'); omit for dense")
    ap.add_argument("--tt-rank", type=int, default=16)
    ap.add_argument("--tt-backend", default="xla")
    ap.add_argument("--tt-autotune", default="cached",
                    choices=["off", "cached", "measure"],
                    help="block-plan autotuner mode for the Pallas backends")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    tt = None
    if args.tt:
        tt = TTConfig(enabled=True, families=tuple(args.tt.split(",")),
                      rank=args.tt_rank, backend=args.tt_backend,
                      autotune=args.tt_autotune,
                      min_factor=2 if args.variant == "smoke" else 8)
    cfg = get_config(args.arch, args.variant, tt=tt)
    model = build(cfg)

    mesh = make_mesh_from_devices()
    rules = dict(shd.ACT_RULES_TRAIN)
    shd.set_ctx(shd.ShardCtx(mesh, rules, ("data",)))

    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                      total_steps=args.steps),
        micro_batches=args.micro_batches,
        compute_dtype=jnp.bfloat16 if args.variant == "full"
        else jnp.float32,
        grad_compression=args.grad_compression,
    )
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))

    def init_fn():
        params = model.init(jax.random.PRNGKey(args.seed))
        state = {"params": params, "opt": adamw_init(params)}
        if tcfg.grad_compression:
            from repro.training.compression import ef_init
            state["ef"] = ef_init(params)
        return state

    start_step, data_state = 0, {}
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, save_every=args.save_every)
        mgr.install_preemption_handler()
        state, start_step, data_state = restore_or_init(
            mgr, init_fn, init_fn())
    else:
        state = init_fn()

    n_params = model.num_params()
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={mesh.shape} "
          f"tt={'on' if cfg.tt.enabled else 'off'} start={start_step}")

    it = DataIterator(cfg, args.batch, args.seq,
                      state=DataState.from_dict(data_state or {}))
    losses, t0 = [], time.time()
    for step in range(start_step + 1, args.steps + 1):
        state, metrics = step_fn(state, next(it))
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps:
            dt = (time.time() - t0) / max(len(losses), 1)
            tok_s = args.batch * args.seq / dt
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"| {dt*1e3:.0f} ms/step {tok_s:.0f} tok/s "
                  f"lr {float(metrics['lr']):.2e}")
        if mgr and mgr.should_save(step):
            mgr.save(state, step, data_state=it.state.as_dict())
        if mgr and mgr.preempted:
            print(f"preempted at step {step}: checkpoint saved, exiting")
            break
    if mgr:
        mgr.save(state, args.steps, data_state=it.state.as_dict())
    shd.set_ctx(None)
    return {"final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None,
            "steps_run": len(losses),
            "params": n_params,
            # for in-process consumers (examples): the trained parameter
            # tree and the built model, so a serving step can run on the
            # result without a checkpoint round-trip
            "model": model,
            "trained_params": state["params"]}


if __name__ == "__main__":
    out = main()
    print(f"done: first_loss={out['first_loss']:.4f} "
          f"final_loss={out['final_loss']:.4f}")
