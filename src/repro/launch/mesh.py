"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_host_mesh():
    """1-device mesh for CPU smoke runs (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
