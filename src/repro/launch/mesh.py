"""Mesh construction — production dry-run shapes and the serving mesh.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_serve_mesh(num_devices: int | None = None, *,
                    data: int | None = None):
    """Serving mesh over the first ``num_devices`` local devices with axes
    ``("data", "model")``.  Default shape ``(1, n)`` — every device joins
    the model axis (sharded embeddings/heads/experts and KV-head-
    partitioned arenas, DESIGN.md §14).  ``data=d`` splits the devices
    ``(d, n/d)`` instead: the data axis partitions decode *slots* (each
    device owns the KV of its share of the batch — batch-parallel decode,
    no per-layer collectives), composing with model-axis partitioning on
    the rest.  Unlike the production dry-run meshes this may cover a
    *subset* of visible devices, which is what the device-count scaling
    sweep needs."""
    devs = jax.devices()
    n = len(devs) if num_devices is None else int(num_devices)
    if n < 1 or n > len(devs):
        raise ValueError(
            f"serve mesh wants {n} devices but {len(devs)} are visible — "
            f"on CPU launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    d = 1 if data is None else int(data)
    if d < 1 or n % d != 0:
        raise ValueError(f"data axis {d} must divide the mesh size {n}")
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(d, n // d),
                             ("data", "model"))
