"""Serving launcher: prefill a batch of synthetic prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
      --variant smoke --batch 4 --prompt-len 64 --steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.configs.shapes import concrete_batch
from repro.serving.engine import generate


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--tt", default=None)
    ap.add_argument("--tt-rank", type=int, default=16)
    ap.add_argument("--tt-backend", default="xla")
    ap.add_argument("--tt-autotune", default="cached",
                    choices=["off", "cached", "measure"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    tt = None
    if args.tt:
        tt = TTConfig(enabled=True, families=tuple(args.tt.split(",")),
                      rank=args.tt_rank, backend=args.tt_backend,
                      autotune=args.tt_autotune,
                      min_factor=2 if args.variant == "smoke" else 8)
    cfg = get_config(args.arch, args.variant, tt=tt)
    model = build(cfg, param_dtype=jnp.bfloat16
                  if args.variant == "full" else jnp.float32)
    params = model.init(jax.random.PRNGKey(args.seed))

    batch = concrete_batch(cfg, args.batch, args.prompt_len, seed=args.seed)
    batch = dict(batch, cache_len=args.prompt_len + args.steps)

    t0 = time.time()
    res = generate(model, params, batch, steps=args.steps,
                   temperature=args.temperature,
                   key=jax.random.PRNGKey(args.seed + 1))
    dt = time.time() - t0
    toks = args.batch * args.steps
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"decode={args.steps}")
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. prefill+compile)")
    print("sample tokens[0]:", res.tokens[0].tolist())
    return {"tokens": res.tokens, "tok_per_s": toks / dt}


if __name__ == "__main__":
    main()
