"""Serving launcher.

Fixed-batch mode (default): prefill a batch of synthetic prompts, decode N
tokens, reporting compile time and steady-state throughput *separately*
(the first generate call pays trace+compile; the second is the number that
scales).

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
      --variant smoke --batch 4 --prompt-len 64 --steps 32

Continuous-batching simulation mode (--arrival-rate): requests arrive as a
Poisson process into the slot-pool scheduler; reports steady-state tok/s
and p50/p95 per-request latency, with compile time excluded via a warm-up
request.  ``--paged`` switches the pool to the block-paged KV cache
(DESIGN.md §7) and reports KV-pool bytes, the block high-water mark and
the prefix-cache hit rate.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b \
      --arrival-rate 4 --max-requests 16 --slots 4 --prompt-len 16 \
      --steps 8 --paged

Prefix-reuse smoke (--prefix-smoke): two requests sharing a long prompt
prefix through the paged scheduler; asserts the second request shares >= 1
resident block and skips the covered prefill compute.

Fault-injection smoke (--fault-smoke): a seeded ``serving.faults``
FaultPlan (alloc failures, admission holds, a cancel, a live resize, a
simulated restart) over a mixed-priority workload; asserts zero leaked
blocks, zero TT plan re-resolutions and survivor token identity
(DESIGN.md §11).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.configs.shapes import concrete_batch
from repro.kernels import plan as ttplan
from repro.serving.engine import generate_fixed
from repro.serving.scheduler import Request, Scheduler


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def _make_sched(model, params, args, cache_len):
    return Scheduler(model, params, num_slots=args.slots,
                     cache_len=cache_len, eos_id=args.eos_id,
                     key=jax.random.PRNGKey(args.seed + 1),
                     paged=args.paged, block_size=args.block_size,
                     num_blocks=args.num_blocks)


def _print_pool_stats(sched) -> None:
    st = sched.stats()
    print(f"kv pool: {st['kv_pool_bytes'] / 1e6:.2f} MB", end="")
    if sched.paged:
        print(f" | blocks: {st['num_blocks']}x{st['block_size']} tokens, "
              f"high-water {st['block_high_water']} "
              f"| prefix hit rate {st['prefix_hit_rate']:.2f} "
              f"({st['prefill_tokens_skipped']} prefill tokens skipped)")
    else:
        print()


def simulate(model, params, args) -> dict:
    """Poisson-arrival continuous-batching simulation (wall-clock driven)."""
    steps = args.steps
    cache_len = args.prompt_len + steps
    sched = _make_sched(model, params, args, cache_len)

    def req(uid, seed):
        toks = concrete_batch(model.cfg, 1, args.prompt_len,
                              seed=seed)["tokens"]
        return Request(uid=uid, inputs={"tokens": toks},
                       max_new_tokens=steps,
                       temperature=args.temperature, top_k=args.top_k)

    # warm-up: one throwaway request compiles prefill, splice, the masked
    # decode step and the pick — all shapes the simulation will reuse
    t0 = time.perf_counter()
    sched.submit(req(-1, args.seed + 999))
    sched.run()
    compile_s = time.perf_counter() - t0
    sched.reset_stats()                    # warm-up out of steady-state
    # every TT plan is resolved at model build / warm-up; the steady-state
    # run must never plan again (DESIGN.md §10)
    plans_warm = ttplan.plan_resolutions()

    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                         size=args.max_requests))
    start = time.perf_counter()
    i = 0
    interrupted = False
    try:
        while i < args.max_requests or not sched.idle:
            now = time.perf_counter() - start
            while i < args.max_requests and arrivals[i] <= now:
                sched.submit(req(i, args.seed + i),
                             submit_time=start + arrivals[i])
                i += 1
            if sched.idle:                  # ahead of the arrival process
                time.sleep(max(0.0,
                               arrivals[i] - (time.perf_counter() - start)))
                continue
            sched.step()
    except KeyboardInterrupt:
        # graceful drain: retire everything still pending as "cancelled"
        # (partial tokens kept) so blocks/slots free and the report below
        # still prints — flagged partial — and we exit 0
        interrupted = True
        for q in list(sched.queue):
            sched.cancel(q.req.uid)
        for s in list(sched.slots):
            if s is not None:
                sched.cancel(s.uid)
    wall = time.perf_counter() - start
    finished = list(sched.finished)

    lats = [f.finish_time - f.submit_time for f in finished]
    tok_s = sched.tokens_out / wall if wall > 0 else float("nan")
    p50, p95 = _percentile(lats, 50), _percentile(lats, 95)
    partial = " (PARTIAL — interrupted)" if interrupted else ""
    print(f"arch={model.cfg.name} slots={args.slots} "
          f"arrival_rate={args.arrival_rate}/s requests={len(finished)} "
          f"prompt={args.prompt_len} max_new={steps} "
          f"pool={'paged' if args.paged else 'dense'}{partial}")
    print(f"compile (warm-up request): {compile_s:.2f}s — excluded below")
    print(f"steady-state: {sched.tokens_out} tokens in {wall:.2f}s "
          f"({tok_s:.1f} tok/s), decode steps={sched.steps_run}")
    print(f"per-request latency: p50={p50*1e3:.1f}ms p95={p95*1e3:.1f}ms")
    _print_pool_stats(sched)
    if interrupted and sched.paged:
        sched.allocator.assert_quiescent()  # interrupt must not leak blocks
    replans = ttplan.plan_resolutions() - plans_warm
    print(f"plan resolutions during steady state: {replans} "
          f"(model plans: {len(model.plan_book)})")
    if args.assert_no_replan and replans != 0:
        raise AssertionError(
            f"{replans} TT plan resolutions during the steady-state run — "
            "serving must execute build-time plans only")
    return {"finished": finished, "tok_per_s": tok_s, "p50_s": p50,
            "p95_s": p95, "compile_s": compile_s, "replans": replans,
            "interrupted": interrupted}


def prefix_smoke(model, params, args) -> dict:
    """Prefix-reuse smoke (CI): two requests whose prompts share a
    ``--prefix-len``-token prefix through the paged scheduler.  The second
    admission must find the prefix blocks resident — sharing >= 1 block,
    skipping the covered prefill compute — and both outputs must match the
    dense-scheduler reference token-for-token."""
    from repro.serving.engine import generate_fixed

    P, tail, steps = args.prefix_len, 16, args.steps
    cache_len = P + tail + steps
    prefix = concrete_batch(model.cfg, 1, P, seed=args.seed)["tokens"]
    prompts = [
        jnp.concatenate(
            [prefix, concrete_batch(model.cfg, 1, tail,
                                    seed=args.seed + 1 + i)["tokens"]], 1)
        for i in range(2)]
    sched = _make_sched(model, params, args, cache_len)
    if not sched.paged or not sched.prefix_cache:
        raise SystemExit("--prefix-smoke requires --paged and a "
                         "prefix-shareable arch (full attention / MLA)")
    t_admit = []
    for uid, toks in enumerate(prompts):
        t0 = time.perf_counter()
        sched.submit(Request(uid=uid, inputs={"tokens": toks},
                             max_new_tokens=steps))
        sched.step()                      # admission (+ first decode step)
        t_admit.append(time.perf_counter() - t0)
    out = sched.run()
    for f in sched.finished:
        out[f.uid] = f
    st = sched.stats()
    shared_blocks = st["prefix_hit_tokens"] // sched.block
    print(f"arch={model.cfg.name} prefix={P} tail={tail} "
          f"block={sched.block}")
    print(f"admission wall: first={t_admit[0]*1e3:.1f}ms "
          f"(cold, compiles) second={t_admit[1]*1e3:.1f}ms")
    print(f"prefix: {shared_blocks} shared blocks, "
          f"{st['prefill_tokens_skipped']} prefill tokens skipped, "
          f"hit rate {st['prefix_hit_rate']:.2f}")
    _print_pool_stats(sched)
    if shared_blocks < 1 or st["prefill_tokens_skipped"] < P - sched.block:
        raise AssertionError(
            f"prefix reuse failed: {shared_blocks} shared blocks, "
            f"{st['prefill_tokens_skipped']} tokens skipped (prefix {P})")
    for uid, toks in enumerate(prompts):
        ref = generate_fixed(model, params,
                             {"tokens": toks, "cache_len": cache_len},
                             steps=steps)
        if out[uid].tokens.tolist() != np.asarray(
                ref.tokens)[0].tolist():
            raise AssertionError(f"request {uid}: paged prefix-reuse "
                                 "output diverged from the dense reference")
    print("prefix-reuse smoke OK (outputs token-identical to dense)")
    return {"shared_blocks": shared_blocks, **st}


def fault_smoke(model, params, args) -> dict:
    """Fault-injection smoke (CI): a seeded FaultPlan — forced alloc
    failures, an admission hold, one mid-stream cancel, one live resize
    and one simulated restart — over a synthetic mixed-priority workload,
    asserting the full invariant suite (``serving.faults``): zero leaked
    blocks, zero plan re-resolutions, and every surviving request's
    tokens bit-identical to an uninterrupted run."""
    from repro.serving.faults import FaultPlan, run_with_faults

    steps = args.steps
    cache_len = args.prompt_len + steps
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed + 1)
    reqs = []
    for uid in range(args.max_requests):
        toks = concrete_batch(model.cfg, 1, args.prompt_len,
                              seed=args.seed + uid)["tokens"]
        reqs.append(Request(
            uid=uid, inputs={"tokens": toks}, max_new_tokens=steps,
            temperature=args.temperature, top_k=args.top_k,
            key=jax.random.fold_in(key, uid),
            priority=int(rng.integers(0, 3)),
            # one tight TTL exercises the deadline/expiry path (virtual
            # step clock: deadline_s is in scheduler steps here)
            deadline_s=3.0 if uid == 0 else None))
    kw = dict(num_slots=args.slots, cache_len=cache_len, eos_id=args.eos_id,
              key=key, paged=args.paged, block_size=args.block_size,
              num_blocks=args.num_blocks)
    # Poisson arrivals in scheduler steps; the last (high-priority, late)
    # arrival lands mid-stream so the preemption path is exercised too
    arrivals = np.cumsum(rng.poisson(1.0, size=len(reqs))).tolist()
    reqs[-1] = dataclasses.replace(reqs[-1], priority=9, deadline_s=None)
    plan = FaultPlan.random(
        args.seed, horizon=max(4, steps),
        uids=[r.uid for r in reqs[:-1]],    # keep the preemptor alive
        resize_to=(args.slots + 1, None))
    print(f"arch={model.cfg.name} slots={args.slots} "
          f"requests={len(reqs)} pool={'paged' if args.paged else 'dense'}")
    print(f"fault plan: alloc_fail@{sorted(plan.alloc_fail_steps)} "
          f"hold@{sorted(plan.hold_steps)} cancels={list(plan.cancels)} "
          f"resizes={list(plan.resizes)} "
          f"restart@{sorted(plan.restart_steps)} arrivals@{arrivals}")
    rep = run_with_faults(model, params, reqs, plan, sched_kwargs=kw,
                          arrival_steps=arrivals)
    print(f"drained in {rep.steps} steps: restarts={rep.restarts} "
          f"preemptions={rep.preemptions} cancelled={rep.cancelled} "
          f"expired={rep.expired} replans={rep.replans}")
    print(f"fault-injection smoke OK ({len(rep.survivors)} survivors "
          f"token-identical to the uninterrupted run)")
    return {"steps": rep.steps, "restarts": rep.restarts,
            "preemptions": rep.preemptions, "cancelled": rep.cancelled,
            "expired": rep.expired, "survivors": len(rep.survivors)}


def fixed(model, params, args) -> dict:
    batch = concrete_batch(model.cfg, args.batch, args.prompt_len,
                           seed=args.seed)
    batch = dict(batch, cache_len=args.prompt_len + args.steps)
    key = jax.random.PRNGKey(args.seed + 1)

    t0 = time.perf_counter()
    res = generate_fixed(model, params, batch, steps=args.steps,
                         temperature=args.temperature, key=key)
    jax.block_until_ready(res.tokens)
    cold = time.perf_counter() - t0
    plans_warm = ttplan.plan_resolutions()     # all resolved by now
    t0 = time.perf_counter()
    res = generate_fixed(model, params, batch, steps=args.steps,
                         temperature=args.temperature, key=key)
    jax.block_until_ready(res.tokens)
    warm = time.perf_counter() - t0
    replans = ttplan.plan_resolutions() - plans_warm

    toks = args.batch * args.steps
    compile_s = max(cold - warm, 0.0)
    print(f"arch={model.cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} decode={args.steps}")
    print(f"compile: {compile_s:.2f}s (cold {cold:.2f}s − warm {warm:.2f}s)")
    print(f"steady-state: {toks} tokens in {warm:.2f}s "
          f"({toks/warm:.1f} tok/s incl. prefill, excl. compile)")
    print("sample tokens[0]:", res.tokens[0].tolist())
    print(f"plan resolutions during warm run: {replans} "
          f"(model plans: {len(model.plan_book)})")
    if args.assert_no_replan and replans != 0:
        raise AssertionError(
            f"{replans} TT plan resolutions during the warm run — "
            "serving must execute build-time plans only")
    return {"tokens": res.tokens, "tok_per_s": toks / warm,
            "compile_s": compile_s, "replans": replans}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=32,
                    help="decode budget (max_new_tokens per request)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--tt", default=None)
    ap.add_argument("--tt-rank", type=int, default=16)
    ap.add_argument("--tt-backend", default="xla")
    ap.add_argument("--tt-autotune", default="cached",
                    choices=["off", "cached", "measure"])
    ap.add_argument("--tt-weights", default="fp32",
                    choices=["fp32", "int8"],
                    help="resident TT core dtype; int8 quantizes the "
                         "checkpoint offline and serves the int8-resident "
                         "kernel path (DESIGN.md §8)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="per-request top-k sampling filter (0 = off)")
    # continuous-batching simulation
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrival rate (req/s); enables simulation")
    ap.add_argument("--max-requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=None,
                    help="slot-pool size (default: --batch)")
    ap.add_argument("--eos-id", type=int, default=None)
    # block-paged KV cache (DESIGN.md §7)
    ap.add_argument("--paged", action="store_true",
                    help="serve from the block-paged KV pool with "
                         "hash-based prefix reuse")
    ap.add_argument("--block-size", type=int, default=64,
                    help="tokens per KV block (--paged)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="arena blocks (default: slots x ceil(cache/block) "
                         "— admission is by free blocks, not slots)")
    ap.add_argument("--prefix-smoke", action="store_true",
                    help="CI smoke: two requests sharing a --prefix-len "
                         "token prefix must share blocks and skip the "
                         "covered prefill")
    ap.add_argument("--prefix-len", type=int, default=128)
    ap.add_argument("--fault-smoke", action="store_true",
                    help="CI smoke: seeded fault-injection run "
                         "(serving.faults.FaultPlan) asserting zero leaked "
                         "blocks, zero replans and survivor token identity")
    ap.add_argument("--assert-no-replan", action="store_true",
                    help="fail if any TT execution plan is resolved during "
                         "the steady-state serving run (CI smoke for the "
                         "plan-compile-execute contract, DESIGN.md §10)")
    args = ap.parse_args(argv)
    if args.slots is None:
        args.slots = args.batch

    tt = None
    if args.tt:
        tt = TTConfig(enabled=True, families=tuple(args.tt.split(",")),
                      rank=args.tt_rank, backend=args.tt_backend,
                      autotune=args.tt_autotune, weights=args.tt_weights,
                      min_factor=2 if args.variant == "smoke" else 8)
    cfg = get_config(args.arch, args.variant, tt=tt)
    model = build(cfg, param_dtype=jnp.bfloat16
                  if args.variant == "full" else jnp.float32)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.tt and args.tt_weights == "int8":
        # offline checkpoint transform: int8 cores + per-core scales
        params = model.quantize_params(params)

    try:
        if args.prefix_smoke:
            return prefix_smoke(model, params, args)
        if args.fault_smoke:
            return fault_smoke(model, params, args)
        if args.arrival_rate is not None:
            return simulate(model, params, args)
        return fixed(model, params, args)
    except KeyboardInterrupt:
        # simulate() drains gracefully on its own; this is the safety net
        # for the other modes — exit 0 without a traceback
        print("\ninterrupted — exiting")
        return {"interrupted": True}


if __name__ == "__main__":
    main()
