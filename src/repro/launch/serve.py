"""Serving launcher.

Fixed-batch mode (default): prefill a batch of synthetic prompts, decode N
tokens, reporting compile time and steady-state throughput *separately*
(the first generate call pays trace+compile; the second is the number that
scales).

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
      --variant smoke --batch 4 --prompt-len 64 --steps 32

Continuous-batching simulation mode (--arrival-rate): requests arrive as a
Poisson process into the slot-pool scheduler; reports steady-state tok/s
and p50/p95 per-request latency, with compile time excluded via a warm-up
request.  ``--paged`` switches the pool to the block-paged KV cache
(DESIGN.md §7) and reports KV-pool bytes, the block high-water mark and
the prefix-cache hit rate.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b \
      --arrival-rate 4 --max-requests 16 --slots 4 --prompt-len 16 \
      --steps 8 --paged

Chunked prefill (--chunk-prefill, DESIGN.md §15): prompts stream into the
pool ``--chunk-size`` tokens at a time *inside* the fused decode step —
decoding requests keep emitting tokens while a long prompt prefills, so
p95 TTFT stops being hostage to the longest prompt in the queue.
``--prefill-budget`` caps prefill tokens per step (the prefill-vs-decode
SLO knob).  Output is token-identical to monolithic prefill.

Streaming serving (--serve / --serve-smoke, DESIGN.md §15): an HTTP/SSE
front-end (stdlib-only) over the async StreamEngine — POST /generate
streams per-token events, GET /stream/<uid>?from=N resumes a dropped
stream (journal-aware with --durable/--restore), POST /shutdown drains.

Prefix-reuse smoke (--prefix-smoke): two requests sharing a long prompt
prefix through the paged scheduler; asserts the second request shares >= 1
resident block and skips the covered prefill compute.

Fault-injection smoke (--fault-smoke): a seeded ``serving.faults``
FaultPlan (alloc failures, admission holds, a cancel, a live resize, a
simulated restart) over a mixed-priority workload; asserts zero leaked
blocks, zero TT plan re-resolutions and survivor token identity
(DESIGN.md §11).

Durability (DESIGN.md §13): ``--compile-cache DIR`` enables the
persistent XLA compilation cache (a restarted process re-jits nothing;
``--assert-cache-hits`` makes CI fail if it does); ``--first-token``
prints a machine-readable ``COLD_START`` line with the process-start →
first-token time (run it twice against one cache dir to measure cold
vs. warm); ``--durable DIR`` wraps the scheduler in the journal +
snapshot pipeline so Ctrl-C (and kill -9) preserve in-flight work,
resumable with ``--restore``; ``--durability-smoke`` is the CI drill for
kill/truncate/bit-flip recovery.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

# captured before the jax import below, so --first-token's "process
# start → first token" includes jax/XLA startup and every compile —
# exactly the costs the persistent compilation cache amortises
_PROC_T0 = time.perf_counter()

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build, get_config
from repro.configs.base import TTConfig
from repro.configs.shapes import concrete_batch
from repro.kernels import plan as ttplan
from repro.serving.engine import generate_fixed
from repro.serving.scheduler import Request, Scheduler

from .cache import cache_entries, enable_compile_cache


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def _make_sched(model, params, args, cache_len):
    return Scheduler(model, params, num_slots=args.slots,
                     cache_len=cache_len, eos_id=args.eos_id,
                     key=jax.random.PRNGKey(args.seed + 1),
                     paged=args.paged, block_size=args.block_size,
                     num_blocks=args.num_blocks, mesh=args.mesh_obj,
                     chunk_prefill=args.chunk_prefill,
                     chunk_size=args.chunk_size,
                     prefill_budget=args.prefill_budget)


def _print_pool_stats(sched) -> None:
    st = sched.stats()
    print(f"kv pool: {st['kv_pool_bytes'] / 1e6:.2f} MB", end="")
    if sched.paged:
        print(f" | blocks: {st['num_blocks']}x{st['block_size']} tokens, "
              f"high-water {st['block_high_water']} "
              f"| prefix hit rate {st['prefix_hit_rate']:.2f} "
              f"({st['prefill_tokens_skipped']} prefill tokens skipped)")
    else:
        print()


def simulate(model, params, args) -> dict:
    """Poisson-arrival continuous-batching simulation (wall-clock driven)."""
    steps = args.steps
    cache_len = args.prompt_len + steps
    sched = _make_sched(model, params, args, cache_len)

    def req(uid, seed):
        toks = concrete_batch(model.cfg, 1, args.prompt_len,
                              seed=seed)["tokens"]
        return Request(uid=uid, inputs={"tokens": toks},
                       max_new_tokens=steps,
                       temperature=args.temperature, top_k=args.top_k)

    # warm-up: one throwaway request compiles prefill, splice, the masked
    # decode step and the pick — all shapes the simulation will reuse
    t0 = time.perf_counter()
    sched.submit(req(-1, args.seed + 999))
    sched.run()
    compile_s = time.perf_counter() - t0
    sched.reset_stats()                    # warm-up out of steady-state
    # every TT plan is resolved at model build / warm-up; the steady-state
    # run must never plan again (DESIGN.md §10)
    plans_warm = ttplan.plan_resolutions()

    if args.durable:
        from repro.serving.durable import DurableScheduler
        if args.restore:
            # the warm-up already compiled every program on this Model, so
            # the recovered scheduler (same model, fresh state) re-jits
            # nothing while it drains the restored requests
            sched = DurableScheduler.recover(
                args.durable, model, params, rebase_clock=True,
                snapshot_every=args.snapshot_every, log=print)
        else:
            sched = DurableScheduler(sched, args.durable,
                                     snapshot_every=args.snapshot_every)

    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                         size=args.max_requests))
    start = time.perf_counter()
    # --restore drains the recovered requests only: re-submitting the
    # synthetic workload would collide with the restored uids
    i = args.max_requests if (args.durable and args.restore) else 0
    interrupted = False
    preserved = False
    try:
        while i < args.max_requests or not sched.idle:
            now = time.perf_counter() - start
            while i < args.max_requests and arrivals[i] <= now:
                sched.submit(req(i, args.seed + i),
                             submit_time=start + arrivals[i])
                i += 1
            if sched.idle:                  # ahead of the arrival process
                time.sleep(max(0.0,
                               arrivals[i] - (time.perf_counter() - start)))
                continue
            sched.step()
    except KeyboardInterrupt:
        interrupted = True
        if args.durable:
            # graceful shutdown == crash recovery entry point: checkpoint
            # the live state (snapshot generation + journal rotation) and
            # keep in-flight work — a later --restore run resumes it
            gen = sched.checkpoint()
            sched.close()
            preserved = True
            print(f"\ninterrupted — state checkpointed to {args.durable} "
                  f"(generation {gen}, {len(sched.queue)} queued, "
                  f"{sched.num_active} active); resume with --restore")
        else:
            # graceful drain: retire everything still pending as
            # "cancelled" (partial tokens kept) so blocks/slots free and
            # the report below still prints — flagged partial — exit 0
            for q in list(sched.queue):
                sched.cancel(q.req.uid)
            for s in list(sched.slots):
                if s is not None:
                    sched.cancel(s.uid)
    if args.durable and not preserved:
        sched.checkpoint()                 # final snapshot on a clean drain
        sched.close()
    wall = time.perf_counter() - start
    finished = list(sched.finished)

    lats = [f.finish_time - f.submit_time for f in finished]
    # TTFT (submit → first token: queueing + prefill) and inter-token
    # latency (per-token decode cadence after the first) are separate
    # SLOs — chunked prefill trades the one against the other, so they
    # are reported apart (ISSUE 10 satellite)
    ttfts = [f.first_token_time - f.submit_time for f in finished
             if f.first_token_time is not None]
    itls = [(f.finish_time - f.first_token_time) / (len(f.tokens) - 1)
            for f in finished
            if f.first_token_time is not None and len(f.tokens) > 1]
    tok_s = sched.tokens_out / wall if wall > 0 else float("nan")
    p50, p95 = _percentile(lats, 50), _percentile(lats, 95)
    ttft50, ttft95 = _percentile(ttfts, 50), _percentile(ttfts, 95)
    itl50, itl95 = _percentile(itls, 50), _percentile(itls, 95)
    partial = " (PARTIAL — interrupted)" if interrupted else ""
    chunked = (f" chunk={sched.chunk_size}x{sched.chunk_lanes}"
               if args.chunk_prefill else "")
    print(f"arch={model.cfg.name} slots={args.slots} "
          f"arrival_rate={args.arrival_rate}/s requests={len(finished)} "
          f"prompt={args.prompt_len} max_new={steps} "
          f"pool={'paged' if args.paged else 'dense'}{chunked}{partial}")
    print(f"compile (warm-up request): {compile_s:.2f}s — excluded below")
    print(f"steady-state: {sched.tokens_out} tokens in {wall:.2f}s "
          f"({tok_s:.1f} tok/s), decode steps={sched.steps_run}")
    print(f"per-request latency: p50={p50*1e3:.1f}ms p95={p95*1e3:.1f}ms")
    print(f"ttft: p50={ttft50*1e3:.1f}ms p95={ttft95*1e3:.1f}ms | "
          f"inter-token: p50={itl50*1e3:.1f}ms p95={itl95*1e3:.1f}ms")
    if args.chunk_prefill:
        print(f"prefill chunks executed: {sched.prefill_chunks} "
              f"(budget {sched.prefill_budget} tok/step)")
    _print_pool_stats(sched)
    if interrupted and sched.paged and not preserved:
        sched.allocator.assert_quiescent()  # interrupt must not leak blocks
    replans = ttplan.plan_resolutions() - plans_warm
    print(f"plan resolutions during steady state: {replans} "
          f"(model plans: {len(model.plan_book)})")
    if args.assert_no_replan and replans != 0:
        raise AssertionError(
            f"{replans} TT plan resolutions during the steady-state run — "
            "serving must execute build-time plans only")
    return {"finished": finished, "tok_per_s": tok_s, "p50_s": p50,
            "p95_s": p95, "ttft_p50_s": ttft50, "ttft_p95_s": ttft95,
            "itl_p50_s": itl50, "itl_p95_s": itl95,
            "compile_s": compile_s, "replans": replans,
            "interrupted": interrupted}


def prefix_smoke(model, params, args) -> dict:
    """Prefix-reuse smoke (CI): two requests whose prompts share a
    ``--prefix-len``-token prefix through the paged scheduler.  The second
    admission must find the prefix blocks resident — sharing >= 1 block,
    skipping the covered prefill compute — and both outputs must match the
    dense-scheduler reference token-for-token."""
    from repro.serving.engine import generate_fixed

    P, tail, steps = args.prefix_len, 16, args.steps
    cache_len = P + tail + steps
    prefix = concrete_batch(model.cfg, 1, P, seed=args.seed)["tokens"]
    prompts = [
        jnp.concatenate(
            [prefix, concrete_batch(model.cfg, 1, tail,
                                    seed=args.seed + 1 + i)["tokens"]], 1)
        for i in range(2)]
    sched = _make_sched(model, params, args, cache_len)
    if not sched.paged or not sched.prefix_cache:
        raise SystemExit("--prefix-smoke requires --paged and a "
                         "prefix-shareable arch (full attention / MLA)")
    t_admit = []
    for uid, toks in enumerate(prompts):
        t0 = time.perf_counter()
        sched.submit(Request(uid=uid, inputs={"tokens": toks},
                             max_new_tokens=steps))
        sched.step()                      # admission (+ first decode step)
        t_admit.append(time.perf_counter() - t0)
    out = sched.run()
    for f in sched.finished:
        out[f.uid] = f
    st = sched.stats()
    shared_blocks = st["prefix_hit_tokens"] // sched.block
    print(f"arch={model.cfg.name} prefix={P} tail={tail} "
          f"block={sched.block}")
    print(f"admission wall: first={t_admit[0]*1e3:.1f}ms "
          f"(cold, compiles) second={t_admit[1]*1e3:.1f}ms")
    print(f"prefix: {shared_blocks} shared blocks, "
          f"{st['prefill_tokens_skipped']} prefill tokens skipped, "
          f"hit rate {st['prefix_hit_rate']:.2f}")
    _print_pool_stats(sched)
    if shared_blocks < 1 or st["prefill_tokens_skipped"] < P - sched.block:
        raise AssertionError(
            f"prefix reuse failed: {shared_blocks} shared blocks, "
            f"{st['prefill_tokens_skipped']} tokens skipped (prefix {P})")
    for uid, toks in enumerate(prompts):
        ref = generate_fixed(model, params,
                             {"tokens": toks, "cache_len": cache_len},
                             steps=steps)
        if out[uid].tokens.tolist() != np.asarray(
                ref.tokens)[0].tolist():
            raise AssertionError(f"request {uid}: paged prefix-reuse "
                                 "output diverged from the dense reference")
    print("prefix-reuse smoke OK (outputs token-identical to dense)")
    return {"shared_blocks": shared_blocks, **st}


def fault_smoke(model, params, args) -> dict:
    """Fault-injection smoke (CI): a seeded FaultPlan — forced alloc
    failures, an admission hold, one mid-stream cancel, one live resize
    and one simulated restart — over a synthetic mixed-priority workload,
    asserting the full invariant suite (``serving.faults``): zero leaked
    blocks, zero plan re-resolutions, and every surviving request's
    tokens bit-identical to an uninterrupted run."""
    from repro.serving.faults import FaultPlan, run_with_faults

    steps = args.steps
    cache_len = args.prompt_len + steps
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed + 1)
    reqs = []
    for uid in range(args.max_requests):
        toks = concrete_batch(model.cfg, 1, args.prompt_len,
                              seed=args.seed + uid)["tokens"]
        reqs.append(Request(
            uid=uid, inputs={"tokens": toks}, max_new_tokens=steps,
            temperature=args.temperature, top_k=args.top_k,
            key=jax.random.fold_in(key, uid),
            priority=int(rng.integers(0, 3)),
            # one tight TTL exercises the deadline/expiry path (virtual
            # step clock: deadline_s is in scheduler steps here)
            deadline_s=3.0 if uid == 0 else None))
    kw = dict(num_slots=args.slots, cache_len=cache_len, eos_id=args.eos_id,
              key=key, paged=args.paged, block_size=args.block_size,
              num_blocks=args.num_blocks,
              chunk_prefill=args.chunk_prefill, chunk_size=args.chunk_size,
              prefill_budget=args.prefill_budget)
    # Poisson arrivals in scheduler steps; the last (high-priority, late)
    # arrival lands mid-stream so the preemption path is exercised too
    arrivals = np.cumsum(rng.poisson(1.0, size=len(reqs))).tolist()
    reqs[-1] = dataclasses.replace(reqs[-1], priority=9, deadline_s=None)
    plan = FaultPlan.random(
        args.seed, horizon=max(4, steps),
        uids=[r.uid for r in reqs[:-1]],    # keep the preemptor alive
        resize_to=(args.slots + 1, None))
    print(f"arch={model.cfg.name} slots={args.slots} "
          f"requests={len(reqs)} pool={'paged' if args.paged else 'dense'}")
    print(f"fault plan: alloc_fail@{sorted(plan.alloc_fail_steps)} "
          f"hold@{sorted(plan.hold_steps)} cancels={list(plan.cancels)} "
          f"resizes={list(plan.resizes)} "
          f"restart@{sorted(plan.restart_steps)} arrivals@{arrivals}")
    rep = run_with_faults(model, params, reqs, plan, sched_kwargs=kw,
                          arrival_steps=arrivals)
    print(f"drained in {rep.steps} steps: restarts={rep.restarts} "
          f"preemptions={rep.preemptions} cancelled={rep.cancelled} "
          f"expired={rep.expired} replans={rep.replans}")
    print(f"fault-injection smoke OK ({len(rep.survivors)} survivors "
          f"token-identical to the uninterrupted run)")
    return {"steps": rep.steps, "restarts": rep.restarts,
            "preemptions": rep.preemptions, "cancelled": rep.cancelled,
            "expired": rep.expired, "survivors": len(rep.survivors)}


def first_token(model, params, args) -> dict:
    """Cold-start probe: one request through the scheduler, reporting
    process start → first decoded token on a machine-readable
    ``COLD_START`` line.  Run twice against one ``--compile-cache`` dir —
    the second (warm) run re-jits nothing and must be faster; CI and
    bench_serve_tt parse the line and assert exactly that."""
    cache_len = args.prompt_len + args.steps
    sched = _make_sched(model, params, args, cache_len)
    toks = concrete_batch(model.cfg, 1, args.prompt_len,
                          seed=args.seed)["tokens"]
    sched.submit(Request(uid=0, inputs={"tokens": toks},
                         max_new_tokens=args.steps,
                         temperature=args.temperature, top_k=args.top_k))
    while sched.tokens_out < 1:
        sched.step()
    t_first = time.perf_counter() - _PROC_T0
    sched.run()                            # drain the rest of the budget
    out = {"arch": model.cfg.name, "prompt_len": args.prompt_len,
           "steps": args.steps,
           "start_to_first_token_s": round(t_first, 4),
           "compile_cache": args.compile_cache,
           "cache_entries": (cache_entries(args.compile_cache)
                             if args.compile_cache else None)}
    print("COLD_START " + json.dumps(out))
    return out


def durability_smoke(model, params, args) -> dict:
    """Durability fault drill (CI, DESIGN.md §13).  Three drills:

    1. kill -9 at a seeded step with the journal + snapshot pipeline on a
       clean store — recovery replays the journal; survivor streams must
       be bit-identical to an uninterrupted run, zero leaked blocks, zero
       plan re-resolutions.
    2. the same kill, but a corruptor truncates / bit-flips the newest
       committed snapshot generation before recovery runs — the
       checksummed fallback must restore the previous generation and
       replay forward across the gap.
    3. store-level: a snapshot whose newest generation is truncated then
       bit-flipped must fall back on load, and a fully-corrupt store must
       raise a clear error — a torn state is never returned.
    """
    import tempfile

    from repro.core import durable
    from repro.serving.faults import (FaultPlan, load_snapshot,
                                      run_with_faults, save_snapshot)

    steps = args.steps
    cache_len = args.prompt_len + steps
    key = jax.random.PRNGKey(args.seed + 1)
    reqs = []
    for uid in range(args.max_requests):
        toks = concrete_batch(model.cfg, 1, args.prompt_len,
                              seed=args.seed + uid)["tokens"]
        reqs.append(Request(uid=uid, inputs={"tokens": toks},
                            max_new_tokens=steps,
                            temperature=args.temperature, top_k=args.top_k,
                            key=jax.random.fold_in(key, uid)))
    kw = dict(num_slots=args.slots, cache_len=cache_len, eos_id=args.eos_id,
              key=key, paged=args.paged, block_size=args.block_size,
              num_blocks=args.num_blocks,
              chunk_prefill=args.chunk_prefill, chunk_size=args.chunk_size,
              prefill_budget=args.prefill_budget)
    plan = FaultPlan.random(args.seed, horizon=max(4, steps),
                            n_alloc_fail=0, n_hold=0, n_cancel=0,
                            with_restart=False, with_kill=True)
    print(f"arch={model.cfg.name} slots={args.slots} requests={len(reqs)} "
          f"pool={'paged' if args.paged else 'dense'} "
          f"kill@{sorted(plan.kill_steps)}")

    with tempfile.TemporaryDirectory() as d:
        rep = run_with_faults(model, params, reqs, plan, sched_kwargs=kw,
                              durable_dir=d, snapshot_every=2)
    assert rep.kills == 1, rep
    print(f"kill drill OK: drained in {rep.steps} steps, "
          f"{len(rep.survivors)} survivors token-identical after recovery")

    rng = np.random.default_rng(args.seed + 7)
    corruptions: list[str] = []

    def corruptor(root, step):
        gens = durable.committed_generations(root)
        if len(gens) < 2:
            return                        # keep one good generation
        p = os.path.join(root, f"gen_{gens[-1]:08d}", "arrays.bin")
        size = os.path.getsize(p)
        if rng.integers(0, 2) == 0:
            with open(p, "r+b") as f:
                f.truncate(int(rng.integers(0, size)))
            corruptions.append(f"truncate gen {gens[-1]}")
        else:
            off = int(rng.integers(0, size))
            with open(p, "r+b") as f:
                f.seek(off)
                b = f.read(1)
                f.seek(off)
                f.write(bytes([b[0] ^ (1 << int(rng.integers(0, 8)))]))
            corruptions.append(f"bit-flip gen {gens[-1]}")

    with tempfile.TemporaryDirectory() as d:
        rep2 = run_with_faults(model, params, reqs, plan, sched_kwargs=kw,
                               baseline=rep.baseline, durable_dir=d,
                               snapshot_every=2, corruptor=corruptor)
    assert rep2.kills == 1, rep2
    print(f"corrupting-kill drill OK ({corruptions or 'nothing to corrupt'})"
          f": recovery fell back past the damage, survivors identical")

    with tempfile.TemporaryDirectory() as d:
        snap1 = {"version": 0, "gen": np.asarray([1], np.int32)}
        snap2 = {"version": 0, "gen": np.asarray([2], np.int32)}
        save_snapshot(d, snap1)
        save_snapshot(d, snap2)
        p = os.path.join(d, "gen_00000002", "arrays.bin")
        with open(p, "r+b") as f:
            f.truncate(2)
        assert int(load_snapshot(d)["gen"][0]) == 1   # fell back
        p1 = os.path.join(d, "gen_00000001", "arrays.bin")
        with open(p1, "r+b") as f:
            f.seek(0)
            b = f.read(1)
            f.seek(0)
            f.write(bytes([b[0] ^ 1]))
        try:
            load_snapshot(d)
            raise AssertionError("fully-corrupt store must raise")
        except durable.CorruptGenerationError:
            pass
    print("store drill OK: truncation falls back, full corruption raises "
          "— a torn state is never returned")
    print("durability smoke OK")
    return {"kills": rep.kills + rep2.kills, "corruptions": corruptions,
            "survivors": len(rep.survivors)}


def serve_mode(model, params, args) -> dict:
    """HTTP/SSE serving (DESIGN.md §15): a StreamEngine step loop behind
    the stdlib SSE front-end.  ``--durable DIR`` journals every
    submit/retire (``--restore`` recovers after a crash, with in-flight
    token streams replayable through GET /stream/<uid>?from=N — the
    journal-aware client reconnect)."""
    from repro.serving.engine import StreamEngine
    from repro.serving.server import make_server

    cache_len = args.prompt_len + args.steps
    sched = _make_sched(model, params, args, cache_len)
    if args.durable:
        from repro.serving.durable import DurableScheduler
        if args.restore:
            sched = DurableScheduler.recover(
                args.durable, model, params, rebase_clock=True,
                snapshot_every=args.snapshot_every, log=print)
        else:
            sched = DurableScheduler(sched, args.durable,
                                     snapshot_every=args.snapshot_every)
    eng = StreamEngine(sched)
    srv = make_server(eng, host=args.host, port=args.port, quiet=False)
    host, port = srv.server_address[:2]
    print(f"serving on http://{host}:{port} — POST /generate, "
          f"GET /stream/<uid>?from=N, GET /stats, POST /shutdown "
          f"(cache_len={cache_len}, "
          f"chunked={'on' if args.chunk_prefill else 'off'})")
    try:
        srv.serve_forever()
        print("shutdown requested — draining")
    except KeyboardInterrupt:
        print("\ninterrupted — draining")
    finally:
        srv.server_close()
        eng.close()
    st = eng.stats()
    print(f"served {st['requests_done']} requests, "
          f"{st['tokens_out']} tokens")
    return st


def serve_smoke(model, params, args) -> dict:
    """CI streaming smoke: an in-process SSE server, two *overlapping*
    streaming requests (per-token events must arrive in order and
    interleave across requests), a mid-stream reconnect replay from an
    arbitrary index, and a graceful POST /shutdown."""
    import http.client
    import threading

    from repro.serving.engine import StreamEngine
    from repro.serving.server import make_server

    steps = args.steps
    cache_len = args.prompt_len + steps
    sched = _make_sched(model, params, args, cache_len)
    eng = StreamEngine(sched)
    plans0 = ttplan.plan_resolutions()    # everything resolved at build
    srv = make_server(eng)
    port = srv.server_address[1]
    th = threading.Thread(target=srv.serve_forever, daemon=True)
    th.start()

    def events(resp):
        buf = b""
        while True:
            chunk = resp.read1(4096)
            if not chunk:
                return
            buf += chunk
            while b"\n\n" in buf:
                raw, buf = buf.split(b"\n\n", 1)
                for line in raw.split(b"\n"):
                    if line.startswith(b"data: "):
                        yield json.loads(line[6:])

    def client(uid, toks, out):
        c = http.client.HTTPConnection("127.0.0.1", port)
        c.request("POST", "/generate",
                  json.dumps({"tokens": toks, "max_new_tokens": steps,
                              "uid": uid}),
                  {"Content-Type": "application/json"})
        r = c.getresponse()
        assert r.status == 200, r.status
        for ev in events(r):
            out.append((time.perf_counter(), ev))
            if "done" in ev:
                break
        c.close()

    prompts = [concrete_batch(model.cfg, 1, args.prompt_len,
                              seed=args.seed + i)["tokens"][0].tolist()
               for i in range(2)]
    outs = [[], []]
    threads = [threading.Thread(target=client, args=(i, prompts[i],
                                                     outs[i]))
               for i in range(2)]
    threads[0].start()
    time.sleep(0.02)
    threads[1].start()
    for t in threads:
        t.join(timeout=300)
        assert not t.is_alive(), "streaming client timed out"
    for uid, out in enumerate(outs):
        assert out[-1][1].get("done") == "length", out[-1]
        idx = [ev["i"] for _, ev in out[:-1]]
        assert idx == list(range(steps)), \
            f"uid {uid}: events out of order: {idx}"
    # the two token streams must overlap in wall time (continuous
    # batching, not serial): each starts before the other finishes
    starts = [out[0][0] for out in outs]
    ends = [out[-1][0] for out in outs]
    assert max(starts) < min(ends), "request streams did not overlap"
    print(f"overlapping streams OK: 2 x {steps} ordered per-token events")

    # reconnect mid-stream: replay uid 0 from an arbitrary index
    frm = max(1, steps // 2)
    c = http.client.HTTPConnection("127.0.0.1", port)
    c.request("GET", f"/stream/0?from={frm}")
    replay = []
    for ev in events(c.getresponse()):
        replay.append(ev)
        if "done" in ev:
            break
    c.close()
    want = [ev["token"] for _, ev in outs[0][frm:-1]]
    got = [ev["token"] for ev in replay[:-1]]
    assert got == want and replay[-1]["done"] == "length", (replay, want)
    print(f"reconnect OK: replayed {len(got)} events from index {frm}")

    c = http.client.HTTPConnection("127.0.0.1", port)
    c.request("GET", "/stats")
    st = json.loads(c.getresponse().read())
    c.close()
    c = http.client.HTTPConnection("127.0.0.1", port)
    c.request("POST", "/shutdown", "{}")
    assert json.loads(c.getresponse().read())["ok"]
    c.close()
    th.join(timeout=30)
    assert not th.is_alive(), "server did not shut down"
    eng.close()
    replans = ttplan.plan_resolutions() - plans0
    print(f"graceful shutdown OK; plan resolutions during serving: "
          f"{replans}")
    if args.assert_no_replan and replans != 0:
        raise AssertionError(
            f"{replans} TT plan resolutions during streaming serving")
    print("streaming smoke OK")
    return {"requests": 2, "steps": steps, "replans": replans, **st}


def fixed(model, params, args) -> dict:
    batch = concrete_batch(model.cfg, args.batch, args.prompt_len,
                           seed=args.seed)
    batch = dict(batch, cache_len=args.prompt_len + args.steps)
    key = jax.random.PRNGKey(args.seed + 1)

    t0 = time.perf_counter()
    res = generate_fixed(model, params, batch, steps=args.steps,
                         temperature=args.temperature, key=key)
    jax.block_until_ready(res.tokens)
    cold = time.perf_counter() - t0
    plans_warm = ttplan.plan_resolutions()     # all resolved by now
    t0 = time.perf_counter()
    res = generate_fixed(model, params, batch, steps=args.steps,
                         temperature=args.temperature, key=key)
    jax.block_until_ready(res.tokens)
    warm = time.perf_counter() - t0
    replans = ttplan.plan_resolutions() - plans_warm

    toks = args.batch * args.steps
    compile_s = max(cold - warm, 0.0)
    print(f"arch={model.cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} decode={args.steps}")
    print(f"compile: {compile_s:.2f}s (cold {cold:.2f}s − warm {warm:.2f}s)")
    print(f"steady-state: {toks} tokens in {warm:.2f}s "
          f"({toks/warm:.1f} tok/s incl. prefill, excl. compile)")
    print("sample tokens[0]:", res.tokens[0].tolist())
    print(f"plan resolutions during warm run: {replans} "
          f"(model plans: {len(model.plan_book)})")
    if args.assert_no_replan and replans != 0:
        raise AssertionError(
            f"{replans} TT plan resolutions during the warm run — "
            "serving must execute build-time plans only")
    return {"tokens": res.tokens, "tok_per_s": toks / warm,
            "compile_s": compile_s, "replans": replans}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=32,
                    help="decode budget (max_new_tokens per request)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--tt", default=None)
    ap.add_argument("--tt-rank", type=int, default=16)
    ap.add_argument("--tt-backend", default="xla")
    ap.add_argument("--tt-autotune", default="cached",
                    choices=["off", "cached", "measure"])
    ap.add_argument("--tt-weights", default="fp32",
                    choices=["fp32", "int8"],
                    help="resident TT core dtype; int8 quantizes the "
                         "checkpoint offline and serves the int8-resident "
                         "kernel path (DESIGN.md §8)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="per-request top-k sampling filter (0 = off)")
    ap.add_argument("--mesh", type=int, default=None,
                    help="serve over an N-device (1, N) mesh (DESIGN.md "
                         "§14): params/KV pool sharded by data placement, "
                         "decode stays one collective-aware executable.  "
                         "On CPU the devices must exist before jax starts "
                         "— launch with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    # continuous-batching simulation
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrival rate (req/s); enables simulation")
    ap.add_argument("--max-requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=None,
                    help="slot-pool size (default: --batch)")
    ap.add_argument("--eos-id", type=int, default=None)
    # block-paged KV cache (DESIGN.md §7)
    ap.add_argument("--paged", action="store_true",
                    help="serve from the block-paged KV pool with "
                         "hash-based prefix reuse")
    ap.add_argument("--block-size", type=int, default=64,
                    help="tokens per KV block (--paged)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="arena blocks (default: slots x ceil(cache/block) "
                         "— admission is by free blocks, not slots)")
    # chunked prefill fused into the decode step (DESIGN.md §15)
    ap.add_argument("--chunk-prefill", action="store_true",
                    help="prefill prompts in fixed-size chunks INSIDE the "
                         "fused decode step (one traced program): decoding "
                         "requests keep emitting tokens while a long "
                         "prompt streams in, cutting p95 TTFT under mixed "
                         "workloads")
    ap.add_argument("--chunk-size", type=int, default=64,
                    help="prompt tokens per prefill chunk "
                         "(--chunk-prefill)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="max prefill tokens per step; runs "
                         "floor(budget/chunk_size) chunk lanes per step "
                         "(default: one lane).  The prefill-vs-decode "
                         "SLO knob: higher = faster admission TTFT, "
                         "more work per step")
    # HTTP/SSE serving (DESIGN.md §15)
    ap.add_argument("--serve", action="store_true",
                    help="start the HTTP/SSE streaming server "
                         "(serving/server.py) over a StreamEngine; "
                         "stop with POST /shutdown or Ctrl-C")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8763,
                    help="--serve port (0 = ephemeral)")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="CI smoke: in-process SSE server, two "
                         "overlapping streaming requests with ordered "
                         "per-token events, a mid-stream reconnect "
                         "replay and a graceful shutdown")
    ap.add_argument("--prefix-smoke", action="store_true",
                    help="CI smoke: two requests sharing a --prefix-len "
                         "token prefix must share blocks and skip the "
                         "covered prefill")
    ap.add_argument("--prefix-len", type=int, default=128)
    ap.add_argument("--fault-smoke", action="store_true",
                    help="CI smoke: seeded fault-injection run "
                         "(serving.faults.FaultPlan) asserting zero leaked "
                         "blocks, zero replans and survivor token identity")
    ap.add_argument("--assert-no-replan", action="store_true",
                    help="fail if any TT execution plan is resolved during "
                         "the steady-state serving run (CI smoke for the "
                         "plan-compile-execute contract, DESIGN.md §10)")
    # durability (DESIGN.md §13)
    ap.add_argument("--compile-cache", default=None,
                    help="persistent XLA compilation cache dir (also via "
                         "$REPRO_COMPILE_CACHE); a restarted process "
                         "reuses every compiled program")
    ap.add_argument("--assert-cache-hits", action="store_true",
                    help="fail if this run adds any entry to "
                         "--compile-cache (CI warm-start smoke: the "
                         "second run must compile nothing)")
    ap.add_argument("--first-token", action="store_true",
                    help="print a COLD_START line with process start -> "
                         "first token; run twice against one "
                         "--compile-cache dir for cold vs. warm")
    ap.add_argument("--durable", default=None,
                    help="journal + snapshot dir: submits/retires are "
                         "journaled, snapshots committed every "
                         "--snapshot-every steps; Ctrl-C preserves "
                         "in-flight work for --restore")
    ap.add_argument("--restore", action="store_true",
                    help="recover the scheduler from --durable (newest "
                         "clean snapshot + journal replay) and drain the "
                         "restored requests")
    ap.add_argument("--snapshot-every", type=int, default=32,
                    help="decode steps between snapshot generations "
                         "(--durable)")
    ap.add_argument("--durability-smoke", action="store_true",
                    help="CI drill: seeded kill -9 recovery (clean and "
                         "corrupted store), truncation/bit-flip fallback")
    args = ap.parse_args(argv)
    if args.slots is None:
        args.slots = args.batch
    if args.restore and not args.durable:
        ap.error("--restore requires --durable DIR")
    args.mesh_obj = None
    if args.mesh is not None:
        scheduler_mode = (args.arrival_rate is not None or args.restore
                          or args.fault_smoke or args.prefix_smoke
                          or args.durability_smoke or args.serve
                          or args.serve_smoke)
        if not scheduler_mode:
            ap.error("--mesh applies to scheduler modes only (use "
                     "--arrival-rate / --restore / the scheduler smokes); "
                     "the fixed-batch and --first-token paths run "
                     "single-device")
        from .mesh import make_serve_mesh
        args.mesh_obj = make_serve_mesh(args.mesh)
        print(f"serving over mesh {dict(args.mesh_obj.shape)} "
              f"({len(args.mesh_obj.devices.ravel())} devices)")

    cache_dir = enable_compile_cache(args.compile_cache)
    args.compile_cache = cache_dir        # resolves $REPRO_COMPILE_CACHE
    n_cache0 = cache_entries(cache_dir) if cache_dir else 0

    tt = None
    if args.tt:
        tt = TTConfig(enabled=True, families=tuple(args.tt.split(",")),
                      rank=args.tt_rank, backend=args.tt_backend,
                      autotune=args.tt_autotune, weights=args.tt_weights,
                      min_factor=2 if args.variant == "smoke" else 8)
    cfg = get_config(args.arch, args.variant, tt=tt)
    model = build(cfg, param_dtype=jnp.bfloat16
                  if args.variant == "full" else jnp.float32)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.tt and args.tt_weights == "int8":
        # offline checkpoint transform: int8 cores + per-core scales
        params = model.quantize_params(params)

    try:
        if args.prefix_smoke:
            out = prefix_smoke(model, params, args)
        elif args.fault_smoke:
            out = fault_smoke(model, params, args)
        elif args.durability_smoke:
            out = durability_smoke(model, params, args)
        elif args.first_token:
            out = first_token(model, params, args)
        elif args.serve_smoke:
            out = serve_smoke(model, params, args)
        elif args.serve:
            out = serve_mode(model, params, args)
        elif args.arrival_rate is not None or args.restore:
            if args.arrival_rate is None:
                args.arrival_rate = 1.0   # --restore drains, no arrivals
            out = simulate(model, params, args)
        else:
            out = fixed(model, params, args)
    except KeyboardInterrupt:
        # simulate() drains gracefully on its own; this is the safety net
        # for the other modes — exit 0 without a traceback
        print("\ninterrupted — exiting")
        return {"interrupted": True}
    if cache_dir:
        n1 = cache_entries(cache_dir)
        print(f"compile cache {cache_dir}: {n_cache0} -> {n1} entries "
              f"({n1 - n_cache0} new compilations persisted)")
        if args.assert_cache_hits and (n1 != n_cache0 or n_cache0 == 0):
            raise AssertionError(
                f"warm start compiled {n1 - n_cache0} new programs "
                f"(cache had {n_cache0} entries) — the persistent "
                f"compilation cache must make a restart re-jit nothing")
    return out


if __name__ == "__main__":
    main()
