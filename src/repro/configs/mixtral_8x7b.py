"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, sliding-window attention (W=4096).
[arXiv:2401.04088; hf]
"""
from .base import ModelConfig, MoEConfig, TTConfig

FULL = ModelConfig(
    name="mixtral-8x7b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=32000,
    head_dim=128, rope_theta=1e6, window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, expert_ff=14336),
    subquadratic=True,   # SWA ring cache → long_500k runs
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    window=32, moe=MoEConfig(num_experts=4, top_k=2, expert_ff=128,
                             capacity_factor=16.0),  # dropless at test scale
    subquadratic=True,
    tt=TTConfig(enabled=True, families=("ffn",), rank=4, min_factor=2),
)
