"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global, 128k ctx, tied embeddings, qk-norm.
[hf:google/gemma-3-1b-pt; unverified]
"""
from .base import ModelConfig, TTConfig

FULL = ModelConfig(
    name="gemma3-4b", family="dense", num_layers=34, d_model=2560,
    num_heads=8, num_kv_heads=4, d_ff=10240, vocab_size=262144,
    head_dim=256, qk_norm=True, rope_theta=1e6,
    local_global_period=6, local_window=1024, tie_embeddings=True,
    subquadratic=True,   # 5/6 of layers are sliding-window → long_500k runs
)

SMOKE = ModelConfig(
    name="gemma3-4b-smoke", family="dense", num_layers=7, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    qk_norm=True, local_global_period=3, local_window=16,
    tie_embeddings=True, subquadratic=True,
    tt=TTConfig(enabled=True, families=("ffn",), rank=4, min_factor=2),
)
