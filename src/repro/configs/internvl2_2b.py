"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT frontend (STUB: precomputed patch embeddings of
width 1024, 1024 tokens) + InternLM2 backbone.  [arXiv:2404.16821; hf]
"""
from .base import ModelConfig, TTConfig

FULL = ModelConfig(
    name="internvl2-2b", family="vlm", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=8, d_ff=8192, vocab_size=92553,
    head_dim=128, rope_theta=1e6,
    frontend="vit", frontend_dim=1024, frontend_tokens=1024,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="internvl2-2b-smoke", family="vlm", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    frontend="vit", frontend_dim=32, frontend_tokens=16,
    tt=TTConfig(enabled=True, families=("ffn",), rank=4, min_factor=2),
)
