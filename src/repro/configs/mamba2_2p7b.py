"""mamba2-2.7b [ssm]: 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality), d_inner=5120, head_dim=64
(80 heads).  [arXiv:2405.21060; unverified]

The paper's technique targets FC layers: it applies to in/out projections
of each SSD block; the scan itself is untouched (DESIGN.md §5).
"""
from .base import ModelConfig, SSMConfig, TTConfig

FULL = ModelConfig(
    name="mamba2-2.7b", family="ssm", num_layers=64, d_model=2560,
    num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=50280, head_dim=64,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    subquadratic=True,   # O(1) decode state
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke", family="ssm", num_layers=2, d_model=64,
    num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=256, head_dim=16,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1),
    subquadratic=True,
    tt=TTConfig(enabled=True, families=("ffn",), rank=4, min_factor=2),
)
