"""input_specs(): ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation) per (arch × shape).

For LM shapes: tokens are [global_batch, seq_len].  ``decode_*``/``long_*``
lower ``serve_step`` — one new token against a cache of ``seq_len`` — not
``train_step``.  Multimodal frontends receive precomputed embeddings
(assignment brief: frontend is a stub).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import Model
from .base import ModelConfig, SHAPES, ShapeSpec

sd = jax.ShapeDtypeStruct


def _token_batch_specs(cfg: ModelConfig, B: int, S: int) -> dict:
    out: dict = {}
    if cfg.frontend == "vit":
        S_img = min(cfg.frontend_tokens, S // 2)
        out["tokens"] = sd((B, S - S_img), jnp.int32)
        out["image_embeds"] = sd((B, S_img, cfg.frontend_dim), jnp.bfloat16)
    elif cfg.frontend == "speech":
        out["speech_embeds"] = sd((B, S, cfg.frontend_dim), jnp.bfloat16)
        out["tokens"] = sd((B, S), jnp.int32)
    else:
        out["tokens"] = sd((B, S), jnp.int32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec | str, model: Model,
                cache_dtype=jnp.bfloat16) -> dict:
    """Returns the kwargs tree for the step function that the dry-run lowers.

    train   → {"batch": {...tokens...}}
    prefill → {"batch": {...tokens...}}
    decode  → {"token": [B,1], "cache": <cache tree at seq_len>}
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        return {"batch": _token_batch_specs(cfg, B, S)}
    # decode: one new token with a cache of S
    enc_T = S if cfg.enc_dec else 0
    return {
        "token": sd((B, 1), jnp.int32),
        "cache": model.cache_shapes(B, S, enc_T=enc_T, dtype=cache_dtype),
    }


def concrete_batch(cfg: ModelConfig, B: int, S: int, seed: int = 0) -> dict:
    """Small concrete batch for smoke tests / examples (CPU-sized)."""
    key = jax.random.PRNGKey(seed)
    specs = _token_batch_specs(cfg, B, S)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, s.shape, 0, cfg.vocab_size,
                                           s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32).astype(
                s.dtype)
    return out
