"""Configuration dataclasses: model architecture, TT compression, shapes."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class TTConfig:
    """How the paper's technique is applied to a model (DESIGN.md §4).

    ``families``: which projection families are TT-factorized.  The DSE
    (core.dse.best_plan) picks the factorization shape at config-build time
    — offline, exactly like the paper's tool.
    """
    enabled: bool = False
    families: tuple[str, ...] = ("ffn",)     # of: ffn, attn, lm_head, embed
    rank: int = 16
    length: int = 2                          # paper §6.4 deploys length-2
    min_factor: int = 8                      # TPU MXU-utilization constraint
    # Surgical per-shape factorization picks — the study engine's trial
    # injection (DESIGN.md §12).  Entries are ((M, N), (ms, ns, ranks)):
    # a projection of shape [N → M] in a covered family uses exactly that
    # TTPlan instead of the config-level best_plan pick.  When any
    # override is present, NON-overridden shapes stay dense even inside
    # covered families, so one candidate plan can be evaluated end-to-end
    # in isolation (same Model entry points, plans still resolved once by
    # the PlanBook — zero re-resolutions during trial evaluation).
    plan_overrides: tuple = ()
    backend: str = "xla"                     # xla | pallas_step | pallas_fused2
                                             #     | pallas_fused | auto
    autotune: str = "cached"                 # off | cached | measure — tile
                                             # selection mode of the measured
                                             # block-plan autotuner
    weights: str = "fp32"                    # fp32 | int8 — resident core
                                             # dtype of the kernel path
                                             # (DESIGN.md §8); int8 keeps the
                                             # packed cores int8 in VMEM

    def override_for(self, M: int, N: int
                     ) -> tuple[tuple, tuple, tuple] | None:
        """The (ms, ns, ranks) override pinned for a [N → M] projection,
        or None."""
        for key, plan in self.plan_overrides:
            if tuple(key) == (M, N):
                return tuple(plan[0]), tuple(plan[1]), tuple(plan[2])
        return None

    @property
    def plan_policy(self) -> tuple[str, str, str]:
        """(backend, tune mode, canonical weight mode) triple consumed by
        the plan resolver (``kernels.plan.PlanBook.from_tt_config``) —
        the typed replacement for :attr:`backend_spec`."""
        return (self.backend, self.autotune,
                "int8" if self.weights == "int8" else "fp")

    @property
    def backend_spec(self) -> str:
        """DEPRECATED stringly-typed spelling of :attr:`plan_policy`:
        backend string with the tune and weight modes folded in
        (``"auto:measure:int8"``).  Kept as a compatibility shim for
        direct ``tt_forward``/``linear_apply`` string callers; the model
        stack resolves ``TTExecutionPlan`` objects through the PlanBook
        instead."""
        spec = self.backend
        if self.autotune != "cached":
            spec += f":{self.autotune}"
        if self.weights == "int8":
            spec += ":int8"
        return spec


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 2
    expert_ff: int = 0
    num_shared: int = 0
    shared_ff: int = 0
    capacity_factor: float = 1.25
    every_n_layers: int = 1                  # MoE at layers where idx % n == n-1
    first_dense_ff: int = 0                  # dense FFN width for layer 0 (dsv2)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention variants
    qk_norm: bool = False
    rope_theta: float = 1e6
    window: int = 0                          # >0 → sliding-window attention
    local_global_period: int = 0             # gemma3: every Nth layer global
    local_window: int = 1024
    mla: MLAConfig | None = None
    attn_every: int = 0                      # jamba: 1 attn layer per period
    attn_index: int = 0                      #        at this index
    # mixture of experts
    moe: MoEConfig | None = None
    # state space
    ssm: SSMConfig | None = None
    # encoder-decoder (seamless)
    enc_dec: bool = False
    num_enc_layers: int = 0
    # multimodal stubs
    frontend: str | None = None              # 'vit' | 'speech'
    frontend_dim: int = 0
    frontend_tokens: int = 0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # paper technique
    tt: TTConfig = TTConfig()
    # attention-kind classification for shape applicability
    subquadratic: bool = False               # can run long_500k decode

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """DESIGN.md §5 skip rules."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch — long_500k needs sub-quadratic attention"
    return True, ""
