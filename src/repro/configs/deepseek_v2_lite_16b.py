"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408 (per expert)
vocab=102400, MoE 64e top-6 + 2 shared — MLA kv_lora=512, first layer dense
(d_ff=10944).  [arXiv:2405.04434; hf]

Note (DESIGN.md §5): MLA is itself a low-rank factorization of the KV path;
TT composes with it on the q/o projections and expert FFNs only.
"""
from .base import MLAConfig, ModelConfig, MoEConfig, TTConfig

FULL = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", num_layers=27, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=102400,
    head_dim=128, rope_theta=1e4,
    mla=MLAConfig(kv_lora=512, rope_head_dim=64, nope_head_dim=128,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, expert_ff=1408, num_shared=2,
                  shared_ff=1408, first_dense_ff=10944),
    subquadratic=False,  # MLA compresses the cache but attention is full
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-16b-smoke", family="moe", num_layers=3,
    d_model=64, num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=256,
    head_dim=16,
    mla=MLAConfig(kv_lora=32, rope_head_dim=8, nope_head_dim=16,
                  v_head_dim=16),
    moe=MoEConfig(num_experts=4, top_k=2, expert_ff=64, num_shared=1,
                  shared_ff=64, first_dense_ff=128,
                  capacity_factor=16.0),  # dropless at test scale
    tt=TTConfig(enabled=True, families=("ffn",), rank=4, min_factor=2),
)
