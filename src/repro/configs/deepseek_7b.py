"""deepseek-7b [dense]: 30L d_model=4096 32H (GQA kv=32 → MHA) d_ff=11008
vocab=102400 — llama-arch.  [arXiv:2401.02954; hf]
"""
from .base import ModelConfig, TTConfig

FULL = ModelConfig(
    name="deepseek-7b", family="dense", num_layers=30, d_model=4096,
    num_heads=32, num_kv_heads=32, d_ff=11008, vocab_size=102400,
    head_dim=128, rope_theta=1e4, subquadratic=False,
)

SMOKE = ModelConfig(
    name="deepseek-7b-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
    rope_theta=1e4,
    tt=TTConfig(enabled=True, families=("ffn", "attn"), rank=4, min_factor=2),
)
