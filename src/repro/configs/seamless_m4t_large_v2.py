"""seamless-m4t-large-v2 [audio]: enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206 — speech frontend STUB
(precomputed frame embeddings of width 1024).  [arXiv:2308.11596; hf]
"""
from .base import ModelConfig, TTConfig

FULL = ModelConfig(
    name="seamless-m4t-large-v2", family="audio", num_layers=24,
    d_model=1024, num_heads=16, num_kv_heads=16, d_ff=8192,
    vocab_size=256206, head_dim=64, rope_theta=1e4,
    enc_dec=True, num_enc_layers=24,
    frontend="speech", frontend_dim=1024,
    subquadratic=False,
)

SMOKE = ModelConfig(
    name="seamless-m4t-large-v2-smoke", family="audio", num_layers=2,
    d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
    head_dim=16, enc_dec=True, num_enc_layers=2,
    frontend="speech", frontend_dim=32,
    tt=TTConfig(enabled=True, families=("ffn",), rank=4, min_factor=2),
)
