"""Config registry: ``get_config(arch, variant)`` + ``build(cfg)`` → Model.

Layer plans (scan groups) are derived from ModelConfig fields here so the
per-arch files stay declarative.
"""
from __future__ import annotations

import importlib
from typing import TYPE_CHECKING

import jax.numpy as jnp

from .base import (MLAConfig, ModelConfig, MoEConfig, SHAPES, ShapeSpec,
                   SSMConfig, TTConfig, shape_applicable)

if TYPE_CHECKING:                      # avoid configs ↔ models import cycle
    from repro.models.model import Model
    from repro.models.transformer import Group

ARCH_IDS = [
    "qwen3_32b", "gemma3_4b", "deepseek_7b", "granite_8b", "jamba_v0_1_52b",
    "deepseek_v2_lite_16b", "mixtral_8x7b", "internvl2_2b", "mamba2_2p7b",
    "seamless_m4t_large_v2",
]

# external ids (--arch flag) → module names
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "qwen3-32b": "qwen3_32b", "gemma3-4b": "gemma3_4b",
    "deepseek-7b": "deepseek_7b", "granite-8b": "granite_8b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "mixtral-8x7b": "mixtral_8x7b", "internvl2-2b": "internvl2_2b",
    "mamba2-2.7b": "mamba2_2p7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
})


def get_config(arch: str, variant: str = "full",
               tt: TTConfig | None = None) -> ModelConfig:
    mod = importlib.import_module(
        f"repro.configs.{ALIASES.get(arch, arch)}")
    cfg: ModelConfig = {"full": mod.FULL, "smoke": mod.SMOKE}[variant]
    if tt is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, tt=tt)
    return cfg


def make_layer_plan(cfg: ModelConfig
                    ) -> tuple[list, list | None]:
    from repro.models.transformer import BlockDef
    L = cfg.num_layers
    if cfg.family == "ssm":
        return [(((BlockDef("ssm", ffn="none"),), L))], None

    if cfg.enc_dec:
        enc = [((BlockDef("gqa", causal=False),), cfg.num_enc_layers)]
        dec = [((BlockDef("gqa", cross=True),), L)]
        return dec, enc

    if cfg.attn_every:                       # jamba: 1 attn per period
        period = []
        for i in range(cfg.attn_every):
            mixer = "gqa" if i == cfg.attn_index else "ssm"
            moe_here = cfg.moe and (i % cfg.moe.every_n_layers
                                    == cfg.moe.every_n_layers - 1)
            period.append(BlockDef(mixer, ffn="moe" if moe_here else "mlp"))
        return [(tuple(period), L // cfg.attn_every)], None

    if cfg.local_global_period:              # gemma3 5:1 local:global
        p = cfg.local_global_period
        local = BlockDef("gqa", window=cfg.local_window, theta=10_000.0)
        glob = BlockDef("gqa", theta=cfg.rope_theta)
        period = tuple([local] * (p - 1) + [glob])
        groups: list[Group] = [(period, L // p)]
        if L % p:
            groups.append(((local,), L % p))
        return groups, None

    if cfg.mla is not None:                  # deepseek-v2
        groups = []
        if cfg.moe and cfg.moe.first_dense_ff:
            groups.append(((BlockDef("mla", ffn="dense0"),), 1))
            groups.append(((BlockDef("mla", ffn="moe"),), L - 1))
        else:
            groups.append(((BlockDef("mla"),), L))
        return groups, None

    ffn = "moe" if (cfg.moe and cfg.moe.num_experts) else "mlp"
    return [((BlockDef("gqa", window=cfg.window, ffn=ffn),), L)], None


def build(cfg: ModelConfig, param_dtype=jnp.float32,
          counts: dict[int, int] | None = None,
          enc_counts: dict[int, int] | None = None) -> "Model":
    from repro.models.model import build_model
    """``counts``/``enc_counts``: optional per-group count overrides (the
    dry-run's reduced-depth roofline compiles use {gi: 1} / {gi: 2})."""
    groups, enc = make_layer_plan(cfg)
    if counts:
        groups = [(p, counts.get(gi, c)) for gi, (p, c) in enumerate(groups)]
    if enc is not None and enc_counts:
        enc = [(p, enc_counts.get(gi, c)) for gi, (p, c) in enumerate(enc)]
    return build_model(cfg, groups, enc, param_dtype)
