"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave (1 attention layer
per 8-layer period, at index 4), MoE every other layer.
[arXiv:2403.19887; hf]
"""
from .base import ModelConfig, MoEConfig, SSMConfig, TTConfig

FULL = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=65536,
    head_dim=128, rope_theta=1e4,
    attn_every=8, attn_index=4,
    moe=MoEConfig(num_experts=16, top_k=2, expert_ff=14336,
                  every_n_layers=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1),
    subquadratic=True,   # hybrid: 28/32 layers are SSM
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke", family="hybrid", num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    attn_every=4, attn_index=2,
    moe=MoEConfig(num_experts=4, top_k=2, expert_ff=128, every_n_layers=2,
                  capacity_factor=16.0),  # dropless at test scale
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1),
    subquadratic=True,
    tt=TTConfig(enabled=True, families=("ffn",), rank=4, min_factor=2),
)
