"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 — llama-arch, code model.  [arXiv:2405.04324; hf]
"""
from .base import ModelConfig, TTConfig

FULL = ModelConfig(
    name="granite-8b", family="dense", num_layers=36, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=49152,
    head_dim=128, rope_theta=1e4, subquadratic=False,
)

SMOKE = ModelConfig(
    name="granite-8b-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    tt=TTConfig(enabled=True, families=("ffn",), rank=4, min_factor=2),
)
