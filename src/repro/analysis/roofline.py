"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (brief §ROOFLINE):

  compute    = HLO_FLOPs / (chips × 197e12)
  memory     = HLO_bytes / (chips × 819e9)
  collective = collective_bytes / (chips × 50e9)

``cost_analysis()`` is *per-device* after SPMD partitioning (verified
empirically: a 2·1024³ matmul on 8 devices reports 2·1024³/8), so global =
per-device × chips and the divisions above reduce to per-device quantities.

collective_bytes is parsed from the partitioned HLO: for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute we take the
per-device result shape and apply a ring-cost factor over its replica-group
size g:  all-gather (g-1)/g·out, all-reduce 2·(g-1)/g·bytes,
reduce-scatter (g-1)/g·in, all-to-all (g-1)/g·bytes, permute 1·bytes.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

from repro.core import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ID_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes_list(type_str: str) -> list[float]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dt])
    return out


def _shape_bytes(type_str: str) -> float:
    return sum(_shape_bytes_list(type_str))


def _group_size(line: str) -> int:
    m = _GROUPS_ID_RE.search(line)
    if m:                       # iota form [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, float]:
    """Per-device bytes-on-wire by op kind (ring model).

    The result type of a ``-start`` op is a tuple ``(operand, result)`` —
    we take the max (the gathered output for all-gather; in == out for
    all-reduce / all-to-all) except for reduce-scatter where the *result*
    (the min) is the per-device shard: ring RS moves (g-1)·out bytes
    (== (g-1)/g of the unreduced input).
    """
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        shapes = _shape_bytes_list(type_str)
        if not shapes:
            continue
        g = _group_size(line)
        if g <= 1:
            continue
        if kind == "reduce-scatter":
            b, factor = min(shapes), float(g - 1)
        elif kind == "all-reduce":
            b, factor = max(shapes), 2.0 * (g - 1) / g
        elif kind == "collective-permute":
            b, factor = max(shapes), 1.0
        else:                       # all-gather / all-to-all
            b, factor = max(shapes), (g - 1) / g
        out[kind] = out.get(kind, 0.0) + b * factor
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class Roofline:
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_per_device: float
    model_flops: float                  # analytic 6·N·D / 2·N·D
    peak_flops: float = hw.PEAK_FLOPS_BF16
    hbm_bw: float = hw.HBM_BW
    ici_bw: float = hw.ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_per_device / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute seconds / bound seconds: how close the dominant
        term lets us get to spending every cycle on model math."""
        t_useful = self.model_flops / (self.chips * self.peak_flops)
        return t_useful / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> dict:
        return {
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_per_device": self.collective_per_device,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_estimate(num_params: int, active_params: int, tokens: int,
                         kind: str) -> float:
    """6·N·D for training, 2·N·D for inference (N = active params)."""
    n = active_params or num_params
    return (6.0 if kind == "train" else 2.0) * n * tokens


# ---------------------------------------------------------------------------
# Report CLI: ``python -m repro.analysis.roofline --table [--variant base]``
# ---------------------------------------------------------------------------

def _load_cells(results_dir: str, variant: str = "base",
                mesh: str | None = "16x16") -> list[dict]:
    import glob
    import json
    import os
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir,
                                              f"*__{variant}.json"))):
        with open(path) as f:
            d = json.load(f)
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def format_table(cells: list[dict]) -> str:
    """Markdown roofline table, one row per ok cell."""
    hdr = ("| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | MODEL/HLO | roofline frac | note |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for d in cells:
        if d.get("status") == "skipped":
            rows.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | —"
                        f" | — | — | — | — | skipped: {d['reason'][:40]} |")
            continue
        if d.get("status") != "ok":
            rows.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | —"
                        f" | — | — | — | — | FAILED |")
            continue
        r = d["roofline"]
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.4f} | |")
    return "\n".join(rows)


def main():
    import argparse
    import os
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--results", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))
    args = ap.parse_args()
    cells = _load_cells(args.results, args.variant,
                        None if args.mesh == "all" else args.mesh)
    print(format_table(cells))


if __name__ == "__main__":
    main()
