# The paper's primary contribution: TT decomposition of FC layers with a
# pruned design-space exploration and hardware-aware kernel planning.
from .tt import TTPlan, make_plan, tt_init, tt_decompose, tt_reconstruct, tt_apply  # noqa: F401
from .flops import (tt_flops, tt_params, dense_flops, dense_params,               # noqa: F401
                    tt_flops_per_einsum, einsum_loop_bounds)
from .dse import DSEConfig, TPU_DSE, explore, count_stages, best_plan             # noqa: F401
from .packing import pack_core, select_blocks, BlockPlan                          # noqa: F401
