"""Generation-based durable array store (DESIGN.md §13).

The common persistence substrate under ``training/checkpoint.py`` and
``serving/faults.py``: a *generation* is one committed directory

    <root>/<prefix>_<NNNNNNNN>/
        arrays.bin      raw array bytes, streamed in bounded chunks
        manifest.json   schema version + per-array index (dtype, shape,
                        byte offset, length, crc32) + the non-array tree

written with the commit protocol a ``kill -9`` cannot tear:

  1. everything lands in a ``<final>.tmp.<pid>`` sibling first,
  2. ``arrays.bin`` and ``manifest.json`` are ``fsync``ed,
  3. the temp dir is atomically renamed onto the final name,
  4. the parent directory is ``fsync``ed so the rename itself is durable.

A crash before (3) leaves only a ``.tmp`` dir, which readers never list;
a crash after (4) leaves a fully-committed generation.  Torn *content*
(truncation, bit rot) is caught at read time: every array carries a
crc32 in the manifest, verified while streaming, and
:func:`load_latest_good` walks generations newest-first until one loads
clean — so a reader observes either a fully-committed generation or a
clear :class:`CorruptGenerationError`, never a torn state.

Arrays are read and written through ``uint8`` views in ``CHUNK_BYTES``
slabs, so peak memory stays bounded by the chunk size, not the largest
leaf (the streamed-checkpoint half of ROADMAP item 4).
"""
from __future__ import annotations

import json
import os
import shutil
import time
import zlib

import numpy as np

# Bumped when the on-disk layout changes; readers reject other schemas
# with a clear error instead of misinterpreting bytes.
DURABLE_SCHEMA = 1

# Streaming slab size for both read and write paths.
CHUNK_BYTES = 1 << 20


class CorruptGenerationError(RuntimeError):
    """A committed generation failed validation (truncated file, checksum
    mismatch, unreadable or wrong-schema manifest)."""


def resolve_dtype(name: str) -> np.dtype:
    """dtype from its manifest name, including the ml_dtypes extensions
    (bf16 cache leaves) that plain numpy only knows once registered."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        try:
            return np.dtype(getattr(ml_dtypes, name))
        except AttributeError:
            raise CorruptGenerationError(
                f"unknown array dtype {name!r} in manifest") from None


def fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flat_bytes(a: np.ndarray) -> np.ndarray:
    """A flat uint8 view of ``a`` (0-d and empty arrays included)."""
    return np.ascontiguousarray(a).reshape(-1).view(np.uint8)


def write_arrays(dirpath: str, arrays: dict,
                 chunk_bytes: int = CHUNK_BYTES) -> dict:
    """Stream ``arrays`` into ``<dirpath>/arrays.bin`` in ``chunk_bytes``
    slabs, fsync it, and return the manifest index
    ``{key: {dtype, shape, offset, nbytes, crc32}}``."""
    index: dict[str, dict] = {}
    offset = 0
    with open(os.path.join(dirpath, "arrays.bin"), "wb") as f:
        for key in sorted(arrays):
            a = np.asarray(arrays[key])
            flat = _flat_bytes(a)
            crc = 0
            for i in range(0, flat.nbytes, chunk_bytes):
                chunk = flat[i:i + chunk_bytes].tobytes()
                f.write(chunk)
                crc = zlib.crc32(chunk, crc)
            index[key] = {"dtype": a.dtype.name, "shape": list(a.shape),
                          "offset": offset, "nbytes": int(flat.nbytes),
                          "crc32": crc}
            offset += flat.nbytes
        f.flush()
        os.fsync(f.fileno())
    return index


def read_arrays(bin_path: str, index: dict, verify: bool = True,
                chunk_bytes: int = CHUNK_BYTES) -> dict:
    """Stream arrays back from ``bin_path`` per the manifest ``index``,
    verifying each crc32 as the bytes go by.  Truncation and corruption
    raise :class:`CorruptGenerationError` naming the offending array."""
    out: dict[str, np.ndarray] = {}
    try:
        f = open(bin_path, "rb")
    except OSError as e:
        raise CorruptGenerationError(f"{bin_path}: unreadable ({e})")
    with f:
        size = os.fstat(f.fileno()).st_size
        for key in sorted(index):
            meta = index[key]
            end = meta["offset"] + meta["nbytes"]
            if end > size:
                raise CorruptGenerationError(
                    f"{bin_path}: array {key!r} extends past end of file "
                    f"(needs bytes [{meta['offset']}, {end}), file has "
                    f"{size} — truncated write)")
            a = np.empty(tuple(meta["shape"]),
                         dtype=resolve_dtype(meta["dtype"]))
            dst = memoryview(a.reshape(-1).view(np.uint8))
            f.seek(meta["offset"])
            crc = 0
            got = 0
            while got < meta["nbytes"]:
                n = f.readinto(dst[got:got + chunk_bytes])
                if not n:
                    raise CorruptGenerationError(
                        f"{bin_path}: short read on array {key!r}")
                crc = zlib.crc32(dst[got:got + n], crc)
                got += n
            if verify and crc != meta["crc32"]:
                raise CorruptGenerationError(
                    f"{bin_path}: checksum mismatch on array {key!r} "
                    f"(stored {meta['crc32']:#x}, computed {crc:#x} — "
                    f"corrupted content)")
            out[key] = a
    return out


# --------------------------------------------------------------- generations
def _gen_dir(root: str, gen: int, prefix: str) -> str:
    return os.path.join(root, f"{prefix}_{gen:08d}")


def committed_generations(root: str, prefix: str = "gen") -> list[int]:
    """Generation numbers with a committed manifest, sorted ascending.
    ``.tmp`` leftovers from crashed writes are invisible by construction."""
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if not name.startswith(prefix + "_") or ".tmp" in name:
            continue
        tail = name[len(prefix) + 1:]
        if tail.isdigit() and os.path.exists(
                os.path.join(root, name, "manifest.json")):
            out.append(int(tail))
    return sorted(out)


def write_generation(root: str, tree, arrays: dict, *, prefix: str = "gen",
                     extra: dict | None = None,
                     chunk_bytes: int = CHUNK_BYTES) -> int:
    """Commit the next generation under ``root`` (temp + fsync + atomic
    rename + parent fsync).  ``tree`` is the JSON-serializable non-array
    payload; ``arrays`` the leaves it references.  Returns the generation
    number."""
    os.makedirs(root, exist_ok=True)
    gens = committed_generations(root, prefix)
    gen = (gens[-1] if gens else 0) + 1
    final = _gen_dir(root, gen, prefix)
    tmp = final + f".tmp.{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        index = write_arrays(tmp, arrays, chunk_bytes)
        manifest = {"schema": DURABLE_SCHEMA, "generation": gen,
                    "time": time.time(), "arrays": index, "tree": tree,
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    fsync_dir(root)
    return gen


def load_generation(root: str, gen: int, *, prefix: str = "gen",
                    verify: bool = True) -> tuple[object, dict, dict]:
    """Load one committed generation → (tree, arrays, manifest), verifying
    every checksum.  Raises :class:`CorruptGenerationError` on any damage."""
    d = _gen_dir(root, gen, prefix)
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CorruptGenerationError(f"{d}: unreadable manifest ({e})")
    if not isinstance(manifest, dict) \
            or manifest.get("schema") != DURABLE_SCHEMA:
        raise CorruptGenerationError(
            f"{d}: manifest schema "
            f"{manifest.get('schema') if isinstance(manifest, dict) else '?'!r}"
            f" != {DURABLE_SCHEMA} (written by an incompatible version)")
    arrays = read_arrays(os.path.join(d, "arrays.bin"), manifest["arrays"],
                         verify=verify)
    return manifest["tree"], arrays, manifest


def load_latest_good(root: str, *, prefix: str = "gen"
                     ) -> tuple[int, object, dict, dict, list[str]]:
    """Newest generation that loads clean → (gen, tree, arrays, manifest,
    skipped) where ``skipped`` describes every newer corrupt generation
    that was passed over.  Raises FileNotFoundError when no generation is
    committed and :class:`CorruptGenerationError` when all are damaged."""
    gens = committed_generations(root, prefix)
    if not gens:
        raise FileNotFoundError(f"no committed generations under {root}")
    skipped: list[str] = []
    for g in reversed(gens):
        try:
            tree, arrays, manifest = load_generation(root, g, prefix=prefix)
            return g, tree, arrays, manifest, skipped
        except CorruptGenerationError as e:
            skipped.append(str(e))
    raise CorruptGenerationError(
        f"every generation under {root} is corrupt:\n  "
        + "\n  ".join(skipped))


def prune_generations(root: str, keep: int = 3, *,
                      prefix: str = "gen") -> None:
    for g in committed_generations(root, prefix)[:-keep]:
        shutil.rmtree(_gen_dir(root, g, prefix), ignore_errors=True)
