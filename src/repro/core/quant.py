"""int8 TT-core quantization (beyond-paper, edge-deployment extension).

The paper compresses FC layers ~100–300× via TT; for its edge/embedded
target the cores can be held in int8 with per-core scales for another
~4× (vs fp32) / ~2× (vs bf16) of weight memory, dequantized on the fly.
Because the cores are tiny, dequantization cost is negligible next to the
chain contraction; because each core's dynamic range is narrow (iid init,
trained with weight decay), symmetric per-core scaling loses little.

Error model: per element |ŵ − w| ≤ s/2 with s = max|core|/127; the chain
multiplies d cores, so the relative output error grows ~linearly in d
(tests bound it empirically).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def quantize_cores(cores: Sequence[jax.Array]
                   ) -> tuple[list[jax.Array], list[jax.Array]]:
    """[G_t] → ([int8 cores], [fp32 scales])."""
    qs, ss = [], []
    for G in cores:
        s = jnp.max(jnp.abs(G.astype(jnp.float32))) / 127.0 + 1e-12
        qs.append(jnp.clip(jnp.round(G.astype(jnp.float32) / s),
                           -127, 127).astype(jnp.int8))
        ss.append(s)
    return qs, ss


def dequantize_cores(qcores: Sequence[jax.Array],
                     scales: Sequence[jax.Array],
                     dtype=jnp.bfloat16) -> list[jax.Array]:
    return [(q.astype(jnp.float32) * s).astype(dtype)
            for q, s in zip(qcores, scales)]


def quantized_bytes(qcores, scales) -> int:
    return sum(q.size for q in qcores) + 4 * len(scales)


def tt_apply_int8(qcores, scales, x: jax.Array,
                  bias: jax.Array | None = None) -> jax.Array:
    """Apply a TT layer from int8 cores (dequant-on-the-fly)."""
    from .tt import tt_apply
    return tt_apply(dequantize_cores(qcores, scales, x.dtype), x, bias)
