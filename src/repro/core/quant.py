"""int8 TT-core quantization (beyond-paper, edge-deployment extension).

The paper compresses FC layers ~100–300× via TT; for its edge/embedded
target the cores can be held in int8 with per-core scales for another
~4× (vs fp32) / ~2× (vs bf16) of weight memory.  Since PR 3 the packed
int8 cores reach the Pallas kernels *as int8* (kernels/tt_contract.py:
dequantization is folded into the matmul epilogue inside VMEM), so the
4× shrinks the VMEM-residency term of the fused-chain fit test
(core.packing, DESIGN.md §8) — quantization buys bandwidth and fused
eligibility, not just checkpoint size.

Scale placement: one symmetric scale per core.  Packing
(``core.packing.pack_core``) is a pure relayout (transpose + reshape), so
max|G| == max|pack_core(G)| and the per-core scale IS the per-packed-matrix
scale — ``pack_core_int8`` and ``pack_core(quantize(G))`` commute exactly.

Error model: per element |ŵ − w| ≤ s/2 with s = max|core|/127
(``roundtrip_bound``); the chain is multilinear in the d cores, so the
relative output error grows ~linearly in d (``chain_error_bound``; tests
bound it empirically, including under hypothesis).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def core_scale(G: jax.Array) -> jax.Array:
    """Symmetric per-core scale, guarded for the all-zero core: an
    epsilon-sized scale would make the round-trip emit denormal noise
    (q·1e-12 underflows on some targets), so a zero core quantizes with
    scale 1 and round-trips to exact zeros."""
    amax = jnp.max(jnp.abs(G.astype(jnp.float32)))
    return jnp.where(amax > 0, amax / 127.0, 1.0)


def quantize_core(G: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One core → (int8 core, fp32 scale)."""
    s = core_scale(G)
    q = jnp.clip(jnp.round(G.astype(jnp.float32) / s),
                 -127, 127).astype(jnp.int8)
    return q, s


def quantize_cores(cores: Sequence[jax.Array]
                   ) -> tuple[list[jax.Array], list[jax.Array]]:
    """[G_t] → ([int8 cores], [fp32 scales])."""
    qs, ss = [], []
    for G in cores:
        q, s = quantize_core(G)
        qs.append(q)
        ss.append(s)
    return qs, ss


def dequantize_cores(qcores: Sequence[jax.Array],
                     scales: Sequence[jax.Array],
                     dtype=jnp.bfloat16) -> list[jax.Array]:
    return [(q.astype(jnp.float32) * s).astype(dtype)
            for q, s in zip(qcores, scales)]


def pack_core_int8(G: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Compile-time pack + quantize of one TT core for the int8 kernels.

    ``G [r_{t-1}, n_t, m_t, r_t]`` → ``(P_q [(n_t·r_t), (m_t·r_{t-1})]
    int8, scale fp32)`` with ONE scale per packed matrix.  Because packing
    only permutes elements, quantize-then-pack and pack-then-quantize give
    bit-identical results; this entry packs first so the quantization grid
    is defined on exactly the matrix the MXU consumes.
    """
    from .packing import pack_core
    return quantize_core(pack_core(G))


def quantized_bytes(qcores, scales) -> int:
    return sum(q.size for q in qcores) + 4 * len(scales)


# ---------------------------------------------------------------------------
# Error bounds (round-trip and chain growth)
# ---------------------------------------------------------------------------

def roundtrip_bound(G: jax.Array) -> jax.Array:
    """Elementwise bound on the quantization round-trip error: for every
    element, |dequant(quant(G)) − G| ≤ scale/2 (nearest-grid-point
    rounding on the symmetric 254-step grid)."""
    return core_scale(G) * 0.5


def chain_error_bound(cores: Sequence[jax.Array]) -> float:
    """First-order relative output-error bound of the int8 chain.

    The chain output is multilinear in the d cores, so to first order

      ‖Δy‖/‖y‖ ≲ Σ_t ‖ΔG_t‖/‖G_t‖ ≤ Σ_t (s_t/2)·√(size_t) / ‖G_t‖,

    i.e. error grows ~linearly in d.  This is a *guidance* bound (exact to
    first order in the perturbation); tests check the measured chain error
    stays below it with margin.
    """
    total = 0.0
    for G in cores:
        g32 = G.astype(jnp.float32)
        norm = float(jnp.linalg.norm(g32))
        if norm == 0.0:
            continue                     # zero core round-trips exactly
        bound = float(roundtrip_bound(G)) * float(jnp.sqrt(G.size))
        total += bound / norm
    return total


def tt_apply_int8(qcores, scales, x: jax.Array,
                  bias: jax.Array | None = None) -> jax.Array:
    """Apply a TT layer from int8 cores (dequant-on-the-fly, XLA chain —
    the host-dequant baseline; the kernel path is kernels.ops.tt_forward
    with ``weights='int8'``)."""
    from .tt import tt_apply
    return tt_apply(dequantize_cores(qcores, scales, x.dtype), x, bias)
