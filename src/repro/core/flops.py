"""Analytic parameter / FLOP models for TT-decomposed FC layers.

Implements Eq. (4) (parameters), Eq. (11)/(13) (FLOPs) of the paper
*Optimizing Tensor Train Decomposition in DNNs for RISC-V Architectures*.

Conventions (paper §2): an FC layer ``y = Wx + b`` with ``W ∈ R^{M×N}`` is
factorized with output factors ``ms = [m_1..m_d]`` (``Π m_t = M``) and input
factors ``ns = [n_1..n_d]`` (``Π n_t = N``) and TT-ranks
``ranks = [r_0..r_d]`` with ``r_0 = r_d = 1``.  Core ``t`` has shape
``[r_{t-1}, n_t, m_t, r_t]``.

All functions are pure Python over ints so the DSE can run without touching
jax device state.
"""
from __future__ import annotations

import math
from typing import Sequence


def prod(xs: Sequence[int]) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def dense_params(M: int, N: int, bias: bool = True) -> int:
    """Parameters of the unfactorized FC layer: M*N (+ M bias)."""
    return M * N + (M if bias else 0)


def dense_flops(M: int, N: int, bias: bool = True) -> int:
    """FLOPs of one dense matrix–vector product: 2*M*N (+ M bias adds)."""
    return 2 * M * N + (M if bias else 0)


def tt_params(ms: Sequence[int], ns: Sequence[int], ranks: Sequence[int],
              bias: bool = True) -> int:
    """Eq. (4): Memory = M + Σ_t r_{t-1}·m_t·n_t·r_t."""
    d = len(ms)
    assert len(ns) == d and len(ranks) == d + 1
    core = sum(ranks[t] * ms[t] * ns[t] * ranks[t + 1] for t in range(d))
    return core + (prod(ms) if bias else 0)


def tt_flops_step(ms: Sequence[int], ns: Sequence[int], ranks: Sequence[int],
                  t: int) -> int:
    """Eq. (13): FLOPs of einsum step ``t`` (1-indexed like the paper).

    FLOPs^(t) = 2 · r_t · r_{t-1} · m_t···m_d · n_1···n_t
    """
    d = len(ms)
    assert 1 <= t <= d
    m_tail = prod(ms[t - 1:])          # m_t … m_d
    n_head = prod(ns[:t])              # n_1 … n_t
    return 2 * ranks[t] * ranks[t - 1] * m_tail * n_head


def tt_flops(ms: Sequence[int], ns: Sequence[int], ranks: Sequence[int],
             bias: bool = True) -> int:
    """Eq. (11): FLOPs = M + Σ_t FLOPs^(t)."""
    d = len(ms)
    total = sum(tt_flops_step(ms, ns, ranks, t) for t in range(1, d + 1))
    return total + (prod(ms) if bias else 0)


def tt_flops_per_einsum(ms: Sequence[int], ns: Sequence[int],
                        ranks: Sequence[int]) -> list[int]:
    """Per-einsum FLOPs, ordered t = 1 … d (paper's last-executed first)."""
    return [tt_flops_step(ms, ns, ranks, t) for t in range(1, len(ms) + 1)]


def max_tt_rank_at_cut(ms: Sequence[int], ns: Sequence[int], t: int) -> int:
    """Paper footnote 5: the maximum feasible r_t is bounded by the matrix
    rank of the t-th unfolding: min(Π_{i≤t} m_i·n_i, Π_{i>t} m_i·n_i)."""
    left = prod(ms[:t]) * prod(ns[:t])
    right = prod(ms[t:]) * prod(ns[t:])
    return min(left, right)


def clip_ranks(ms: Sequence[int], ns: Sequence[int],
               ranks: Sequence[int]) -> tuple[int, ...]:
    """Clip a requested rank list to the feasible TT max rank at each cut."""
    d = len(ms)
    out = [1]
    for t in range(1, d):
        out.append(min(int(ranks[t]), max_tt_rank_at_cut(ms, ns, t)))
    out.append(1)
    return tuple(out)


def compression_ratio(ms, ns, ranks, bias: bool = True) -> float:
    return dense_params(prod(ms), prod(ns), bias) / max(
        1, tt_params(ms, ns, ranks, bias))


def einsum_loop_bounds(ms: Sequence[int], ns: Sequence[int],
                       ranks: Sequence[int], batch: int = 1
                       ) -> list[dict[str, int]]:
    """Loop bounds {mt, bt, nt, rt, rt_1} of each einsum kernel, in
    *execution* order (core d first), as in paper Listing 2 / Table 3.

    ``bt`` is the flattened remainder dimension; with a token batch ``batch``
    it is folded in (paper evaluates batch=1 vectors; we generalize).
    """
    d = len(ms)
    N = prod(ns)
    out = []
    # execution order: t = d, d-1, …, 1
    b = batch * N
    for t in range(d, 0, -1):
        nt, mt = ns[t - 1], ms[t - 1]
        rt, rt_1 = ranks[t], ranks[t - 1]
        bt = b // (nt * rt)
        out.append(dict(t=t, mt=mt, bt=bt, nt=nt, rt=rt, rt_1=rt_1,
                        flops=2 * mt * bt * nt * rt * rt_1))
        # next state has size mt * bt * rt_1
        b = mt * bt * rt_1
    return out


def num_permutations_aligned(ms: Sequence[int], ns: Sequence[int]) -> int:
    """Proposition 4: number of (m-perm, n-perm) pairs collapsing onto one
    aligned representative: (d!)² / (k_1!·…·k_j!) where k_i are the
    multiplicities of repeated values within each list."""
    d = len(ms)
    denom = 1
    for seq in (ms, ns):
        for v in set(seq):
            denom *= math.factorial(list(seq).count(v))
    return (math.factorial(d) ** 2) // denom
