"""DSE study engine: persistent, resumable, data-aware trial evaluation
(DESIGN.md §12).

``core.dse`` is the analytic funnel — enumerate, prune, rank by static
cost.  This module closes the accuracy loop around it, Optuna-style
(SNIPPETS §2): a :class:`Study` owns a trial space (the funnel's
survivors), evaluates trials in parallel batches against *measured*
objectives, and persists every outcome to a schema-versioned JSON file so
an interrupted study resumes bit-deterministically.

Three layers, composable:

* :class:`Study` — the engine: trial bookkeeping, atomic persistence
  (temp + ``os.replace``, same idiom as the autotune cache), batched
  parallel execution, seeded resume, pluggable objectives.
* :func:`activation_score` — the data term: whitened weight-space error
  ``‖(W − Ŵ)X‖_F / ‖W X‖_F`` evaluated from a calibration second moment
  ``Σ = E[xxᵀ]`` (Data-Driven Low-Rank Compression, arxiv 2107.05787) —
  no activations stored, only the [N, N] Gram from
  ``Model.activation_stats``.
* :func:`make_model_evaluator` — the end-to-end trial evaluator: builds a
  TT twin of a dense reference model with exactly one projection
  factorized (``TTConfig.plan_overrides``), decompose-initialized from
  the dense weights, optionally finetuned (``training.finetune``), and
  measures activation error, perplexity delta, and serving decode tok/s
  through the frozen-plan ``Model``/``TTExecutionPlan`` path — asserting
  ZERO plan re-resolutions during the measured window.

State file schema (``STUDY_SCHEMA``):

.. code-block:: json

    {"schema": 1, "M": 128, "N": 64, "seed": 0,
     "trials": [{"tid": 0, "seed": 913, "status": "done",
                 "solution": {"ms": [...], "ns": [...], "ranks": [...],
                              "weight_dtype": "fp32"},
                 "metrics": {"act_err": 0.01, "ppl_delta": 0.2,
                             "tok_s": 512.0}}]}

Unknown schemas are refused loudly (a study is an experiment record —
silently reinterpreting one corrupts science); plan identity is stored as
(ms, ns, ranks) and re-derived through ``generate_candidates``-equivalent
pricing on load, so static costs can never drift from the code that
computed them.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Sequence

import numpy as np

from .dse import (DSEConfig, DSEResult, Solution, count_stages,
                  generate_candidates, plan_err_proxy, weight_bytes,
                  with_metrics)
from .flops import einsum_loop_bounds, tt_flops, tt_params
from .tt import TTPlan, tt_decompose, tt_reconstruct

STUDY_SCHEMA = 1


# ---------------------------------------------------------------------------
# Solution (de)serialization — plan identity only; costs re-priced on load
# ---------------------------------------------------------------------------

def solution_from_plan(ms: Sequence[int], ns: Sequence[int],
                       ranks: Sequence[int], weight_dtype: str,
                       cfg: DSEConfig = DSEConfig()) -> Solution:
    """Price a (ms, ns, ranks, dtype) identity into a full Solution with
    the same static costs :func:`repro.core.dse.generate_candidates`
    would attach — the load-path twin of candidate generation."""
    plan = TTPlan(tuple(int(m) for m in ms), tuple(int(n) for n in ns),
                  tuple(int(r) for r in ranks))
    f = tt_flops(plan.ms, plan.ns, plan.ranks)
    p = tt_params(plan.ms, plan.ns, plan.ranks)
    bounds = einsum_loop_bounds(plan.ms, plan.ns, plan.ranks, cfg.batch)
    from .dse import select_threads
    threads = tuple(select_threads(b["flops"], cfg) for b in bounds)
    return Solution(plan, f, p, threads,
                    max(b["flops"] for b in bounds),
                    weight_dtype=weight_dtype,
                    # packed core elements (plan.params), NOT the padded
                    # kernel layout count p — must match the generator
                    bytes=weight_bytes(plan.params, plan.d, weight_dtype),
                    err_proxy=plan_err_proxy(plan, weight_dtype))


def _sol_to_dict(s: Solution) -> dict:
    return {"ms": list(s.plan.ms), "ns": list(s.plan.ns),
            "ranks": list(s.plan.ranks), "weight_dtype": s.weight_dtype}


# ---------------------------------------------------------------------------
# Trials
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Trial:
    tid: int
    solution: Solution
    seed: int
    status: str = "pending"            # pending | done | failed
    metrics: dict = dataclasses.field(default_factory=dict)

    @property
    def measured(self) -> Solution:
        return with_metrics(self.solution, self.metrics)


def trial_seed(study_seed: int, tid: int) -> int:
    """Deterministic per-trial seed — a pure function of (study seed,
    tid), NOT of execution order, so a resumed study re-derives identical
    randomness for its remaining trials."""
    return (study_seed * 1_000_003 + tid * 9_176) % (2 ** 31 - 1)


# ---------------------------------------------------------------------------
# The study engine
# ---------------------------------------------------------------------------

class Study:
    """Persistent, resumable DSE study over one FC layer's trial space.

    Lifecycle: :meth:`create` enumerates the funnel's survivors into
    pending trials and persists them; :meth:`run` evaluates pending
    trials in parallel batches, checkpointing state after every batch
    (so a kill mid-study loses at most one in-flight batch, and those
    trials simply re-run on resume — same seeds, same results);
    :meth:`load` / :meth:`open` resume.  Results are recorded by trial
    id, never by completion order, so rankings are deterministic under
    any worker interleaving."""

    def __init__(self, path: str, M: int, N: int, seed: int,
                 trials: list[Trial], dse: DSEConfig = DSEConfig()):
        self.path = path
        self.M, self.N, self.seed = int(M), int(N), int(seed)
        self.trials = trials
        self.dse = dse

    # -------------------------------------------------------- construction
    @classmethod
    def create(cls, path: str, M: int, N: int,
               cfg: DSEConfig = DSEConfig(), seed: int = 0,
               max_trials: int | None = None) -> "Study":
        """Seed a fresh study: the funnel's survivors (static-cost order,
        cheapest first) become the trial space.  Refuses to clobber an
        existing state file — resuming and re-creating must never be
        confusable."""
        if os.path.exists(path):
            raise FileExistsError(
                f"study state already exists at {path} — Study.load() to "
                f"resume, or remove the file to start over")
        sols = sorted(generate_candidates(M, N, cfg),
                      key=lambda s: (s.flops, s.params, s.bytes))
        if max_trials is not None:
            sols = sols[:max_trials]
        trials = [Trial(tid=i, solution=s, seed=trial_seed(seed, i))
                  for i, s in enumerate(sols)]
        study = cls(path, M, N, seed, trials, cfg)
        study.save()
        return study

    @classmethod
    def load(cls, path: str, cfg: DSEConfig = DSEConfig()) -> "Study":
        with open(path) as f:
            state = json.load(f)
        schema = state.get("schema")
        if schema != STUDY_SCHEMA:
            raise ValueError(
                f"study state {path} has schema {schema!r}, this code "
                f"speaks {STUDY_SCHEMA} — refusing to reinterpret an "
                f"experiment record")
        trials = [Trial(tid=int(t["tid"]),
                        solution=solution_from_plan(
                            cfg=cfg, **t["solution"]),
                        seed=int(t["seed"]),
                        status=t.get("status", "pending"),
                        metrics=dict(t.get("metrics", {})))
                  for t in state["trials"]]
        return cls(path, state["M"], state["N"], state["seed"], trials, cfg)

    @classmethod
    def open(cls, path: str, M: int, N: int,
             cfg: DSEConfig = DSEConfig(), seed: int = 0,
             max_trials: int | None = None) -> "Study":
        """Resume-or-create entry point (what the CLI uses)."""
        if os.path.exists(path):
            return cls.load(path, cfg)
        return cls.create(path, M, N, cfg, seed, max_trials)

    # -------------------------------------------------------- persistence
    def to_state(self) -> dict:
        return {"schema": STUDY_SCHEMA, "M": self.M, "N": self.N,
                "seed": self.seed,
                "trials": [{"tid": t.tid, "seed": t.seed,
                            "status": t.status,
                            "solution": _sol_to_dict(t.solution),
                            "metrics": t.metrics}
                           for t in self.trials]}

    def save(self) -> None:
        """Atomic write: temp file + ``os.replace`` in the target's
        directory (same filesystem ⇒ atomic rename), the autotune-cache
        idiom — a crash mid-save leaves the previous state intact, never
        a torn JSON."""
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_state(), f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    # ---------------------------------------------------------- execution
    def pending(self) -> list[Trial]:
        return [t for t in self.trials if t.status == "pending"]

    def run(self, evaluate: Callable[..., dict], batch_size: int = 4,
            max_trials: int | None = None, workers: int | None = None,
            log: Callable[[str], None] | None = None) -> int:
        """Evaluate pending trials in tid order, ``batch_size`` at a time
        on a thread pool (trial evaluation is jax-compute-bound, which
        releases the GIL; process workers would re-trace every model per
        trial).  ``evaluate(solution, seed)`` → metrics dict; a raising
        trial is recorded ``failed`` with the error message, it does not
        take the study down.  State is checkpointed after every batch.
        Returns the number of trials evaluated this call."""
        todo = self.pending()
        if max_trials is not None:
            todo = todo[:max_trials]
        done = 0
        for i in range(0, len(todo), max(batch_size, 1)):
            batch = todo[i:i + max(batch_size, 1)]
            with ThreadPoolExecutor(
                    max_workers=workers or max(len(batch), 1)) as pool:
                futs = [pool.submit(self._run_one, evaluate, t)
                        for t in batch]
                for t, fut in zip(batch, futs):
                    t.status, t.metrics = fut.result()
            done += len(batch)
            self.save()
            if log is not None:
                for t in batch:
                    log(f"trial {t.tid} [{t.solution.plan.describe()} "
                        f"{t.solution.weight_dtype}] → {t.status} "
                        f"{t.metrics}")
        return done

    @staticmethod
    def _run_one(evaluate, trial: Trial) -> tuple[str, dict]:
        try:
            metrics = evaluate(trial.solution, trial.seed)
        except Exception as e:                      # noqa: BLE001
            return "failed", {"error": f"{type(e).__name__}: {e}"}
        return "done", {k: (float(v) if isinstance(v, (int, float))
                            else v) for k, v in metrics.items()}

    # ------------------------------------------------------------ results
    def completed(self) -> list[Trial]:
        return [t for t in self.trials if t.status == "done"]

    def ranking(self, objective: Callable[[Trial], float] | None = None
                ) -> list[Trial]:
        """Completed trials sorted ascending by ``objective`` (default:
        measured perplexity delta, static FLOPs as tiebreak), tid as the
        final tiebreak so equal-objective orderings are deterministic."""
        obj = objective or (lambda t: (
            t.metrics.get("ppl_delta", float("inf")), t.solution.flops))
        return sorted(self.completed(), key=lambda t: (obj(t), t.tid))

    def best(self, objective: Callable[[Trial], float] | None = None
             ) -> Trial:
        ranked = self.ranking(objective)
        if not ranked:
            raise ValueError(f"study {self.path} has no completed trials")
        return ranked[0]

    def result(self, with_counts: bool = False) -> DSEResult:
        """The study as a :class:`DSEResult`: every completed trial's
        solution with its measured metrics attached — feeds straight into
        ``DSEResult.measured_front`` / ``pareto_front``."""
        counts = count_stages(self.M, self.N, self.dse) if with_counts \
            else {}
        counts = dict(counts, trials=len(self.trials),
                      trials_done=len(self.completed()))
        sols = sorted((t.measured for t in self.completed()),
                      key=lambda s: (s.flops, s.params, s.bytes))
        return DSEResult(self.M, self.N, counts, sols)


# ---------------------------------------------------------------------------
# Activation-aware scoring (the data term)
# ---------------------------------------------------------------------------

def activation_score(W, plan: TTPlan, sigma, weight_dtype: str = "fp32"
                     ) -> float:
    """Data-aware relative error of factorizing ``W [M, N]`` per ``plan``:
    ``‖(W − Ŵ) X‖_F / ‖W X‖_F`` over the calibration distribution,
    computed from the input second moment ``Σ = E[xxᵀ] [N, N]`` as
    ``√(tr(ΔΣΔᵀ) / tr(WΣWᵀ))`` with ``Δ = W − Ŵ`` — exact for the
    captured batches, no activations materialized.

    ``Ŵ`` is the TT-SVD reconstruction at the plan's ranks; for int8
    candidates the cores are additionally round-tripped through the
    serving quantizer, so the score prices what the deployed kernels
    actually multiply by — the fp32 and int8 twins of one plan get
    genuinely different data-aware scores."""
    W = np.asarray(W, np.float64)
    if W.shape != (plan.M, plan.N):
        raise ValueError(f"W shape {W.shape} does not match plan "
                         f"[{plan.M}x{plan.N}]")
    cores = tt_decompose(W, plan)
    if weight_dtype == "int8":
        import jax.numpy as jnp

        from .quant import dequantize_cores, quantize_cores
        q, s = quantize_cores([np.asarray(c) for c in cores])
        cores = [np.asarray(c) for c in dequantize_cores(q, s,
                                                         jnp.float32)]
    W_hat = np.asarray(tt_reconstruct([np.asarray(c, np.float64)
                                       for c in cores]), np.float64)
    sigma = np.asarray(sigma, np.float64)
    delta = W - W_hat
    num = float(np.trace(delta @ sigma @ delta.T))
    den = float(np.trace(W @ sigma @ W.T))
    return float(np.sqrt(max(num, 0.0) / max(den, 1e-30)))


# ---------------------------------------------------------------------------
# Model-level trial evaluator (the end-to-end term)
# ---------------------------------------------------------------------------

def _dense_weights_by_shape(params) -> dict[tuple[int, int], np.ndarray]:
    """Map (N, M) → one dense weight slice [N, M] from a parameter tree
    (first layer of a scanned stack — the representative the data-aware
    score factorizes)."""
    out: dict[tuple[int, int], np.ndarray] = {}

    def walk(node):
        if not isinstance(node, dict):
            return
        for k, v in node.items():
            if k == "w" and not isinstance(v, dict):
                w = np.asarray(v)
                w = w.reshape((-1,) + w.shape[-2:])[0]
                out.setdefault((w.shape[0], w.shape[1]), w)
            elif isinstance(v, dict):
                walk(v)
    walk(params)
    return out


def _copy_backbone(tt_params: dict, dense_params: dict) -> dict:
    """Overlay every non-TT leaf of the twin with the dense reference's
    value, so dense and twin differ ONLY in the factorized projection."""
    def walk(t_node, d_node):
        out = {}
        for k, v in t_node.items():
            if k == "tt":
                out[k] = v
            elif isinstance(v, dict):
                out[k] = walk(v, d_node.get(k, {})
                              if isinstance(d_node, dict) else {})
            else:
                dv = (d_node.get(k) if isinstance(d_node, dict) else None)
                out[k] = dv if dv is not None else v
        return out
    return walk(tt_params, dense_params)


def _decode_tok_s(model, params, slots: int, prompt: int, steps: int
                  ) -> float:
    """Steady-state decode tok/s through the continuous-batching
    scheduler at full occupancy (the ``bench_serve_tt`` evaluator shape:
    admissions + compiles outside the timed window)."""
    import time

    from repro.data.pipeline import make_batch
    from repro.serving.scheduler import Request, Scheduler

    budget = steps + 4
    sched = Scheduler(model, params, num_slots=slots,
                      cache_len=prompt + budget + 2)
    for b in range(slots):
        toks = make_batch(model.cfg, 1, prompt, step=b)["tokens"]
        sched.submit(Request(uid=b, inputs={"tokens": toks},
                             max_new_tokens=budget))
    sched.step()                   # admissions + first masked step
    sched.step()                   # warm steady step
    t0 = time.perf_counter()
    for _ in range(steps):
        sched.step()
    return slots * steps / (time.perf_counter() - t0)


# trials evaluating right now, across all evaluators (Study.run batches
# share the process).  The global kernels.plan.PLAN_RESOLUTIONS counter is
# only meaningful for the zero-replan assert when exactly one trial is in
# flight — a concurrent trial's *build-time* priming legitimately bumps it
# inside this trial's measured window.  The always-on invariant is
# model-scoped instead: this twin's PlanBook must not grow.
_IN_FLIGHT = 0
_IN_FLIGHT_LOCK = threading.Lock()


@dataclasses.dataclass(frozen=True)
class EvaluatorConfig:
    family: str = "ffn"            # families the twin may factorize in
    n_calib: int = 2               # calibration batches (activation stats)
    n_eval: int = 2                # held-out batches (perplexity)
    batch: int = 2
    seq: int = 32
    calib_seed: int = 7777         # disjoint from the training default
    measure_tok_s: bool = False    # serving throughput per trial (slow)
    serve_slots: int = 2
    serve_prompt: int = 8
    serve_steps: int = 16
    finetune_steps: int = 0        # >0: rank-adaptive core finetune before
                                   # the perplexity measurement
    train_steps: int = 0           # >0: train the dense reference first —
                                   # an untrained net's weights are noise,
                                   # so rank wouldn't correlate with
                                   # quality and every trial would tie


def make_model_evaluator(cfg, ecfg: EvaluatorConfig = EvaluatorConfig(),
                         seed: int = 0):
    """Build the end-to-end trial evaluator for one model config.

    Returns ``evaluate(solution, seed=0) → metrics`` (satisfies both the
    :class:`Study` trial signature and ``dse.QualityGate.evaluate``).
    Setup — dense reference build/init, calibration capture, dense
    perplexity — runs ONCE here; each trial then:

    1. scores the candidate plan data-aware (:func:`activation_score`
       against the captured Σ and the real dense weight),
    2. builds a TT twin with exactly that projection factorized
       (``TTConfig.plan_overrides``), backbone copied from the dense
       reference, cores TT-SVD-initialized from the dense weight
       (``training.finetune.tt_params_from_dense``) and optionally
       finetuned,
    3. measures perplexity delta (and, if configured, scheduler decode
       tok/s) through the frozen-plan path, asserting ZERO plan
       re-resolutions inside the measured window (``plan_resolutions``
       is returned in the metrics and must be 0).

    The returned metrics dict carries ``act_err`` / ``ppl_delta`` /
    ``tok_s`` (the ``Solution`` measured fields) plus diagnostics
    (``dense_ppl``, ``tt_ppl``, ``plan_resolutions``, finetune deltas).
    """
    import dataclasses as _dc

    import jax

    from repro.configs import build
    from repro.data.pipeline import calibration_batches
    from repro.kernels import plan as plan_mod
    from repro.training.finetune import (FinetuneConfig, finetune_tt,
                                         tt_params_from_dense)

    dense_cfg = _dc.replace(cfg, tt=_dc.replace(cfg.tt, enabled=False,
                                                plan_overrides=()))
    model = build(dense_cfg)
    params = model.init(jax.random.PRNGKey(seed))
    if ecfg.train_steps > 0:
        import jax.numpy as jnp

        from repro.data.pipeline import make_batch
        from repro.training.optimizer import adamw_init
        from repro.training.train_loop import TrainConfig, make_train_step
        tcfg = TrainConfig(compute_dtype=jnp.float32, remat=False)
        state = {"params": params, "opt": adamw_init(params)}
        step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
        for i in range(ecfg.train_steps):
            state, _ = step(state, make_batch(dense_cfg, ecfg.batch,
                                              ecfg.seq, step=i))
        params = state["params"]
    calib = calibration_batches(dense_cfg, ecfg.batch, ecfg.seq,
                                ecfg.n_calib, seed=ecfg.calib_seed)
    evalb = calibration_batches(dense_cfg, ecfg.batch, ecfg.seq,
                                ecfg.n_eval, seed=ecfg.calib_seed + 1)
    stats = model.activation_stats(params, calib)
    weights = _dense_weights_by_shape(params)

    def mean_loss(m, p):
        fn = jax.jit(lambda pp, bb: m.loss(pp, bb, remat=False))
        return float(np.mean([float(fn(p, b)) for b in evalb]))

    dense_loss = mean_loss(model, params)
    dense_ppl = float(np.exp(dense_loss))

    def evaluate(sol: Solution, eval_seed: int = 0) -> dict:
        plan = sol.plan
        key = (plan.N, plan.M)
        if key not in stats or key not in weights:
            raise ValueError(
                f"no calibrated projection of shape [N={plan.N} → "
                f"M={plan.M}] in {dense_cfg.name}: calibrated shapes "
                f"{sorted(stats)} — the trial space must come from the "
                f"model's own projection shapes")
        w = weights[key]                               # [N, M], y = x @ w
        act_err = activation_score(w.T, plan, stats[key]["sigma"],
                                   sol.weight_dtype)

        tt_cfg = _dc.replace(cfg, tt=_dc.replace(
            cfg.tt, enabled=True,
            families=("ffn", "attn", "lm_head"),
            plan_overrides=(((plan.M, plan.N),
                             (plan.ms, plan.ns, plan.ranks)),),
            weights="int8" if sol.weight_dtype == "int8" else "fp32"))
        twin = build(tt_cfg)
        tt_params = _copy_backbone(twin.init(jax.random.PRNGKey(seed)),
                                   params)
        tt_params = tt_params_from_dense(tt_params, params)
        metrics: dict = {"act_err": act_err, "dense_ppl": dense_ppl}
        if ecfg.finetune_steps > 0:
            pre = mean_loss(twin, tt_params)
            tt_params, hist = finetune_tt(
                twin, tt_params, calib,
                FinetuneConfig(steps=ecfg.finetune_steps))
            metrics["finetune_loss_pre"] = pre
            metrics["finetune_loss_post"] = hist[-1]
        if sol.weight_dtype == "int8":
            tt_params = twin.quantize_params(tt_params)
        global _IN_FLIGHT
        with _IN_FLIGHT_LOCK:
            _IN_FLIGHT += 1
        try:
            twin.plan_book                   # prime: resolve plans NOW
            mean_loss(twin, tt_params)       # warm traces (int8 twin may
            #                                  resolve its one extra plan
            #                                  on the first quantized call)
            book_before = len(twin.plan_book)
            global_before = plan_mod.plan_resolutions()
            solo_before = _IN_FLIGHT == 1
            tt_loss = mean_loss(twin, tt_params)
            if ecfg.measure_tok_s:
                metrics["tok_s"] = _decode_tok_s(
                    twin, tt_params, ecfg.serve_slots, ecfg.serve_prompt,
                    ecfg.serve_steps)
            replans = len(twin.plan_book) - book_before
            global_replans = plan_mod.plan_resolutions() - global_before
            solo = solo_before and _IN_FLIGHT == 1
        finally:
            with _IN_FLIGHT_LOCK:
                _IN_FLIGHT -= 1
        # solo ⇒ the global counter is attributable to this trial too —
        # the stronger assert (it also catches direct plan_tt_forward
        # calls that bypass the book).  Concurrent ⇒ the book-local
        # invariant is the sound one.
        if replans or (solo and global_replans):
            raise RuntimeError(
                f"{max(replans, global_replans)} plan re-resolutions "
                f"during trial evaluation of {plan.describe()} — the "
                f"measured window must run entirely through frozen "
                f"TTExecutionPlans")
        metrics["plan_resolutions"] = replans
        metrics["tt_ppl"] = float(np.exp(tt_loss))
        metrics["ppl_delta"] = metrics["tt_ppl"] - dense_ppl
        return metrics

    return evaluate
