"""Target hardware model: TPU v5e (one chip) + ICI mesh.

Single source of truth for every roofline / DSE / block-selection constant.
The container executes on CPU; these describe the *target*.
"""

# --- per-chip compute / memory -------------------------------------------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
PEAK_FLOPS_FP32 = PEAK_FLOPS_BF16 / 4
HBM_BYTES = 16 * 2 ** 30          # 16 GiB
HBM_BW = 819e9                    # B/s
VMEM_BYTES = 64 * 2 ** 20         # conservative v5e figure
VMEM_BUDGET_BYTES = 32 * 2 ** 20  # ~half kept for pipelining/compiler slack

# --- vector/matrix unit geometry ------------------------------------------
MXU = 128                         # systolic array dim
LANES = 128
SUBLANES = 8

# --- interconnect ----------------------------------------------------------
ICI_BW = 50e9                     # B/s per link (prompt-specified)

# --- mesh ------------------------------------------------------------------
POD_CHIPS = 256                   # 16 x 16 single pod
NUM_PODS = 2


def ridge_intensity(dtype_bytes: int = 2) -> float:
    """FLOP/byte at which compute and HBM terms balance."""
    return PEAK_FLOPS_BF16 / HBM_BW


def compute_seconds(flops: float, chips: int = 1) -> float:
    return flops / (chips * PEAK_FLOPS_BF16)


def memory_seconds(bytes_: float, chips: int = 1) -> float:
    return bytes_ / (chips * HBM_BW)


def collective_seconds(bytes_: float, chips: int = 1) -> float:
    return bytes_ / (chips * ICI_BW)
