"""Compile-time core packing and block-shape selection (paper §4.3 → TPU).

The paper's compiler pipeline for the einsum kernel is:
  array packing (compile-time re-layout of the constant core G)
  → vectorize the r-loop (multiples of vl)
  → register blocking chosen by an analytical load/store model (§4.3.4)
  → L2 cache tiling chosen by a cache-way occupancy model (§4.3.5).

TPU transfer (DESIGN.md §2): the constant core is packed into an
MXU-friendly matrix at parameter-build time; "registers" become VMEM tiles;
the L/S-instruction objective becomes an HBM-bytes-moved objective; the
L2-fit test (Eq. 26–28) becomes a VMEM-residency constraint.  The shape of
the model is identical — minimize memory traffic subject to a fast-memory
capacity — only the constants changed.

Every fit test takes a *per-operand* itemsize (DESIGN.md §8): ``itemsize``
prices the activations/states (fp32 accumulation ⇒ 4), ``weight_itemsize``
prices the resident packed cores (4 fp32, 2 bf16, 1 int8).  Int8-resident
weights shrink the residency term 4×, which directly enlarges the
fused-chain eligibility set and the batch tile.
"""
from __future__ import annotations

import dataclasses

from . import hw
from .flops import prod


def pack_core(G):
    """Compile-time array packing of one TT core.

    ``G [r_{t-1}, n_t, m_t, r_t]``  →  ``P [(n_t·r_t), (m_t·r_{t-1})]``
    so that the step contraction becomes ``state2 @ P`` on the MXU.  This is
    the paper's §4.3.1 re-layout: executed offline (at parameter build /
    checkpoint load), never at inference time.
    """
    r0, n, m, r1 = G.shape
    return G.transpose(1, 3, 2, 0).reshape(n * r1, m * r0)


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """Chosen VMEM tiling for one einsum step out[m,b,r0] += G·x."""
    bm: int          # m-tile
    bb: int          # b-tile
    bn: int          # n-tile (grid-accumulated)
    traffic_bytes: int
    vmem_bytes: int


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _divisors_pow2(n: int, lo: int, hi: int):
    v = lo
    while v <= min(n, hi):
        yield v
        v *= 2
    if n < hi and (n & (n - 1)) != 0:
        yield n           # the full (non-pow2) extent, padded by mosaic


def select_blocks(mt: int, bt: int, nt: int, rt: int, rt_1: int,
                  itemsize: int = 4,
                  vmem_budget: int = hw.VMEM_BUDGET_BYTES,
                  weight_itemsize: int | None = None) -> BlockPlan:
    """Analytical block-shape selection (paper §4.3.4 step 2–3).

    HBM traffic model for grid (m/bm, b/bb, n/bn) with n innermost
    (accumulation):

      bytes(G)   = ceil(m/bm) … G re-read once per *b*-tile
      bytes(X)   = ceil(b/bb) … X re-read once per *m*-tile
      bytes(out) = written once

    Minimize total subject to double-buffered VMEM residency:
      2·(bm·bn·rt·rt_1 + bb·bn·rt + bm·bb·rt_1)·itemsize ≤ budget.
    Alignment: last dim padded to the 128-lane register shape, second-minor
    to 8 sublanes (the TPU analogue of the paper's vl-multiple rule).

    ``weight_itemsize`` prices the resident G tile separately from the
    activation tiles (int8-resident cores: 1 byte/elem, DESIGN.md §8).
    """
    cands = select_blocks_candidates(mt, bt, nt, rt, rt_1, itemsize,
                                     vmem_budget, k=1,
                                     weight_itemsize=weight_itemsize)
    return cands[0]


def select_blocks_candidates(mt: int, bt: int, nt: int, rt: int, rt_1: int,
                             itemsize: int = 4,
                             vmem_budget: int = hw.VMEM_BUDGET_BYTES,
                             k: int = 4,
                             weight_itemsize: int | None = None
                             ) -> list[BlockPlan]:
    """Top-``k`` feasible block plans by the analytical traffic model,
    best first.  The empirical autotuner (kernels.autotune) times these
    on-device instead of trusting the model's ranking — the measured
    counterpart of the paper's §4.3.4 'pick the analytical argmin'."""
    w_item = itemsize if weight_itemsize is None else weight_itemsize
    g_total = mt * nt * rt * rt_1 * w_item
    x_total = bt * nt * rt * itemsize
    o_total = mt * bt * rt_1 * itemsize

    cands: list[BlockPlan] = []
    for bm in _divisors_pow2(mt, 8, 512):
        for bb in _divisors_pow2(bt, 8, 1024):
            for bn in _divisors_pow2(nt, 8, 2048):
                vmem = 2 * (w_item * bm * bn * rt * rt_1
                            + itemsize * (bb * bn * rt + bm * bb * rt_1))
                if vmem > vmem_budget:
                    continue
                n_mtiles = -(-mt // bm)
                n_btiles = -(-bt // bb)
                traffic = (g_total * n_btiles + x_total * n_mtiles + o_total)
                cands.append(BlockPlan(bm, bb, bn, traffic, vmem))
    if not cands:         # degenerate tiny problem: single block
        return [BlockPlan(min(mt, 8), min(bt, 8), min(nt, 8),
                          g_total + x_total + o_total, 0)]
    cands.sort(key=lambda c: (c.traffic_bytes, -c.vmem_bytes))
    return cands[:k]


def chain_fits_vmem(plan_sizes: list[int], itemsize: int = 4,
                    vmem_budget: int = hw.VMEM_BUDGET_BYTES,
                    weight_elems: int = 0,
                    weight_itemsize: int | None = None) -> bool:
    """Paper Eq. (26) analogue: can the whole einsum chain for one batch
    tile stay resident in VMEM (weights + largest two consecutive states)?

    ``plan_sizes`` are the element counts of the chain states s_0 … s_d for
    one batch tile; ``weight_elems`` is the total element count of the
    packed cores (held once, not double-buffered) priced at
    ``weight_itemsize`` bytes/elem (defaults to ``itemsize``; int8-resident
    cores pass 1, which is what buys the enlarged eligibility set)."""
    w_item = itemsize if weight_itemsize is None else weight_itemsize
    peak = 0
    for a, b in zip(plan_sizes, plan_sizes[1:]):
        peak = max(peak, a + b)
    return peak * itemsize * 2 + weight_elems * w_item <= vmem_budget


@dataclasses.dataclass(frozen=True)
class FitReport:
    """Priced VMEM-fit verdict for one whole chain — the structured form
    of the Eq. 26 test the plan resolver (kernels.plan) records in every
    ``TTExecutionPlan``, instead of each caller re-deriving it."""
    fits: bool                   # VMEM-resident at SOME power-of-two tile
    batch_tile: int | None       # the largest such tile (None when not)
    weight_bytes: int            # packed-core residency at weight_itemsize
    peak_state_bytes: int        # per-row peak consecutive state pair


def chain_fit_report(ns, ms, ranks, itemsize: int = 4,
                     vmem_budget: int = hw.VMEM_BUDGET_BYTES,
                     weight_itemsize: int | None = None) -> FitReport:
    """One-stop fused-chain fit verdict: the ``fused_chain_batch_tile``
    decision plus the byte terms it priced, so the caller can persist WHY
    a chain did or did not fuse (plan provenance, DESIGN.md §10)."""
    w_item = itemsize if weight_itemsize is None else weight_itemsize
    sizes = chain_state_sizes(ns, ms, ranks)
    w_elems = chain_weight_elems(ns, ms, ranks)
    peak = max((a + b for a, b in zip(sizes, sizes[1:])), default=sizes[0])
    tile = fused_chain_batch_tile(ns, ms, ranks, itemsize=itemsize,
                                  vmem_budget=vmem_budget,
                                  weight_itemsize=w_item)
    return FitReport(fits=tile is not None, batch_tile=tile,
                     weight_bytes=w_elems * w_item,
                     peak_state_bytes=peak * itemsize)


def chain_state_sizes(ns, ms, ranks) -> list[int]:
    """Per-batch-element feature sizes of the chain states s_0 … s_d.

    s_0 = N = Π n_t; after the step on core ``t`` (executed d → 1) the state
    is [m_t, b_t, r_{t-1}] flattened, so s_{d-t+1} = m_t·b_t·r_{t-1};
    s_d = M.  These are the intermediates the fused kernel keeps in VMEM.
    """
    d = len(ns)
    f = prod(ns)
    sizes = [f]
    for t in range(d - 1, -1, -1):
        bt = f // (ns[t] * ranks[t + 1])
        f = ms[t] * bt * ranks[t]
        sizes.append(f)
    return sizes


def chain_weight_elems(ns, ms, ranks) -> int:
    """Total element count of the packed cores P_1 … P_d."""
    return sum(ns[t] * ranks[t + 1] * ms[t] * ranks[t]
               for t in range(len(ns)))


def fused_chain_batch_tile(ns, ms, ranks, itemsize: int = 4,
                           vmem_budget: int = hw.VMEM_BUDGET_BYTES,
                           weight_itemsize: int | None = None
                           ) -> int | None:
    """Largest power-of-two batch tile for which the *whole* chain is
    VMEM-resident (packed weights + double-buffered peak state pair), or
    ``None`` when even the minimum 8-row tile does not fit — the caller
    must then fall back to the per-step kernel.  This is the fused-chain
    analogue of the paper's L2-fit test (Eq. 26–28), routed through
    ``chain_fits_vmem``.  ``weight_itemsize=1`` (int8-resident cores)
    admits chains whose fp32 weights alone bust the budget."""
    sizes = chain_state_sizes(ns, ms, ranks)
    weights = chain_weight_elems(ns, ms, ranks)
    bb = 1024
    while bb >= 8:
        if chain_fits_vmem([bb * s for s in sizes], itemsize, vmem_budget,
                           weight_elems=weights,
                           weight_itemsize=weight_itemsize):
            return bb
        bb //= 2
    return None


def fused2_batch_tile(N: int, M: int, mid: int, weights: int,
                      itemsize: int = 4,
                      vmem_budget: int = hw.VMEM_BUDGET_BYTES,
                      weight_itemsize: int | None = None) -> int:
    """Largest power-of-two batch tile such that X-tile + intermediate +
    Y-tile + packed weights double-buffer in VMEM (fused d=2 kernel)."""
    w_item = itemsize if weight_itemsize is None else weight_itemsize
    bb = 1024
    while bb > 8:
        need = 2 * itemsize * (bb * (N + mid + M)) + w_item * weights
        if need <= vmem_budget:
            return bb
        bb //= 2
    return 8
