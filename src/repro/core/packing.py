"""Compile-time core packing and block-shape selection (paper §4.3 → TPU).

The paper's compiler pipeline for the einsum kernel is:
  array packing (compile-time re-layout of the constant core G)
  → vectorize the r-loop (multiples of vl)
  → register blocking chosen by an analytical load/store model (§4.3.4)
  → L2 cache tiling chosen by a cache-way occupancy model (§4.3.5).

TPU transfer (DESIGN.md §2): the constant core is packed into an
MXU-friendly matrix at parameter-build time; "registers" become VMEM tiles;
the L/S-instruction objective becomes an HBM-bytes-moved objective; the
L2-fit test (Eq. 26–28) becomes a VMEM-residency constraint.  The shape of
the model is identical — minimize memory traffic subject to a fast-memory
capacity — only the constants changed.
"""
from __future__ import annotations

import dataclasses

from . import hw
from .flops import prod


def pack_core(G):
    """Compile-time array packing of one TT core.

    ``G [r_{t-1}, n_t, m_t, r_t]``  →  ``P [(n_t·r_t), (m_t·r_{t-1})]``
    so that the step contraction becomes ``state2 @ P`` on the MXU.  This is
    the paper's §4.3.1 re-layout: executed offline (at parameter build /
    checkpoint load), never at inference time.
    """
    r0, n, m, r1 = G.shape
    return G.transpose(1, 3, 2, 0).reshape(n * r1, m * r0)


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """Chosen VMEM tiling for one einsum step out[m,b,r0] += G·x."""
    bm: int          # m-tile
    bb: int          # b-tile
    bn: int          # n-tile (grid-accumulated)
    traffic_bytes: int
    vmem_bytes: int


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _divisors_pow2(n: int, lo: int, hi: int):
    v = lo
    while v <= min(n, hi):
        yield v
        v *= 2
    if n < hi and (n & (n - 1)) != 0:
        yield n           # the full (non-pow2) extent, padded by mosaic


def select_blocks(mt: int, bt: int, nt: int, rt: int, rt_1: int,
                  itemsize: int = 4,
                  vmem_budget: int = hw.VMEM_BUDGET_BYTES) -> BlockPlan:
    """Analytical block-shape selection (paper §4.3.4 step 2–3).

    HBM traffic model for grid (m/bm, b/bb, n/bn) with n innermost
    (accumulation):

      bytes(G)   = ceil(m/bm) … G re-read once per *b*-tile
      bytes(X)   = ceil(b/bb) … X re-read once per *m*-tile
      bytes(out) = written once

    Minimize total subject to double-buffered VMEM residency:
      2·(bm·bn·rt·rt_1 + bb·bn·rt + bm·bb·rt_1)·itemsize ≤ budget.
    Alignment: last dim padded to the 128-lane register shape, second-minor
    to 8 sublanes (the TPU analogue of the paper's vl-multiple rule).
    """
    g_total = mt * nt * rt * rt_1 * itemsize
    x_total = bt * nt * rt * itemsize
    o_total = mt * bt * rt_1 * itemsize

    best: BlockPlan | None = None
    for bm in _divisors_pow2(mt, 8, 512):
        for bb in _divisors_pow2(bt, 8, 1024):
            for bn in _divisors_pow2(nt, 8, 2048):
                vmem = 2 * itemsize * (bm * bn * rt * rt_1
                                       + bb * bn * rt + bm * bb * rt_1)
                if vmem > vmem_budget:
                    continue
                n_mtiles = -(-mt // bm)
                n_btiles = -(-bt // bb)
                traffic = (g_total * n_btiles + x_total * n_mtiles + o_total)
                cand = BlockPlan(bm, bb, bn, traffic, vmem)
                if best is None or (cand.traffic_bytes, -cand.vmem_bytes) < \
                        (best.traffic_bytes, -best.vmem_bytes):
                    best = cand
    if best is None:      # degenerate tiny problem: single block
        best = BlockPlan(min(mt, 8), min(bt, 8), min(nt, 8),
                         g_total + x_total + o_total, 0)
    return best


def chain_fits_vmem(plan_sizes: list[int], itemsize: int = 4,
                    vmem_budget: int = hw.VMEM_BUDGET_BYTES) -> bool:
    """Paper Eq. (26) analogue: can the whole einsum chain for one batch
    tile stay resident in VMEM (weights + largest two consecutive states)?"""
    peak = 0
    for a, b in zip(plan_sizes, plan_sizes[1:]):
        peak = max(peak, a + b)
    return peak * itemsize * 2 <= vmem_budget


def fused2_batch_tile(N: int, M: int, mid: int, weights: int,
                      itemsize: int = 4,
                      vmem_budget: int = hw.VMEM_BUDGET_BYTES) -> int:
    """Largest power-of-two batch tile such that X-tile + intermediate +
    Y-tile + packed weights double-buffer in VMEM (fused d=2 kernel)."""
    bb = 1024
    while bb > 8:
        need = 2 * itemsize * (bb * (N + mid + M)) + itemsize * weights
        if need <= vmem_budget:
            return bb
        bb //= 2
    return 8
