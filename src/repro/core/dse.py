"""Design-space exploration for TT-decomposed FC layers (paper §4).

Pipeline (paper Fig. 4):

  stage 0  "all initial solutions"    — every (m-perm, n-perm, rank-list)
  stage 1  alignment strategy (§4.1)  — keep only the aligned permutation
                                        (Definition 1: m desc, n asc)
  stage 2  vectorization constr. (§4.2.1) — ranks multiples of ``vl``
  stage 3  initial-layer constr. (§4.2.2) — FLOPs & params below dense
  stage 4  scalability constr.   (§4.2.3) — thread-count selection + prune
                                        long low-workload configurations

Stages 0–2 are *counted analytically* (the spaces reach 1e33 — the paper's
point is precisely that they must be pruned without materialization).
Stages 3–4 enumerate the surviving aligned ⨯ uniform-rank grid (the paper
uses uniform intermediate ranks R throughout, cf. §2 footnote 3).

Hardware adaptation: ``vl`` defaults to 8 (RVV, paper-faithful).  TPU mode
uses ``vl=128`` (lane width) — see DESIGN.md §2.  The thread-count table
(paper Fig. 9) generalizes to a ``parallel_units`` table; on TPU it chooses
the grid split of the Pallas kernel instead of pthread counts.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Callable, Iterable, Iterator, Sequence

from .flops import (dense_flops, dense_params, einsum_loop_bounds,
                    max_tt_rank_at_cut, num_permutations_aligned, prod,
                    tt_flops, tt_params)
from .tt import TTPlan, make_plan


# ---------------------------------------------------------------------------
# Factorization enumeration
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def multiplicative_partitions(n: int, min_factor: int = 2
                              ) -> tuple[tuple[int, ...], ...]:
    """All multisets of integers ≥ ``min_factor`` with product ``n``,
    each returned ascending.  ``n`` itself is included as the length-1
    factorization."""
    out: list[tuple[int, ...]] = []

    def rec(remaining: int, start: int, acc: tuple[int, ...]):
        if remaining == 1:
            if acc:
                out.append(acc)
            return
        f = start
        while f * f <= remaining:
            if remaining % f == 0:
                rec(remaining // f, f, acc + (f,))
            f += 1
        if remaining >= start:
            out.append(acc + (remaining,))

    rec(n, min_factor, ())
    return tuple(sorted(set(out)))


def factorizations_by_length(n: int, max_d: int) -> dict[int, list[tuple[int, ...]]]:
    by_len: dict[int, list[tuple[int, ...]]] = {}
    for f in multiplicative_partitions(n):
        if len(f) <= max_d:
            by_len.setdefault(len(f), []).append(f)
    return by_len


def aligned_pair(fm: Sequence[int], fn: Sequence[int]
                 ) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Definition 1: output factors descending, input factors ascending."""
    return tuple(sorted(fm, reverse=True)), tuple(sorted(fn))


def aligned_combination_shapes(M: int, N: int, max_d: int = 12, min_d: int = 2,
                               min_factor: int = 2
                               ) -> list[tuple[tuple[int, ...], tuple[int, ...]]]:
    """All aligned (ms, ns) combination shapes with equal length d."""
    fm_by = factorizations_by_length(M, max_d)
    fn_by = factorizations_by_length(N, max_d)
    out = []
    for d in range(min_d, max_d + 1):
        for fm in fm_by.get(d, ()):
            if fm[0] < min_factor:       # ascending → fm[0] is the minimum
                continue
            for fn in fn_by.get(d, ()):
                if fn[0] < min_factor:
                    continue
                out.append(aligned_pair(fm, fn))
    return out


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DSEConfig:
    vl: int = 8                    # vector length (8 = RVV paper; 128 = TPU lane)
    rank_cap: int = 3064           # paper's benchmark rank ceiling
    rank_step: int = 8             # grid step for enumerated solutions
    max_d: int = 12                # paper Fig. 10 explores lengths 2–12
    min_d: int = 2
    min_factor: int = 2            # discard shapes with any factor below this
                                   # (paper: 2; TPU mode: 8 so every einsum
                                   # dim can feed the 8-sublane register file;
                                   # recovers the paper's §6.4 balanced picks)
    batch: int = 1                 # tokens folded into the chain's b-dim
    weight_dtypes: tuple[str, ...] = ("fp32",)
                                   # resident core dtypes enumerated per
                                   # surviving plan (DESIGN.md §8): adding
                                   # "int8" emits a mixed-precision twin
                                   # with the quantized memory footprint
                                   # and a quantization-error proxy
    # paper Fig. 9: FLOPs → thread count on the SpacemiT K1
    thread_table: tuple[tuple[float, int], ...] = (
        (2e6, 1), (4e6, 2), (8e6, 3), (float("inf"), 4))
    max_scalable_d: int = 4        # prune length > this …
    heavy_flops_min: float = 8e6   # … when the heaviest einsum is below this


TPU_DSE = DSEConfig(vl=128, rank_step=128, min_factor=8,
                    # TPU analogue of Fig. 9: FLOPs → number of TensorCores
                    # worth of grid parallelism before per-kernel overheads
                    # dominate (napkin: ~5 µs launch+pipeline fill @197TF/s).
                    thread_table=((1e9, 1), (4e9, 2), (1.6e10, 4),
                                  (float("inf"), 8)))


def select_threads(flops: float, cfg: DSEConfig) -> int:
    """Paper §4.2.3 / Fig. 9: workload-dependent parallelism selection."""
    for bound, t in cfg.thread_table:
        if flops < bound:
            return t
    return cfg.thread_table[-1][1]


# ---------------------------------------------------------------------------
# Analytic stage counting (stages 0–2)
# ---------------------------------------------------------------------------

def _rank_choice_counts(ms, ns, cap: int, multiple_of: int = 1) -> float:
    """Π over internal cuts of the number of admissible r_t values for the
    *aligned* permutation (representative; see module docstring)."""
    d = len(ms)
    total = 1.0
    for t in range(1, d):
        cut = min(max_tt_rank_at_cut(ms, ns, t), cap)
        k = cut // multiple_of
        if k == 0:
            return 0.0
        total *= k
    return total


def count_stages(M: int, N: int, cfg: DSEConfig = DSEConfig()) -> dict[str, float]:
    """Reproduce the count columns of Tables 1–2.

    ``all_initial`` = Σ_shapes perms(m)·perms(n)·Π_t |{1..cap_t}|
    ``aligned``     = Σ_shapes Π_t |{1..cap_t}|
    ``vectorized``  = Σ_shapes Π_t |{vl, 2vl, .. cap_t}|
    """
    shapes = aligned_combination_shapes(M, N, cfg.max_d, cfg.min_d, 2)
    c_all = c_aligned = c_vec = 0.0
    for ms, ns in shapes:
        rc = _rank_choice_counts(ms, ns, cfg.rank_cap, 1)
        c_all += num_permutations_aligned(ms, ns) * rc
        c_aligned += rc
        c_vec += _rank_choice_counts(ms, ns, cfg.rank_cap, cfg.vl)
    return {"all_initial": c_all, "aligned": c_aligned, "vectorized": c_vec}


# ---------------------------------------------------------------------------
# Enumerated pipeline (stages 2–4) → concrete solutions
# ---------------------------------------------------------------------------

_WEIGHT_ITEMSIZE = {"fp32": 4, "bf16": 2, "int8": 1}


def core_err_bound(core_shape: Sequence[int], weight_dtype: str) -> float:
    """First-order relative error contributed by ONE resident core at
    ``weight_dtype`` — a *computed* upper bound, not a per-dtype constant.

    The chain output is multilinear in the d cores, so per-core relative
    perturbations add to first order (``quant.chain_error_bound``'s shape):

    * fp32 is the reference representation: 0.
    * bf16 rounds each element to an 8-bit significand (7 stored + 1
      implicit); half-ulp rounding is a *relative* perturbation per
      element, so ‖ΔG‖/‖G‖ ≤ 2⁻⁹ independent of the core size.
    * int8 quantizes on the symmetric 254-step grid with per-core scale
      s = max|G|/127 and |Δ| ≤ s/2 per element — an *absolute* grid, so
      the relative error depends on the core's max/norm ratio.  For the
      iid (Glorot-style) init the stack uses, E max|G| ≈ σ√(2 ln size)
      and ‖G‖ ≈ σ√size, giving

        ‖ΔG‖/‖G‖ ≤ (s/2)·√size / ‖G‖ ≈ √(2 ln size) / 254

      — bigger cores quantize *relatively* worse, which the old constant
      ``1/254`` per core missed entirely.
    """
    if weight_dtype not in _WEIGHT_ITEMSIZE:
        raise ValueError(
            f"unknown weight dtype {weight_dtype!r}: expected one of "
            f"{tuple(_WEIGHT_ITEMSIZE)}")
    if weight_dtype == "fp32":
        return 0.0
    if weight_dtype == "bf16":
        return 2.0 ** -9
    size = max(prod(core_shape), 2)
    return math.sqrt(2.0 * math.log(size)) / 254.0


def plan_err_proxy(plan: TTPlan, weight_dtype: str) -> float:
    """Computed first-order upper bound on the relative output error of a
    TT chain whose cores are resident at ``weight_dtype`` — Σ_t per-core
    bounds (the chain is multilinear, so core perturbations add)."""
    return sum(core_err_bound(shape, weight_dtype)
               for shape in plan.core_shapes)


def weight_bytes(core_params: int, d: int, weight_dtype: str) -> int:
    """Resident byte footprint of the packed TT cores at ``weight_dtype``.

    For int8 this is exactly ``core.quant.quantized_bytes``: one byte per
    core element plus one fp32 scale per core (unit-tested against it) —
    the number the dtype-aware VMEM fit model and the serving engine see.
    """
    if weight_dtype not in _WEIGHT_ITEMSIZE:
        raise ValueError(
            f"unknown weight dtype {weight_dtype!r}: expected one of "
            f"{tuple(_WEIGHT_ITEMSIZE)}")
    if weight_dtype == "int8":
        return core_params + 4 * d
    return core_params * _WEIGHT_ITEMSIZE[weight_dtype]


@dataclasses.dataclass(frozen=True)
class Solution:
    plan: TTPlan
    flops: int
    params: int
    threads: tuple[int, ...]       # per einsum, execution order (core d first)
    max_einsum_flops: int
    weight_dtype: str = "fp32"     # resident core dtype of this candidate
    bytes: int = 0                 # weight_bytes(core params, d, dtype)
    err_proxy: float = 0.0         # computed first-order error upper bound
                                   # (plan_err_proxy; 0 for fp32)
    # measured trial metrics, attached by the study engine / quality gate
    # (core.study): None until the candidate has actually been evaluated
    act_err: float | None = None   # activation-aware ‖WX−TT(W)X‖/‖WX‖
    ppl_delta: float | None = None  # end-to-end perplexity delta vs dense
    tok_s: float | None = None     # measured serving decode throughput

    @property
    def d(self) -> int:
        return self.plan.d

    @property
    def quant_rel_err(self) -> float:
        """DEPRECATED alias of :attr:`err_proxy` (the old name of the
        analytic accuracy axis; kept so existing callers keep working)."""
        warnings.warn("Solution.quant_rel_err is deprecated — use "
                      "Solution.err_proxy", DeprecationWarning, stacklevel=2)
        return self.err_proxy


_NO_DEFAULT = object()


@dataclasses.dataclass
class DSEResult:
    M: int
    N: int
    counts: dict[str, float]
    solutions: list[Solution]      # sorted by FLOPs ascending

    def best(self, length: int | None = None, rank: int | None = None,
             default=_NO_DEFAULT) -> Solution | None:
        """First (= cheapest, list is FLOPs-sorted) solution matching the
        filters.  No match raises a ValueError naming the filters unless a
        ``default`` is supplied (pass ``default=None`` for the legacy
        None-on-miss behavior)."""
        for s in self.solutions:
            if length is not None and s.d != length:
                continue
            if rank is not None and any(r not in (1, rank)
                                        for r in s.plan.ranks):
                continue
            return s
        if default is not _NO_DEFAULT:
            return default
        raise ValueError(
            f"no surviving solution with length={length} rank={rank} for "
            f"[{self.M}x{self.N}] ({len(self.solutions)} survivors) — "
            f"relax the filters or widen DSEConfig (rank grid/min_factor)")

    def measured_front(self, axes: Sequence[str] = (
            "flops", "bytes", "tok_s", "ppl_delta")) -> list[Solution]:
        """Pareto front over measured trial metrics: only solutions that
        carry every requested axis (i.e. were actually evaluated) compete.
        Default axes are the quality-gate contract: static cost (flops,
        bytes) × measured serving throughput × measured quality."""
        evaluated = [s for s in self.solutions
                     if all(getattr(s, a) is not None for a in axes)]
        return pareto_front(evaluated, axes=axes)


def _uniform_rank_grid(ms, ns, cfg: DSEConfig) -> Iterable[int]:
    d = len(ms)
    cap = min(cfg.rank_cap,
              min(max_tt_rank_at_cut(ms, ns, t) for t in range(1, d)))
    r = cfg.vl
    while r <= cap:
        yield r
        r += cfg.rank_step


def generate_candidates(M: int, N: int, cfg: DSEConfig = DSEConfig(),
                        counts: dict | None = None) -> Iterator[Solution]:
    """Stages 2–4 of the funnel as a lazy candidate stream (the extracted
    enumerate/prune core of :func:`explore` — the study engine
    (``core.study``) consumes this directly as its trial space).

    Yields one :class:`Solution` per surviving plan × enumerated weight
    dtype, in shape-enumeration order (deterministic).  ``counts``, if
    supplied, is filled in place with the funnel tallies as the stream is
    consumed (``vectorized_enumerated`` / ``initial_layer`` /
    ``scalability`` count PLANS; the weight-dtype twins are memory-model
    variants of a plan, tallied as ``dtype_enumerated``)."""
    dense_f, dense_p = dense_flops(M, N), dense_params(M, N)
    c = counts if counts is not None else {}
    c.update(vectorized_enumerated=0, initial_layer=0, scalability=0,
             dtype_enumerated=0)
    for ms, ns in aligned_combination_shapes(M, N, cfg.max_d, cfg.min_d,
                                             cfg.min_factor):
        for R in _uniform_rank_grid(ms, ns, cfg):
            c["vectorized_enumerated"] += 1
            plan = make_plan(ms, ns, R)
            f = tt_flops(ms, ns, plan.ranks)
            p = tt_params(ms, ns, plan.ranks)
            # stage 3: initial-layer constraint (§4.2.2)
            if f >= dense_f or p >= dense_p:
                continue
            c["initial_layer"] += 1
            # stage 4: scalability constraint (§4.2.3)
            bounds = einsum_loop_bounds(ms, ns, plan.ranks, cfg.batch)
            heaviest = max(b["flops"] for b in bounds)
            if plan.d > cfg.max_scalable_d and heaviest < cfg.heavy_flops_min:
                continue
            threads = tuple(select_threads(b["flops"], cfg) for b in bounds)
            c["scalability"] += 1
            # one candidate per enumerated weight dtype: FLOPs are dtype-
            # invariant, the memory footprint and the quantization-error
            # proxy are not — this is what puts mixed-precision solutions
            # on the pareto front (DESIGN.md §8)
            for wd in cfg.weight_dtypes:
                wb = weight_bytes(plan.params, plan.d, wd)  # validates wd
                c["dtype_enumerated"] += 1
                yield Solution(plan, f, p, threads, heaviest,
                               weight_dtype=wd, bytes=wb,
                               err_proxy=plan_err_proxy(plan, wd))


def count_enumerated(M: int, N: int, cfg: DSEConfig = DSEConfig()) -> int:
    """Analytic count of the enumerated stage-2 grid — the number of
    (shape, uniform rank) pairs :func:`generate_candidates` visits, i.e.
    ``explore()``'s ``vectorized_enumerated``.  Unlike the Table-1/2
    ``vectorized`` column (independent per-cut rank choices at
    min_factor 2) this prices exactly the uniform-rank grid under
    ``cfg.min_factor``, so tests can assert parity with enumeration."""
    n = 0
    for ms, ns in aligned_combination_shapes(M, N, cfg.max_d, cfg.min_d,
                                             cfg.min_factor):
        d = len(ms)
        cap = min(cfg.rank_cap,
                  min(max_tt_rank_at_cut(ms, ns, t) for t in range(1, d)))
        if cap >= cfg.vl:
            n += (cap - cfg.vl) // cfg.rank_step + 1
    return n


@dataclasses.dataclass(frozen=True)
class QualityGate:
    """Measured-quality admission contract for :func:`explore` (and the
    study engine): the leading ``top_k`` survivors are handed to
    ``evaluate`` (a trial evaluator returning a metrics dict with any of
    ``act_err`` / ``ppl_delta`` / ``tok_s`` — ``core.study`` builds the
    model-level one), the metrics are attached to the solutions, and any
    candidate whose measured perplexity delta exceeds ``max_ppl_delta``
    is REJECTED from the result — the funnel can no longer crown a plan
    that destroys model quality."""
    evaluate: Callable[[Solution], dict]
    max_ppl_delta: float
    top_k: int = 8

    def admits(self, metrics: dict) -> bool:
        ppl = metrics.get("ppl_delta")
        return ppl is None or ppl <= self.max_ppl_delta


_METRIC_FIELDS = ("act_err", "ppl_delta", "tok_s")


def with_metrics(sol: Solution, metrics: dict) -> Solution:
    """Attach measured trial metrics to a solution (ignores unknown
    keys so evaluators can report extra diagnostics)."""
    known = {k: metrics[k] for k in _METRIC_FIELDS if k in metrics}
    return dataclasses.replace(sol, **known) if known else sol


def apply_quality_gate(res: DSEResult, gate: QualityGate) -> DSEResult:
    """Evaluate the leading ``gate.top_k`` solutions, attach their
    measured metrics, drop the ones the gate rejects.  The tail past
    ``top_k`` is kept un-evaluated (it was already losing on the static
    axes).  ``counts`` gains ``quality_evaluated`` / ``quality_gated``."""
    kept: list[Solution] = []
    n_eval = n_gated = 0
    for s in res.solutions[:gate.top_k]:
        measured = with_metrics(s, gate.evaluate(s))
        n_eval += 1
        if (measured.ppl_delta is not None
                and measured.ppl_delta > gate.max_ppl_delta):
            n_gated += 1
            continue
        kept.append(measured)
    counts = dict(res.counts, quality_evaluated=n_eval,
                  quality_gated=n_gated)
    return DSEResult(res.M, res.N, counts,
                     kept + res.solutions[gate.top_k:])


def explore(M: int, N: int, cfg: DSEConfig = DSEConfig(),
            with_counts: bool = True, measure_top: int = 0,
            quality_gate: QualityGate | None = None) -> DSEResult:
    """Run the full paper pipeline for one FC layer ``[N → M]``.

    ``measure_top > 0`` adds stage 4b: re-rank that many of the leading
    survivors by *measured* kernel time (``rerank_measured``) instead of
    trusting the static FLOPs/thread-table ordering.

    ``quality_gate`` adds stage 5 (the accuracy loop, DESIGN.md §12): the
    leading ``gate.top_k`` survivors are evaluated for measured quality
    (activation error / perplexity delta / serving tok/s) and candidates
    above the gate's perplexity-delta threshold are rejected — applied
    AFTER the measured rerank so the gate sees the deployment ordering."""
    counts = count_stages(M, N, cfg) if with_counts else {}
    funnel: dict = {}
    survivors = list(generate_candidates(M, N, cfg, counts=funnel))
    survivors.sort(key=lambda s: (s.flops, s.params, s.bytes))
    counts.update(funnel)
    res = DSEResult(M, N, counts, survivors)
    if measure_top > 0:
        res = rerank_measured(res, batch=max(cfg.batch, 1),
                              limit=measure_top)
    if quality_gate is not None:
        res = apply_quality_gate(res, quality_gate)
    return res


# axes measured "bigger is better" — negated before comparison so the
# pareto machinery uniformly minimizes
_MAXIMIZE_AXES = frozenset({"tok_s"})
DEFAULT_AXES = ("flops", "bytes", "err_proxy")


def _axis_values(s: Solution, axes: Sequence[str]) -> tuple:
    vals = []
    for a in axes:
        v = getattr(s, a)
        if v is None:
            raise ValueError(
                f"solution {s.plan.describe()} has no measured {a!r} — "
                f"evaluate it (quality gate / study trial) before asking "
                f"for a front over {tuple(axes)}")
        vals.append(-v if a in _MAXIMIZE_AXES else v)
    return tuple(vals)


def pareto_front(solutions: Sequence[Solution],
                 axes: Sequence[str] = DEFAULT_AXES) -> list[Solution]:
    """Non-dominated set over ``axes`` (attribute names of
    :class:`Solution`; all minimized except ``tok_s``), returned sorted by
    the axis tuple.  The default axes are the analytic front
    (flops, bytes, err_proxy); the quality-gate contract uses
    ``("flops", "bytes", "tok_s", "ppl_delta")`` via
    :meth:`DSEResult.measured_front`.

    With mixed weight dtypes enumerated (``DSEConfig.weight_dtypes``) the
    int8 twin of a plan has identical FLOPs, a ~4× smaller byte footprint
    and a nonzero error proxy — so the front genuinely mixes precisions:
    int8 candidates win the memory axis, fp32 candidates the accuracy
    axis, and neither dominates the other.

    Lexicographic-sort scan, O(n·|front|): any dominator of ``s`` sorts
    strictly before ``s``, and by transitivity a dominated solution is
    always dominated by some member of the front built so far — so one
    pass against the accepted front suffices (the survivor lists here are
    thousands long after dtype enumeration; all-pairs would be O(n²))."""
    axes = tuple(axes)
    decorated = sorted(((_axis_values(s, axes), s) for s in solutions),
                       key=lambda vs: vs[0])

    def dominates(o: tuple, s: tuple) -> bool:
        return all(a <= b for a, b in zip(o, s)) and o != s

    front: list[tuple] = []
    out: list[Solution] = []
    for v, s in decorated:
        if not any(dominates(o, v) for o in front):
            front.append(v)
            out.append(s)
    return out


def rerank_measured(res: DSEResult, batch: int = 32, limit: int = 8,
                    backend: str = "auto", interpret: bool | None = None,
                    dtype=None) -> DSEResult:
    """Stage 4b: re-rank the top-``limit`` survivors by measured kernel
    time of the deployed TT forward (the fused/step Pallas path chosen by
    ``backend``), keeping the static ordering for the tail.

    The paper's stage 4 ranks by FLOPs + the Fig. 9 thread table — a static
    proxy.  On real hardware the einsum chain's cost is layout- and
    residency-dependent, so the final pick among near-tied survivors is
    made by running them (interpret-mode timing on CPU containers).
    Candidates carrying ``weight_dtype='int8'`` are timed on the
    int8-resident kernel path (pre-quantized cores + scales, exactly what
    serving runs), so the measured front scores mixed-precision solutions
    on their own kernels — an int8 twin that newly fits the fused chain
    beats its step-fallback fp32 sibling here.

    Each candidate is jitted and warmed up (one untimed call +
    ``block_until_ready``) before ``_median_time`` sees it, so the ranking
    reflects steady-state kernel time, never trace+compile — a solution
    must not lose stage 4b just because it compiled first/slowest.

    Dispatch is plan-first (DESIGN.md §10): each candidate is resolved
    into a ``TTExecutionPlan`` (one planning pass per candidate — the
    exact routing, fit verdict and tiles deployment would use: the tune
    mode defaults to 'cached', so persisted measured tiles are honored,
    and a ``backend="auto:measure"`` spec times measured winners) and
    timed through ``tt_forward(plan=...)``; no string-spec round-trips."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.autotune import _median_time
    from repro.kernels.ops import tt_forward
    from repro.kernels.plan import plan_tt_forward
    from .quant import quantize_cores
    from .tt import tt_init

    dtype = dtype or jnp.float32
    timed: list[tuple[float, Solution]] = []
    for i, sol in enumerate(res.solutions[:limit]):
        cores = [c.astype(dtype) for c in
                 tt_init(jax.random.PRNGKey(i), sol.plan)]
        x = jax.random.normal(jax.random.PRNGKey(limit + i),
                              (batch, sol.plan.N), jnp.float32).astype(dtype)
        tp = sol.plan
        if sol.weight_dtype == "int8":
            qcores, qscales = quantize_cores(cores)
            eplan = plan_tt_forward(tp.ns, tp.ms, tp.ranks, batch=batch,
                                    dtype=dtype, backend=backend,
                                    weights="int8", interpret=interpret)
            fwd = jax.jit(functools.partial(tt_forward, plan=eplan,
                                            interpret=interpret))
            call = functools.partial(fwd, qcores, x, scales=qscales)
        else:
            if sol.weight_dtype == "bf16":
                # candidates are timed at their own residency: bf16 cores
                # route through the dtype-aware fit model (2 B/elem), so a
                # bf16 twin that newly fits the fused chain ranks on the
                # fused kernel, not its fp32 sibling's time
                cores = [c.astype(jnp.bfloat16) for c in cores]
            eplan = plan_tt_forward(
                tp.ns, tp.ms, tp.ranks, batch=batch, dtype=dtype,
                backend=backend,
                weight_itemsize=jnp.dtype(cores[0].dtype).itemsize,
                interpret=interpret)
            fwd = jax.jit(functools.partial(tt_forward, plan=eplan,
                                            interpret=interpret))
            call = functools.partial(fwd, cores, x)
        jax.block_until_ready(call())              # trace+compile, untimed
        t = _median_time(call, warmup=0)
        timed.append((t, sol))
    timed.sort(key=lambda tp: tp[0])
    reranked = [sol for _, sol in timed] + res.solutions[limit:]
    counts = dict(res.counts, measured_rerank=len(timed))
    return DSEResult(res.M, res.N, counts, reranked)


def best_plan(M: int, N: int, rank: int = 8, length: int | None = 2,
              cfg: DSEConfig | None = None, min_factor: int | None = None
              ) -> TTPlan | None:
    """The layer-level entry point used by TTLinear: min-FLOPs surviving
    solution at uniform rank ``rank`` (paper §6.4 deploys length-2,
    min-FLOPs solutions)."""
    # fast path: only enumerate the requested rank
    cfg = dataclasses.replace(cfg or DSEConfig(),
                              vl=rank, rank_step=rank, rank_cap=rank)
    if min_factor is not None:
        cfg = dataclasses.replace(cfg, min_factor=min_factor)
    res = explore(M, N, cfg, with_counts=False)
    sol = res.best(length=length, rank=rank, default=None)
    if sol is None and length is not None:
        # relax the length preference
        sol = res.best(length=None, rank=rank, default=None)
    return sol.plan if sol else None
