"""Tensor-Train matrix core math (T3F conventions) in JAX.

A TT-matrix for ``W ∈ R^{M×N}`` (``y = W x``) is a list of ``d`` cores,
core ``t`` (1-indexed) of shape ``[r_{t-1}, n_t, m_t, r_t]`` — exactly the
layout used by the paper (§2) and the T3F library.

The forward pass is the paper's Listing-1 einsum chain:

    state  = x reshaped to [b_d, n_d, r_d]
    out_t  = einsum("rnmk,bnk->mbr", G_t, state)      # t = d … 1
    y      = flatten(out_1) (+ bias)

which performs **zero transposes** between steps — only reshapes — the
property the paper's compiler work relies on.  We preserve it here; the
single final transpose ([M, B] → [B, M]) is the price of a leading token
batch, and is absorbed by XLA into the consumer.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .flops import (clip_ranks, dense_params, prod, tt_flops, tt_params)


@dataclasses.dataclass(frozen=True)
class TTPlan:
    """A fully specified factorization choice for one FC layer."""
    ms: tuple[int, ...]          # output factors, Π = M
    ns: tuple[int, ...]          # input factors,  Π = N
    ranks: tuple[int, ...]       # r_0 … r_d (r_0 = r_d = 1)

    def __post_init__(self):
        assert len(self.ms) == len(self.ns), (self.ms, self.ns)
        assert len(self.ranks) == len(self.ms) + 1
        assert self.ranks[0] == 1 and self.ranks[-1] == 1

    @property
    def d(self) -> int:
        return len(self.ms)

    @property
    def M(self) -> int:
        return prod(self.ms)

    @property
    def N(self) -> int:
        return prod(self.ns)

    @property
    def core_shapes(self) -> list[tuple[int, int, int, int]]:
        return [(self.ranks[t], self.ns[t], self.ms[t], self.ranks[t + 1])
                for t in range(self.d)]

    @property
    def params(self) -> int:
        return tt_params(self.ms, self.ns, self.ranks, bias=False)

    @property
    def flops(self) -> int:
        return tt_flops(self.ms, self.ns, self.ranks, bias=False)

    @property
    def compression(self) -> float:
        return dense_params(self.M, self.N, bias=False) / max(1, self.params)

    def describe(self) -> str:
        return (f"TT[M={self.M}={'x'.join(map(str, self.ms))}, "
                f"N={self.N}={'x'.join(map(str, self.ns))}, "
                f"r={list(self.ranks)}] params={self.params} "
                f"flops={self.flops} cx={self.compression:.1f}x")


def make_plan(ms: Sequence[int], ns: Sequence[int],
              rank: int | Sequence[int]) -> TTPlan:
    """Build a TTPlan; a scalar ``rank`` means [1, R, …, R, 1] (paper §2),
    clipped to the feasible max rank at each cut (paper footnote 5)."""
    ms, ns = tuple(int(m) for m in ms), tuple(int(n) for n in ns)
    d = len(ms)
    if isinstance(rank, int):
        ranks = [1] + [rank] * (d - 1) + [1]
    else:
        ranks = list(rank)
    return TTPlan(ms, ns, clip_ranks(ms, ns, ranks))


# ---------------------------------------------------------------------------
# Initialization / conversion
# ---------------------------------------------------------------------------

def tt_init(key: jax.Array, plan: TTPlan, dtype=jnp.float32,
            target_std: float | None = None) -> list[jax.Array]:
    """Random TT cores such that the implied dense W has elementwise std
    ≈ ``target_std`` (default: Glorot, sqrt(2/(M+N))).

    For iid N(0, σ²) cores, Var(W_ij) = (Π_t σ_t²) · (Π_{t=1}^{d-1} r_t), so
    each core gets σ_t = (target_var / Π r_t)^(1/2d).
    """
    if target_std is None:
        target_std = float(np.sqrt(2.0 / (plan.M + plan.N)))
    rank_prod = prod(plan.ranks[1:-1]) if plan.d > 1 else 1
    sigma = (target_std ** 2 / max(rank_prod, 1)) ** (1.0 / (2 * plan.d))
    keys = jax.random.split(key, plan.d)
    return [jax.random.normal(k, shape, dtype) * jnp.asarray(sigma, dtype)
            for k, shape in zip(keys, plan.core_shapes)]


def tt_decompose(W: jax.Array | np.ndarray, plan: TTPlan,
                 ) -> list[np.ndarray]:
    """TT-SVD of a dense ``W [M, N]`` into cores per ``plan`` (numpy;
    offline tooling — matches what T3F's ``to_tt_matrix`` computes).

    Ranks are clipped to the matrix rank of each unfolding, so for
    sufficiently large requested ranks reconstruction is exact.
    """
    W = np.asarray(W, np.float64)
    assert W.shape == (plan.M, plan.N)
    d, ms, ns, ranks = plan.d, plan.ms, plan.ns, plan.ranks
    # [M, N] -> [m_1.., n_1..] -> interleave -> [n_1, m_1, n_2, m_2, ...]
    T = W.reshape(ms + ns)
    perm = []
    for t in range(d):
        perm += [d + t, t]          # (n_t, m_t)
    T = T.transpose(perm)
    cores: list[np.ndarray] = []
    r_prev = 1
    for t in range(d):
        nt, mt = ns[t], ms[t]
        rest = T.size // (r_prev * nt * mt)
        mat = T.reshape(r_prev * nt * mt, rest)
        U, S, Vh = np.linalg.svd(mat, full_matrices=False)
        r_t = 1 if t == d - 1 else min(ranks[t + 1], len(S))
        cores.append(U[:, :r_t].reshape(r_prev, nt, mt, r_t))
        T = (S[:r_t, None] * Vh[:r_t]).reshape((r_t,) + tuple(
            x for pair in [(ns[i], ms[i]) for i in range(t + 1, d)]
            for x in pair))
        r_prev = r_t
    # absorb the residual scalar chain into the last core
    cores[-1] = cores[-1] * T.reshape(1, 1, 1, 1) if T.ndim == 1 and T.size == 1 \
        else cores[-1]
    return [c.astype(np.float32) for c in cores]


def tt_reconstruct(cores: Sequence[jax.Array]) -> jax.Array:
    """Contract TT cores back to the dense ``W [M, N]`` (testing only)."""
    d = len(cores)
    # acc over processed cores: [n_1..n_t, m_1..m_t, r_t]
    acc = None
    ms, ns = [], []
    for t, G in enumerate(cores):
        r0, nt, mt, r1 = G.shape
        ns.append(nt)
        ms.append(mt)
        if acc is None:
            acc = G  # [1, n, m, r] -> treat as [n, m, r]
            acc = acc.reshape(nt, mt, r1)
        else:
            # acc [..., r0] x G [r0, n, m, r1] -> [..., n, m, r1]
            acc = jnp.tensordot(acc, G, axes=[[-1], [0]])
    # acc dims: n_1, m_1, n_2, m_2, ..., n_d, m_d
    perm_m = [2 * t + 1 for t in range(d)]
    perm_n = [2 * t for t in range(d)]
    acc = acc.reshape(tuple(x for t in range(d) for x in (ns[t], ms[t])))
    acc = acc.transpose(perm_m + perm_n)
    return acc.reshape(prod(ms), prod(ns))


# ---------------------------------------------------------------------------
# Forward (paper Listing 1, batched)
# ---------------------------------------------------------------------------

def tt_apply_chain(cores: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """Paper-faithful einsum chain.  ``x [B, N] → y [B, M]`` (no bias).

    Executes cores d → 1 with einsum("rnmk,bnk->mbr") and reshapes only,
    exactly as T3F / paper Listing 1; the token batch B is folded into the
    chain's ``b`` dimension and recovered by one final transpose.
    """
    B = x.shape[0]
    state = x.reshape(B, -1)                      # [B, N]
    d = len(cores)
    # fold B into the leading position of the b-block
    state = state.reshape(-1)                     # [B*N]
    b = state.shape[0]
    for t in range(d - 1, -1, -1):
        G = cores[t]
        r0, nt, mt, r1 = G.shape
        state = state.reshape(b // (nt * r1), nt, r1)
        # einsum("rnmk,bnk->mbr")
        state = jnp.einsum("rnmk,bnk->mbr", G, state,
                           preferred_element_type=state.dtype)
        b = state.size
        state = state.reshape(-1)
    M = b // B
    # layout is [m_1, …, m_d, B] → transpose to [B, M]
    return state.reshape(M, B).T


def tt_apply_batched(cores: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """SPMD-friendly chain: the token axis stays leading throughout.

    The paper's chain (``tt_apply_chain``) folds the token batch into the
    chain's ``b`` dimension — the right loop fusion for a single CPU, but
    it reshapes *through* the batch axis, so GSPMD loses the data-parallel
    sharding and re-gathers activations at every step (measured: qwen3
    train t_coll 44.7 → 448.7 s with naive TT; EXPERIMENTS §Perf it. 3).
    Keeping ``T`` leading makes every reshape feature-only: the chain is
    collective-free and the final [m, B] transpose disappears.

    Identical math: the paper's b_t always factors as B·(b_t/B) with B
    leading, so this is the same contraction with T pulled outside.
    """
    T = x.shape[0]
    state = x                                     # [T, F]
    for t in range(len(cores) - 1, -1, -1):
        G = cores[t]
        r0, nt, mt, r1 = G.shape
        f = state.shape[-1] if state.ndim == 2 else int(
            np.prod(state.shape[1:]))
        state = state.reshape(T, f // (nt * r1), nt, r1)
        # paper step einsum with the token axis carried through
        state = jnp.einsum("rnmk,tbnk->tmbr", G, state,
                           preferred_element_type=state.dtype)
        state = state.reshape(T, -1)
    return state                                  # [T, M] (m-major == M)


def tt_apply(cores: Sequence[jax.Array], x: jax.Array,
             bias: jax.Array | None = None) -> jax.Array:
    """Apply a TT layer to ``x [..., N]`` → ``[..., M]``."""
    lead = x.shape[:-1]
    y = tt_apply_batched(cores, x.reshape(-1, x.shape[-1]))
    if bias is not None:
        y = y + bias
    return y.reshape(lead + (y.shape[-1],))
