"""Pure-jnp oracles for the TT einsum kernels.

These are the correctness references each Pallas kernel is swept against
(tests/test_kernels.py) and the "unoptimized" baseline of the paper's
Figs. 12–16 breakdown.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from repro.core.tt import tt_apply_chain


def tt_einsum_step_ref(G: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """Paper Listing 2: out[m,b,r] = Σ_{n,k} G[r,n,m,k]·X[b,n,k].

    ``G [r_{t-1}, n_t, m_t, r_t]``, ``X [b_t, n_t, r_t]`` →
    ``out [m_t, b_t, r_{t-1}]`` — accumulation in fp32.
    """
    out = jnp.einsum("rnmk,bnk->mbr", G.astype(jnp.float32),
                     X.astype(jnp.float32))
    return out.astype(X.dtype)


def tt_chain_ref(cores: Sequence[jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """Whole-layer oracle: ``x [B, N] → y [B, M]`` via the paper chain."""
    return tt_apply_chain(cores, x)


def tt_fused2_ref(cores: Sequence[jnp.ndarray], x: jnp.ndarray
                  ) -> jnp.ndarray:
    """Oracle for the fused d=2 kernel — identical math to tt_chain_ref but
    written as the two packed matmuls + explicit relayouts the kernel fuses.

    cores: [G1 [1, n1, m1, r1], G2 [r1, n2, m2, 1]];  x [B, n1*n2].
    """
    assert len(cores) == 2
    G1, G2 = cores
    _, n1, m1, r1 = G1.shape
    r1b, n2, m2, r2 = G2.shape
    assert r1b == r1 and r2 == 1 and G1.shape[0] == 1
    B = x.shape[0]
    f32 = jnp.float32
    p2 = G2.transpose(1, 3, 2, 0).reshape(n2, m2 * r1).astype(f32)   # packed
    p1 = G1.transpose(1, 3, 2, 0).reshape(n1 * r1, m1).astype(f32)   # packed
    a = x.reshape(B * n1, n2).astype(f32) @ p2                       # MXU 1
    a = a.reshape(B, n1, m2, r1).transpose(0, 2, 1, 3)               # VMEM T
    y = a.reshape(B * m2, n1 * r1) @ p1                              # MXU 2
    y = y.reshape(B, m2, m1).transpose(0, 2, 1)                      # VMEM T
    return y.reshape(B, m1 * m2).astype(x.dtype)
