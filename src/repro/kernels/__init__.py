"""TT kernel stack: Pallas kernels (``tt_contract``), the measured
block-plan autotuner (``autotune``), the plan-compile-execute pipeline
(``plan``) and the thin plan executor (``ops.tt_forward``).
DESIGN.md §2, §8, §10.
"""
from .plan import (PLANNING_BATCH, PlanBook,  # noqa: F401
                   TTExecutionPlan, clear_plan_memo, plan_resolutions,
                   plan_tt_forward, resolve_plan)
