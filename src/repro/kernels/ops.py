"""Public jit'd entry points for TT layer application.

``tt_forward(cores, x, bias, backend)`` dispatches between:

  'xla'           — paper-faithful einsum chain lowered by XLA
                    (the "IREE-class compiler" baseline of Figs. 12–14)
  'pallas_step'   — chain with one blocked Pallas kernel per einsum step
  'pallas_fused2' — single fused kernel for d=2 plans (paper §6.4 deploys
                    length-2 solutions; this is the fast path)
  'auto'          — fused2 when d==2, else pallas_step
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.packing import pack_core, select_blocks
from repro.core.tt import tt_apply
from .tt_contract import tt_fused2_pallas, tt_step_pallas

BACKENDS = ("xla", "pallas_step", "pallas_fused2", "auto")


def _chain_with_step_kernel(cores: Sequence[jax.Array], x: jax.Array,
                            interpret: bool | None) -> jax.Array:
    """Paper chain where each einsum runs in the blocked Pallas kernel.
    Layout between steps follows the paper exactly: reshapes only."""
    B = x.shape[0]
    state = x.reshape(-1)
    b = state.shape[0]
    for t in range(len(cores) - 1, -1, -1):
        G = cores[t]
        r0, nt, mt, r1 = G.shape
        bt = b // (nt * r1)
        st = state.reshape(bt, nt, r1)
        plan = select_blocks(mt, bt, nt, r1, r0)
        out = tt_step_pallas(G, st, plan, interpret=interpret)   # [m, b, r0]
        state = out.reshape(-1).astype(x.dtype)
        b = state.shape[0]
    M = b // B
    return state.reshape(M, B).T


def tt_forward(cores: Sequence[jax.Array], x: jax.Array,
               bias: jax.Array | None = None, backend: str = "auto",
               interpret: bool | None = None) -> jax.Array:
    """Apply a TT layer to ``x [..., N]`` → ``[..., M]``."""
    assert backend in BACKENDS, backend
    d = len(cores)
    if backend == "auto":
        backend = "pallas_fused2" if d == 2 else "pallas_step"

    lead, N = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, N)

    if backend == "xla":
        y = tt_apply(cores, x2)
    elif backend == "pallas_fused2":
        assert d == 2, "fused2 backend requires a length-2 plan"
        G1, G2 = cores
        _, n1, m1, r1 = G1.shape
        _, n2, m2, _ = G2.shape
        y = tt_fused2_pallas(
            x2, pack_core(G2), pack_core(G1),
            dims=(n1, n2, m1, m2, r1), interpret=interpret)
    else:
        y = _chain_with_step_kernel(cores, x2, interpret)

    if bias is not None:
        y = y + bias
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)
