"""Public jit'd entry points for TT layer application.

``tt_forward(cores, x, bias, backend)`` dispatches between:

  'xla'           — paper-faithful einsum chain lowered by XLA
                    (the "IREE-class compiler" baseline of Figs. 12–14)
  'pallas_step'   — chain with one blocked Pallas kernel per einsum step
                    (every intermediate round-trips through HBM)
  'pallas_fused2' — single fused kernel for d=2 plans (paper §6.4 deploys
                    length-2 solutions; this is the d=2 fast path)
  'pallas_fused'  — single fused kernel for ANY depth d ≥ 2: all packed
                    matmuls + relayouts in VMEM, zero HBM intermediates
  'auto'          — fused2 when d==2; fused chain when the whole chain is
                    VMEM-resident (core.packing.fused_chain_batch_tile /
                    chain_fits_vmem); pallas_step otherwise

A backend string may carry ``:``-separated suffix tokens, e.g.
``"auto:measure"`` or ``"auto:measure:int8"``: a tune mode
(off | cached | measure) is handed to the empirical autotuner
(kernels.autotune) and a weight mode (fp | int8) selects the resident
core dtype.  Explicit ``tune=`` / ``weights=`` arguments win over the
suffix.  Default tune mode is 'cached' (no timing; dict lookup).

``weights='int8'`` (DESIGN.md §8) keeps the packed cores int8 all the way
into VMEM: the Pallas backends dispatch to the ``*_int8_pallas`` kernel
variants (in-kernel dequant, fp32 accumulation), and the ``auto`` routing
re-evaluates fused eligibility under 1-byte weight residency — chains that
are step-fallback in fp32 can fuse under int8.  Cores may arrive either as
float (quantized on the fly, symmetric per-core scales) or pre-quantized
int8 with an explicit ``scales`` sequence (models/layers quantized
storage).  The fp path prices weight residency at the cores' own itemsize
(bf16 cores count 2 bytes), so the fit model is dtype-aware throughout.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.packing import fused_chain_batch_tile, pack_core
from repro.core.quant import dequantize_cores, quantize_cores
from repro.core.tt import tt_apply
from . import autotune
from .tt_contract import (tt_fused2_int8_pallas, tt_fused2_pallas,
                          tt_fused_chain_int8_pallas, tt_fused_chain_pallas,
                          tt_step_int8_pallas, tt_step_pallas)

BACKENDS = ("xla", "pallas_step", "pallas_fused2", "pallas_fused", "auto")
# accepted weight-mode tokens ('fp32' is an alias kept for TTConfig
# readability; the canonical modes are autotune.WEIGHT_MODES)
_WEIGHT_ALIASES = {"fp": "fp", "fp32": "fp", "float32": "fp", "int8": "int8"}


def chain_dims(cores: Sequence[jax.Array]
               ) -> tuple[tuple[int, ...], tuple[int, ...], tuple[int, ...]]:
    """(ns, ms, ranks) signature of a core list (the TTPlan triple)."""
    ns = tuple(int(G.shape[1]) for G in cores)
    ms = tuple(int(G.shape[2]) for G in cores)
    ranks = tuple(int(G.shape[0]) for G in cores) + (int(cores[-1].shape[3]),)
    return ns, ms, ranks


def parse_backend_spec(backend: str, tune: str | None = None,
                       weights: str | None = None
                       ) -> tuple[str, str | None, str | None]:
    """Split ``"<backend>[:<tune>][:<weights>]"`` into its parts.

    Suffix tokens are classified by membership (tune modes vs weight
    modes) so the order is free; explicit ``tune=``/``weights=`` arguments
    always win over suffix tokens.  Weight aliases ('fp32', 'float32')
    normalize to the canonical 'fp' in both positions."""
    if weights is not None:
        if weights not in _WEIGHT_ALIASES:
            raise ValueError(
                f"unknown weight mode {weights!r}: expected one of "
                f"{tuple(_WEIGHT_ALIASES)}")
        weights = _WEIGHT_ALIASES[weights]
    if ":" in backend:
        backend, *suffix = backend.split(":")
        suffix_tune = suffix_weights = None
        for tok in suffix:
            if tok in autotune.TUNE_MODES:
                if suffix_tune is not None:
                    raise ValueError(
                        f"conflicting tune-mode suffixes "
                        f"{suffix_tune!r} and {tok!r} in backend spec")
                suffix_tune = tok
            elif tok in _WEIGHT_ALIASES:
                if suffix_weights is not None:
                    raise ValueError(
                        f"conflicting weight-mode suffixes "
                        f"{suffix_weights!r} and {tok!r} in backend spec")
                suffix_weights = _WEIGHT_ALIASES[tok]
            else:
                raise ValueError(
                    f"unknown backend suffix {tok!r}: expected a tune mode "
                    f"{autotune.TUNE_MODES} or a weight mode "
                    f"{tuple(_WEIGHT_ALIASES)}")
        tune = tune if tune is not None else suffix_tune
        weights = weights if weights is not None else suffix_weights
    return backend, tune, weights


def _chain_with_step_kernel(cores: Sequence[jax.Array], x: jax.Array,
                            interpret: bool | None, tune: str,
                            scales: Sequence[jax.Array] | None = None
                            ) -> jax.Array:
    """Paper chain where each einsum runs in the blocked Pallas kernel.
    Layout between steps follows the paper exactly: reshapes only.
    With ``scales`` the cores are int8-resident (one launch of the int8
    step kernel per core)."""
    B = x.shape[0]
    state = x.reshape(-1)
    b = state.shape[0]
    for t in range(len(cores) - 1, -1, -1):
        G = cores[t]
        r0, nt, mt, r1 = G.shape
        if b % (nt * r1) != 0:
            raise ValueError(
                f"TT chain/input mismatch at step {t}: state of {b} "
                f"elements is not divisible by n_{t}·r_{t} = {nt}·{r1} "
                f"(core shape {tuple(G.shape)}) — the core list is "
                f"inconsistent with x.shape[-1] or the inter-core ranks")
        bt = b // (nt * r1)
        st = state.reshape(bt, nt, r1)
        if scales is not None:
            plan = autotune.step_plan(mt, bt, nt, r1, r0, x.dtype,
                                      mode=tune, interpret=interpret,
                                      weights="int8")
            out = tt_step_int8_pallas(G, scales[t], st, plan,
                                      interpret=interpret)
        else:
            plan = autotune.step_plan(
                mt, bt, nt, r1, r0, G.dtype, mode=tune, interpret=interpret,
                weight_itemsize=jnp.dtype(G.dtype).itemsize)
            out = tt_step_pallas(G, st, plan, interpret=interpret)
        state = out.reshape(-1).astype(x.dtype)   # [m, b, r0] flattened
        b = state.shape[0]
    M = b // B
    return state.reshape(M, B).T


def tt_forward(cores: Sequence[jax.Array], x: jax.Array,
               bias: jax.Array | None = None, backend: str = "auto",
               interpret: bool | None = None,
               tune: str | None = None,
               weights: str | None = None,
               scales: Sequence[jax.Array] | jax.Array | None = None
               ) -> jax.Array:
    """Apply a TT layer to ``x [..., N]`` → ``[..., M]``.

    ``backend`` may embed the tune and/or weight mode as
    ``"<backend>:<tune>:<weights>"``; explicit ``tune=`` / ``weights=``
    arguments win over the suffix.  ``weights='int8'`` runs the
    int8-resident kernel path: float ``cores`` are quantized on the fly
    (symmetric per-core scales), pre-quantized int8 ``cores`` require the
    matching ``scales``.  Int8 cores passed without a weight mode imply
    ``weights='int8'``.
    """
    backend, tune, weights = parse_backend_spec(backend, tune, weights)
    tune = tune or "cached"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}: expected one of {BACKENDS}")
    if tune not in autotune.TUNE_MODES:
        raise ValueError(
            f"unknown tune mode {tune!r}: expected one of "
            f"{autotune.TUNE_MODES}")
    if weights is None and cores[0].dtype == jnp.int8:
        weights = "int8"
    weights = weights or "fp"
    if weights not in autotune.WEIGHT_MODES:
        raise ValueError(
            f"unknown weight mode {weights!r}: expected one of "
            f"{autotune.WEIGHT_MODES}")

    d = len(cores)
    ns, ms, ranks = chain_dims(cores)
    Nc = 1
    for n in ns:
        Nc *= n
    if Nc != x.shape[-1]:
        raise ValueError(
            f"TT core list with input modes {ns} (prod={Nc}) does not "
            f"match x.shape[-1]={x.shape[-1]}")
    for t in range(len(cores) - 1):
        if cores[t].shape[3] != cores[t + 1].shape[0]:
            raise ValueError(
                f"TT rank mismatch between cores {t} and {t + 1}: "
                f"r={cores[t].shape[3]} vs r={cores[t + 1].shape[0]}")

    qcores: list[jax.Array] | None = None
    qscales: list[jax.Array] | None = None
    if weights == "int8":
        if cores[0].dtype == jnp.int8:
            if scales is None:
                raise ValueError(
                    "pre-quantized int8 cores require the matching per-core "
                    "scales (core.quant.quantize_cores)")
            qcores, qscales = list(cores), list(scales)
        else:
            if scales is not None:
                raise ValueError(
                    "scales are only accepted with pre-quantized int8 "
                    "cores; float cores are quantized on the fly with "
                    "their own scales — externally calibrated scales "
                    "would be silently discarded here")
            qcores, qscales = quantize_cores(cores)
        w_itemsize = 1
    elif cores[0].dtype == jnp.int8:
        raise ValueError(
            "int8 cores cannot run the float path — pass weights='int8' "
            "with their scales")
    else:
        if scales is not None:
            raise ValueError(
                "scales were passed but weights is not 'int8' — they "
                "would be silently ignored")
        w_itemsize = jnp.dtype(cores[0].dtype).itemsize

    lead, N = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, N)
    B = x2.shape[0]
    itemsize = max(x.dtype.itemsize, 4)

    if backend == "auto":
        if d == 2:
            backend = "pallas_fused2"
        elif d > 2 and fused_chain_batch_tile(
                ns, ms, ranks, itemsize=itemsize,
                weight_itemsize=w_itemsize) is not None:
            backend = "pallas_fused"
        else:
            backend = "pallas_step"

    if backend == "xla":
        if weights == "int8":
            y = tt_apply(dequantize_cores(qcores, qscales, jnp.float32),
                         x2.astype(jnp.float32))
        else:
            y = tt_apply(cores, x2)
    elif backend == "pallas_fused2":
        if d != 2:
            raise ValueError(
                f"fused2 backend requires a length-2 plan, got d={d}")
        n1, n2 = ns
        m1, m2 = ms
        block_b = autotune.fused_tile(ns, ms, ranks, x.dtype, B,
                                      mode=tune, interpret=interpret,
                                      weights=weights,
                                      weight_itemsize=w_itemsize)
        dims2 = (n1, n2, m1, m2, ranks[1])
        if weights == "int8":
            y = tt_fused2_int8_pallas(
                x2, pack_core(qcores[1]), pack_core(qcores[0]),
                [qscales[1], qscales[0]], dims2,
                block_b=block_b, interpret=interpret)
        else:
            y = tt_fused2_pallas(
                x2, pack_core(cores[1]), pack_core(cores[0]),
                dims=dims2, block_b=block_b, interpret=interpret)
    elif backend == "pallas_fused":
        if d < 2:
            raise ValueError(
                f"fused chain backend requires d >= 2, got d={d}")
        block_b = autotune.fused_tile(ns, ms, ranks, x.dtype, B,
                                      mode=tune, interpret=interpret,
                                      weights=weights,
                                      weight_itemsize=w_itemsize)
        if block_b is None:
            raise ValueError(
                "chain does not fit VMEM — use pallas_step (or "
                "backend='auto')")
        if weights == "int8":
            packed = [pack_core(G) for G in reversed(qcores)]
            y = tt_fused_chain_int8_pallas(
                x2, packed, list(reversed(qscales)), (ns, ms, ranks),
                block_b=block_b, interpret=interpret)
        else:
            packed = [pack_core(G) for G in reversed(cores)]
            y = tt_fused_chain_pallas(x2, packed, (ns, ms, ranks),
                                      block_b=block_b, interpret=interpret)
    else:
        y = _chain_with_step_kernel(qcores if weights == "int8" else cores,
                                    x2, interpret, tune, scales=qscales)

    if bias is not None:
        y = y + bias
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)
