"""Public jit'd entry points for TT layer application.

``tt_forward(cores, x, bias, plan=...)`` EXECUTES a resolved
:class:`kernels.plan.TTExecutionPlan` (DESIGN.md §10): the plan already
carries the concrete backend, the fused batch tile or per-step block
plans, the weight mode and the VMEM fit verdict, so execution is a pure
dispatch — no string parsing, no fit heuristics, no autotune lookups.

Backends a plan can resolve to:

  'xla'           — paper-faithful einsum chain lowered by XLA
                    (the "IREE-class compiler" baseline of Figs. 12–14)
  'pallas_step'   — chain with one blocked Pallas kernel per einsum step
                    (every intermediate round-trips through HBM)
  'pallas_fused2' — single fused kernel for d=2 plans (paper §6.4 deploys
                    length-2 solutions; this is the d=2 fast path)
  'pallas_fused'  — single fused kernel for ANY depth d ≥ 2: all packed
                    matmuls + relayouts in VMEM, zero HBM intermediates

Without ``plan=`` the call goes through the DEPRECATION SHIM: the
``backend`` string (optionally a legacy ``"<backend>[:<tune>][:<weights>]"``
spec, e.g. ``"auto:measure:int8"``) is compiled into a plan by the
memoized resolver ``kernels.plan.resolve_plan`` at the call's batch size.
The behavior is identical to the plan path — ``'auto'`` routes fused2 at
d=2, the fused chain when the dtype-aware VMEM fit admits it, per-step
otherwise — but model code should resolve plans ONCE at build time
(``models``' PlanBook) instead of per call.

``weights='int8'`` (DESIGN.md §8) keeps the packed cores int8 all the way
into VMEM: the Pallas backends dispatch to the ``*_int8_pallas`` kernel
variants (in-kernel dequant, fp32 accumulation), and the fit verdict is
priced at 1-byte weight residency — chains that are step-fallback in fp32
can fuse under int8.  Cores may arrive either as float (quantized on the
fly, symmetric per-core scales) or pre-quantized int8 with an explicit
``scales`` sequence (models/layers quantized storage).  The fp path prices
weight residency at the cores' own itemsize (bf16 cores count 2 bytes).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.packing import pack_core
from repro.core.quant import dequantize_cores, quantize_cores
from repro.core.tt import tt_apply
from . import autotune
from . import plan as planner
from .plan import BACKENDS, WEIGHT_ALIASES, TTExecutionPlan  # noqa: F401
from .tt_contract import (tt_fused2_int8_pallas, tt_fused2_pallas,
                          tt_fused_chain_int8_pallas, tt_fused_chain_pallas,
                          tt_step_int8_pallas, tt_step_pallas)

# legacy alias (plan.WEIGHT_ALIASES is canonical)
_WEIGHT_ALIASES = WEIGHT_ALIASES


def chain_dims(cores: Sequence[jax.Array]
               ) -> tuple[tuple[int, ...], tuple[int, ...], tuple[int, ...]]:
    """(ns, ms, ranks) signature of a core list (the TTPlan triple)."""
    ns = tuple(int(G.shape[1]) for G in cores)
    ms = tuple(int(G.shape[2]) for G in cores)
    ranks = tuple(int(G.shape[0]) for G in cores) + (int(cores[-1].shape[3]),)
    return ns, ms, ranks


def parse_backend_spec(backend: str, tune: str | None = None,
                       weights: str | None = None
                       ) -> tuple[str, str | None, str | None]:
    """Split ``"<backend>[:<tune>][:<weights>]"`` into its parts
    (deprecation shim — see ``kernels.plan.compile_spec``, which this
    delegates to).  Malformed specs (unknown or empty tokens, duplicate
    token classes) raise a ValueError naming every valid token."""
    return planner.compile_spec(backend, tune, weights)


def _chain_with_step_kernel(cores: Sequence[jax.Array], x: jax.Array,
                            interpret: bool | None,
                            step_plans: Sequence,
                            scales: Sequence[jax.Array] | None = None
                            ) -> jax.Array:
    """Paper chain where each einsum runs in the blocked Pallas kernel.
    Layout between steps follows the paper exactly: reshapes only.
    ``step_plans`` are the plan's per-step BlockPlans in execution order
    (core d first); the kernel clamps tiles to the runtime extents, so a
    plan resolved at the nominal planning batch serves any batch.
    With ``scales`` the cores are int8-resident (one launch of the int8
    step kernel per core)."""
    B = x.shape[0]
    state = x.reshape(-1)
    b = state.shape[0]
    for j, t in enumerate(range(len(cores) - 1, -1, -1)):
        G = cores[t]
        r0, nt, mt, r1 = G.shape
        if b % (nt * r1) != 0:
            raise ValueError(
                f"TT chain/input mismatch at step {t}: state of {b} "
                f"elements is not divisible by n_{t}·r_{t} = {nt}·{r1} "
                f"(core shape {tuple(G.shape)}) — the core list is "
                f"inconsistent with x.shape[-1] or the inter-core ranks")
        bt = b // (nt * r1)
        st = state.reshape(bt, nt, r1)
        bplan = step_plans[j]
        if scales is not None:
            out = tt_step_int8_pallas(G, scales[t], st, bplan,
                                      interpret=interpret)
        else:
            out = tt_step_pallas(G, st, bplan, interpret=interpret)
        state = out.reshape(-1).astype(x.dtype)   # [m, b, r0] flattened
        b = state.shape[0]
    M = b // B
    return state.reshape(M, B).T


def tt_forward(cores: Sequence[jax.Array], x: jax.Array,
               bias: jax.Array | None = None, backend: str = "auto",
               interpret: bool | None = None,
               tune: str | None = None,
               weights: str | None = None,
               scales: Sequence[jax.Array] | jax.Array | None = None,
               plan: TTExecutionPlan | None = None) -> jax.Array:
    """Apply a TT layer to ``x [..., N]`` → ``[..., M]``.

    ``plan=`` executes a pre-resolved :class:`TTExecutionPlan` directly —
    the model stack resolves each layer's plan once at build time and
    passes it here, so tracing performs zero planning.  Without a plan the
    call compiles one from the legacy arguments: ``backend`` may embed the
    tune and/or weight mode as ``"<backend>:<tune>:<weights>"`` (a
    deprecated spelling); explicit ``tune=`` / ``weights=`` arguments win
    over the suffix.  ``weights='int8'`` runs the int8-resident kernel
    path: float ``cores`` are quantized on the fly (symmetric per-core
    scales), pre-quantized int8 ``cores`` require the matching ``scales``.
    Int8 cores passed without a weight mode imply ``weights='int8'``.
    """
    d = len(cores)
    ns, ms, ranks = chain_dims(cores)
    Nc = 1
    for n in ns:
        Nc *= n
    if Nc != x.shape[-1]:
        raise ValueError(
            f"TT core list with input modes {ns} (prod={Nc}) does not "
            f"match x.shape[-1]={x.shape[-1]}")
    for t in range(len(cores) - 1):
        if cores[t].shape[3] != cores[t + 1].shape[0]:
            raise ValueError(
                f"TT rank mismatch between cores {t} and {t + 1}: "
                f"r={cores[t].shape[3]} vs r={cores[t + 1].shape[0]}")

    if plan is not None:
        if (plan.ns, plan.ms, plan.ranks) != (ns, ms, ranks):
            raise ValueError(
                f"plan/chain mismatch: plan is for n={plan.ns} m={plan.ms} "
                f"r={plan.ranks}, cores are n={ns} m={ms} r={ranks}")
        # the plan is authoritative: conflicting legacy arguments are an
        # error, never silently dropped
        if backend not in ("auto", plan.requested, plan.backend):
            raise ValueError(
                f"backend={backend!r} conflicts with the plan "
                f"({plan.requested!r} -> {plan.backend!r}) — drop the "
                f"argument or re-plan")
        if tune is not None and tune != plan.tune:
            raise ValueError(
                f"tune={tune!r} conflicts with the plan's tune mode "
                f"{plan.tune!r} — drop the argument or re-plan")
        if weights is not None and \
                planner.normalize_weights(weights) != plan.weights:
            raise ValueError(
                f"weights={weights!r} conflicts with the plan's weight "
                f"mode {plan.weights!r} — drop the argument or re-plan")
        weights = plan.weights
    else:
        backend, tune, weights = planner.compile_spec(
            backend, tune, weights, warn=True)
        tune = tune or "cached"
        if tune not in autotune.TUNE_MODES:
            raise ValueError(
                f"unknown tune mode {tune!r}: expected one of "
                f"{autotune.TUNE_MODES}")
        if weights is None and cores[0].dtype == jnp.int8:
            weights = "int8"
        weights = weights or "fp"

    # --------------------------------------------------------- core storage
    qcores: list[jax.Array] | None = None
    qscales: list[jax.Array] | None = None
    if weights == "int8":
        if cores[0].dtype == jnp.int8:
            if scales is None:
                raise ValueError(
                    "pre-quantized int8 cores require the matching per-core "
                    "scales (core.quant.quantize_cores)")
            qcores, qscales = list(cores), list(scales)
        else:
            if scales is not None:
                raise ValueError(
                    "scales are only accepted with pre-quantized int8 "
                    "cores; float cores are quantized on the fly with "
                    "their own scales — externally calibrated scales "
                    "would be silently discarded here")
            qcores, qscales = quantize_cores(cores)
        w_itemsize = 1
    elif cores[0].dtype == jnp.int8:
        raise ValueError(
            "int8 cores cannot run the float path — pass weights='int8' "
            "with their scales")
    else:
        if scales is not None:
            raise ValueError(
                "scales were passed but weights is not 'int8' — they "
                "would be silently ignored")
        w_itemsize = jnp.dtype(cores[0].dtype).itemsize

    lead, N = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, N)
    B = x2.shape[0]

    if plan is None:
        plan = planner.resolve_plan(
            ns, ms, ranks, batch=B, dtype=x.dtype, backend=backend,
            tune=tune, weights=weights, weight_itemsize=w_itemsize,
            interpret=interpret)

    # ------------------------------------------------------------ execution
    if plan.backend == "xla":
        if weights == "int8":
            y = tt_apply(dequantize_cores(qcores, qscales, jnp.float32),
                         x2.astype(jnp.float32))
        else:
            y = tt_apply(cores, x2)
    elif plan.backend == "pallas_fused2":
        n1, n2 = ns
        m1, m2 = ms
        dims2 = (n1, n2, m1, m2, ranks[1])
        if weights == "int8":
            y = tt_fused2_int8_pallas(
                x2, pack_core(qcores[1]), pack_core(qcores[0]),
                [qscales[1], qscales[0]], dims2,
                block_b=plan.block_b, interpret=interpret)
        else:
            y = tt_fused2_pallas(
                x2, pack_core(cores[1]), pack_core(cores[0]),
                dims=dims2, block_b=plan.block_b, interpret=interpret)
    elif plan.backend == "pallas_fused":
        if plan.block_b is None:
            raise ValueError(
                "malformed plan: pallas_fused without a batch tile — "
                "re-resolve with kernels.plan.plan_tt_forward")
        if weights == "int8":
            packed = [pack_core(G) for G in reversed(qcores)]
            y = tt_fused_chain_int8_pallas(
                x2, packed, list(reversed(qscales)), (ns, ms, ranks),
                block_b=plan.block_b, interpret=interpret)
        else:
            packed = [pack_core(G) for G in reversed(cores)]
            y = tt_fused_chain_pallas(x2, packed, (ns, ms, ranks),
                                      block_b=plan.block_b,
                                      interpret=interpret)
    elif plan.backend == "pallas_step":
        if plan.step_plans is None or len(plan.step_plans) != d:
            raise ValueError(
                "malformed plan: pallas_step without per-step block plans "
                "— re-resolve with kernels.plan.plan_tt_forward")
        y = _chain_with_step_kernel(qcores if weights == "int8" else cores,
                                    x2, interpret, plan.step_plans,
                                    scales=qscales)
    else:
        raise ValueError(
            f"plan resolved to unknown backend {plan.backend!r}")

    if bias is not None:
        y = y + bias
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)
