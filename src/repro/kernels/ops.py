"""Public jit'd entry points for TT layer application.

``tt_forward(cores, x, bias, backend)`` dispatches between:

  'xla'           — paper-faithful einsum chain lowered by XLA
                    (the "IREE-class compiler" baseline of Figs. 12–14)
  'pallas_step'   — chain with one blocked Pallas kernel per einsum step
                    (every intermediate round-trips through HBM)
  'pallas_fused2' — single fused kernel for d=2 plans (paper §6.4 deploys
                    length-2 solutions; this is the d=2 fast path)
  'pallas_fused'  — single fused kernel for ANY depth d ≥ 2: all packed
                    matmuls + relayouts in VMEM, zero HBM intermediates
  'auto'          — fused2 when d==2; fused chain when the whole chain is
                    VMEM-resident (core.packing.fused_chain_batch_tile /
                    chain_fits_vmem); pallas_step otherwise

A backend string may carry a tune-mode suffix, e.g. ``"auto:measure"`` —
the mode (off | cached | measure) is handed to the empirical autotuner
(kernels.autotune), which replaces analytical tile picks with measured,
JSON-persisted winners.  Default mode is 'cached' (no timing; dict lookup).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.packing import fused_chain_batch_tile, pack_core
from repro.core.tt import tt_apply
from . import autotune
from .tt_contract import (tt_fused2_pallas, tt_fused_chain_pallas,
                          tt_step_pallas)

BACKENDS = ("xla", "pallas_step", "pallas_fused2", "pallas_fused", "auto")


def chain_dims(cores: Sequence[jax.Array]
               ) -> tuple[tuple[int, ...], tuple[int, ...], tuple[int, ...]]:
    """(ns, ms, ranks) signature of a core list (the TTPlan triple)."""
    ns = tuple(int(G.shape[1]) for G in cores)
    ms = tuple(int(G.shape[2]) for G in cores)
    ranks = tuple(int(G.shape[0]) for G in cores) + (int(cores[-1].shape[3]),)
    return ns, ms, ranks


def _chain_with_step_kernel(cores: Sequence[jax.Array], x: jax.Array,
                            interpret: bool | None, tune: str) -> jax.Array:
    """Paper chain where each einsum runs in the blocked Pallas kernel.
    Layout between steps follows the paper exactly: reshapes only."""
    B = x.shape[0]
    state = x.reshape(-1)
    b = state.shape[0]
    for t in range(len(cores) - 1, -1, -1):
        G = cores[t]
        r0, nt, mt, r1 = G.shape
        if b % (nt * r1) != 0:
            raise ValueError(
                f"TT chain/input mismatch at step {t}: state of {b} "
                f"elements is not divisible by n_{t}·r_{t} = {nt}·{r1} "
                f"(core shape {tuple(G.shape)}) — the core list is "
                f"inconsistent with x.shape[-1] or the inter-core ranks")
        bt = b // (nt * r1)
        st = state.reshape(bt, nt, r1)
        plan = autotune.step_plan(mt, bt, nt, r1, r0, G.dtype,
                                  mode=tune, interpret=interpret)
        out = tt_step_pallas(G, st, plan, interpret=interpret)   # [m, b, r0]
        state = out.reshape(-1).astype(x.dtype)
        b = state.shape[0]
    M = b // B
    return state.reshape(M, B).T


def tt_forward(cores: Sequence[jax.Array], x: jax.Array,
               bias: jax.Array | None = None, backend: str = "auto",
               interpret: bool | None = None,
               tune: str | None = None) -> jax.Array:
    """Apply a TT layer to ``x [..., N]`` → ``[..., M]``.

    ``backend`` may embed the tune mode as ``"<backend>:<mode>"``; an
    explicit ``tune=`` argument wins over the suffix.
    """
    if ":" in backend:
        backend, suffix = backend.split(":", 1)
        tune = tune if tune is not None else suffix
    tune = tune or "cached"
    assert backend in BACKENDS, backend
    assert tune in autotune.TUNE_MODES, tune
    d = len(cores)
    ns, ms, ranks = chain_dims(cores)
    Nc = 1
    for n in ns:
        Nc *= n
    if Nc != x.shape[-1]:
        raise ValueError(
            f"TT core list with input modes {ns} (prod={Nc}) does not "
            f"match x.shape[-1]={x.shape[-1]}")
    for t in range(len(cores) - 1):
        if cores[t].shape[3] != cores[t + 1].shape[0]:
            raise ValueError(
                f"TT rank mismatch between cores {t} and {t + 1}: "
                f"r={cores[t].shape[3]} vs r={cores[t + 1].shape[0]}")

    lead, N = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, N)
    B = x2.shape[0]
    itemsize = max(x.dtype.itemsize, 4)

    if backend == "auto":
        if d == 2:
            backend = "pallas_fused2"
        elif d > 2 and fused_chain_batch_tile(ns, ms, ranks,
                                              itemsize=itemsize) is not None:
            backend = "pallas_fused"
        else:
            backend = "pallas_step"

    if backend == "xla":
        y = tt_apply(cores, x2)
    elif backend == "pallas_fused2":
        assert d == 2, "fused2 backend requires a length-2 plan"
        G1, G2 = cores
        _, n1, m1, r1 = G1.shape
        _, n2, m2, _ = G2.shape
        block_b = autotune.fused_tile(ns, ms, ranks, x.dtype, B,
                                      mode=tune, interpret=interpret)
        y = tt_fused2_pallas(
            x2, pack_core(G2), pack_core(G1),
            dims=(n1, n2, m1, m2, r1), block_b=block_b, interpret=interpret)
    elif backend == "pallas_fused":
        assert d >= 2, "fused chain backend requires d >= 2"
        block_b = autotune.fused_tile(ns, ms, ranks, x.dtype, B,
                                      mode=tune, interpret=interpret)
        assert block_b is not None, \
            "chain does not fit VMEM — use pallas_step (or backend='auto')"
        packed = [pack_core(G) for G in reversed(cores)]
        y = tt_fused_chain_pallas(x2, packed, (ns, ms, ranks),
                                  block_b=block_b, interpret=interpret)
    else:
        y = _chain_with_step_kernel(cores, x2, interpret, tune)

    if bias is not None:
        y = y + bias
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)
