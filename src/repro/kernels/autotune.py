"""Empirical block-plan autotuner for the TT Pallas kernels (DESIGN.md §2).

The paper picks block shapes with a purely analytical load/store model
(§4.3.4–4.3.5).  The model ranks candidates well but its constants are
guesses; this module closes the loop the way production autotuners do:

  1. enumerate a handful of candidates FROM the analytical model
     (``core.packing``: top-k ``select_blocks_candidates`` for the per-step
     kernel, the VMEM-fit tile ± one octave for the fused kernels),
  2. time each candidate on the device actually executing (interpret-mode
     timing on CPU containers — relative ranking is what transfers),
  3. persist the winner in a JSON cache keyed by
     (kernel kind, shape, ranks, dtype, jax backend)
     so every later call — including in other processes — is a dict lookup.

Tune modes (threaded through ``kernels.ops.tt_forward``):

  'off'      — analytical plan only, never read or write the cache
  'cached'   — use a persisted winner if present, else analytical (no
               timing; the default — safe inside jit traces and prod paths)
  'measure'  — time candidates on miss and persist the winner

The cache file defaults to ``~/.cache/repro/autotune.json`` and is
overridden by ``$REPRO_AUTOTUNE_CACHE`` or an explicit ``cache_path=``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.flops import prod
from repro.core.packing import (BlockPlan, fused_chain_batch_tile,
                                select_blocks_candidates)
from .tt_contract import (tt_fused2_pallas, tt_fused_chain_pallas,
                          tt_step_pallas)

TUNE_MODES = ("off", "cached", "measure")

# number of candidate timings actually executed (tests assert cache hits
# run zero of these)
N_MEASUREMENTS = 0


def _default_cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


@dataclasses.dataclass
class AutotuneCache:
    """JSON-file-backed plan cache with an in-memory mirror."""
    path: str
    entries: dict

    @classmethod
    def load(cls, path: str) -> "AutotuneCache":
        entries = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    entries = json.load(f)
            except (json.JSONDecodeError, OSError):
                entries = {}
        return cls(path, entries)

    def get(self, key: str) -> dict | None:
        return self.entries.get(key)

    def put(self, key: str, value: dict) -> None:
        self.entries[key] = value
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "w") as f:
            json.dump(self.entries, f, indent=1, sort_keys=True)


_CACHES: dict[str, AutotuneCache] = {}


def get_cache(cache_path: str | None = None) -> AutotuneCache:
    path = cache_path or _default_cache_path()
    if path not in _CACHES:
        _CACHES[path] = AutotuneCache.load(path)
    return _CACHES[path]


def clear_memory_caches() -> None:
    """Drop in-memory mirrors (tests use this to prove disk round-trips)."""
    _CACHES.clear()


def plan_key(kind: str, ns: Sequence[int], ms: Sequence[int],
             ranks: Sequence[int], dtype, B: int) -> str:
    return "|".join([
        kind,
        "n" + "x".join(map(str, ns)),
        "m" + "x".join(map(str, ms)),
        "r" + "x".join(map(str, ranks)),
        jnp.dtype(dtype).name,
        f"B{B}",
        jax.default_backend(),
    ])


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------

def _median_time(fn: Callable[[], jax.Array], warmup: int = 1,
                 iters: int = 3) -> float:
    global N_MEASUREMENTS
    N_MEASUREMENTS += 1
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _pow2_neighbors(v: int, B: int, lo: int = 8, hi: int = 1024) -> list[int]:
    """The analytical pick and two octaves below it, clipped to
    [lo, min(hi, B-ish)].  Never above ``v``: for the fused kernels ``v``
    is the LARGEST VMEM-feasible tile, so any larger candidate would win
    interpret-mode timing (no VMEM there) and persist a plan that busts
    VMEM on real hardware."""
    cap = min(hi, v, max(lo, 1 << (max(B - 1, 1)).bit_length()))
    cands = {max(lo, min(c, cap)) for c in (v // 4, v // 2, v)}
    return sorted(cands)


# ---------------------------------------------------------------------------
# Fused-kernel batch-tile tuning (d=2 and d>=3)
# ---------------------------------------------------------------------------

def fused_tile(ns: tuple[int, ...], ms: tuple[int, ...],
               ranks: tuple[int, ...], dtype, B: int,
               mode: str = "cached", interpret: bool | None = None,
               cache_path: str | None = None) -> int | None:
    """Batch tile for the fused chain (any d ≥ 2).  Returns None when the
    chain is not VMEM-resident at any tile (caller falls back to per-step).
    """
    assert mode in TUNE_MODES, mode
    itemsize = max(jnp.dtype(dtype).itemsize, 4)
    analytic = fused_chain_batch_tile(ns, ms, ranks, itemsize=itemsize)
    if analytic is None:
        return None
    if mode == "off":
        return analytic

    key = plan_key("fused_chain", ns, ms, ranks, dtype, B)
    cache = get_cache(cache_path)
    hit = cache.get(key)
    if hit is not None:
        return int(hit["block_b"])
    if mode == "cached":
        return analytic

    # mode == 'measure': time the analytic pick ± one octave
    d = len(ns)
    keys = jax.random.split(jax.random.PRNGKey(0), d + 1)
    x = jax.random.normal(keys[0], (B, prod(ns)), jnp.float32).astype(dtype)
    packed = [
        jax.random.normal(
            keys[1 + j], (ns[t] * ranks[t + 1], ms[t] * ranks[t]),
            jnp.float32).astype(dtype)
        for j, t in enumerate(range(d - 1, -1, -1))
    ]
    dims = (tuple(ns), tuple(ms), tuple(ranks))
    timed: dict[str, float] = {}
    for bb in _pow2_neighbors(analytic, B):
        if d == 2:
            n1, n2 = ns
            m1, m2 = ms
            fn = lambda bb=bb: tt_fused2_pallas(
                x, packed[0], packed[1], (n1, n2, m1, m2, ranks[1]),
                block_b=bb, interpret=interpret)
        else:
            fn = lambda bb=bb: tt_fused_chain_pallas(
                x, packed, dims, block_b=bb, interpret=interpret)
        timed[str(bb)] = _median_time(fn)
    best = int(min(timed, key=timed.get))
    cache.put(key, {"block_b": best, "time_s": timed[str(best)],
                    "source": "measured", "analytic_block_b": analytic,
                    "candidates": timed})
    return best


# ---------------------------------------------------------------------------
# Per-step BlockPlan tuning
# ---------------------------------------------------------------------------

def step_plan(mt: int, bt: int, nt: int, rt: int, rt_1: int, dtype,
              mode: str = "cached", interpret: bool | None = None,
              cache_path: str | None = None, k: int = 4) -> BlockPlan:
    """Blocked-step plan: analytical argmin, or the measured winner among
    the analytical top-k (the paper's §4.3.4 selection, but benchmarked)."""
    assert mode in TUNE_MODES, mode
    itemsize = max(jnp.dtype(dtype).itemsize, 4)
    cands = select_blocks_candidates(mt, bt, nt, rt, rt_1, itemsize, k=k)
    if mode == "off":
        return cands[0]

    key = plan_key("step", (nt,), (mt,), (rt_1, rt), dtype, bt)
    cache = get_cache(cache_path)
    hit = cache.get(key)
    if hit is not None:
        return BlockPlan(int(hit["bm"]), int(hit["bb"]), int(hit["bn"]),
                         int(hit.get("traffic_bytes", 0)),
                         int(hit.get("vmem_bytes", 0)))
    if mode == "cached" or len(cands) == 1:
        return cands[0]

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    G = jax.random.normal(k1, (rt_1, nt, mt, rt), jnp.float32).astype(dtype)
    X = jax.random.normal(k2, (bt, nt, rt), jnp.float32).astype(dtype)
    timed = [(_median_time(lambda p=p: tt_step_pallas(
        G, X, p, interpret=interpret)), p) for p in cands]
    t_best, best = min(timed, key=lambda tp: tp[0])
    cache.put(key, {"bm": best.bm, "bb": best.bb, "bn": best.bn,
                    "traffic_bytes": best.traffic_bytes,
                    "vmem_bytes": best.vmem_bytes,
                    "time_s": t_best, "source": "measured",
                    "candidates": {f"{p.bm}x{p.bb}x{p.bn}": t
                                   for t, p in timed}})
    return best
