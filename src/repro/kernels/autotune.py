"""Empirical block-plan autotuner for the TT Pallas kernels (DESIGN.md §2).

The paper picks block shapes with a purely analytical load/store model
(§4.3.4–4.3.5).  The model ranks candidates well but its constants are
guesses; this module closes the loop the way production autotuners do:

  1. enumerate a handful of candidates FROM the analytical model
     (``core.packing``: top-k ``select_blocks_candidates`` for the per-step
     kernel, the VMEM-fit tile ± one octave for the fused kernels),
  2. time each candidate on the device actually executing (interpret-mode
     timing on CPU containers — relative ranking is what transfers),
  3. persist the winner in a JSON cache keyed by
     (kernel kind, shape, ranks, dtype, weight dtype, jax backend)
     so every later call — including in other processes — is a dict lookup.

The weight dtype is part of the key because it changes both the feasible
set (int8-resident cores shrink the VMEM residency term 4×, DESIGN.md §8)
and the measured kernel (the ``*_int8_pallas`` variants are timed when
``weights='int8'``).  The cache file is written atomically (temp file +
``os.replace``) so concurrent benchmark runs never leave a truncated JSON.

Tune modes (threaded through ``kernels.ops.tt_forward``):

  'off'      — analytical plan only, never read or write the cache
  'cached'   — use a persisted winner if present, else analytical (no
               timing; the default — safe inside jit traces and prod paths)
  'measure'  — time candidates on miss and persist the winner

The cache file defaults to ``~/.cache/repro/autotune.json`` and is
overridden by ``$REPRO_AUTOTUNE_CACHE`` or an explicit ``cache_path=``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.flops import prod
from repro.core.packing import (BlockPlan, fused_chain_batch_tile,
                                select_blocks_candidates)
from .tt_contract import (KERNEL_VERSION, tt_fused2_int8_pallas,
                          tt_fused2_pallas, tt_fused_chain_int8_pallas,
                          tt_fused_chain_pallas, tt_step_int8_pallas,
                          tt_step_pallas)

TUNE_MODES = ("off", "cached", "measure")
WEIGHT_MODES = ("fp", "int8")       # resident dtype class of the cores

# Versioned cache schema, tied to the kernel generation: every entry is
# stamped ``"schema": CACHE_SCHEMA`` on write, and load() silently drops
# entries from other schemas (or malformed/unknown formats) — an old
# cache file survives a kernel migration instead of crashing it or, worse,
# serving tiles measured against different kernel semantics.
CACHE_SCHEMA = KERNEL_VERSION

# number of candidate timings actually executed (tests assert cache hits
# run zero of these)
N_MEASUREMENTS = 0


def _default_cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


@dataclasses.dataclass
class AutotuneCache:
    """JSON-file-backed plan cache with an in-memory mirror."""
    path: str
    entries: dict

    @classmethod
    def load(cls, path: str) -> "AutotuneCache":
        entries = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    raw = json.load(f)
            except (json.JSONDecodeError, OSError):
                raw = {}
            if isinstance(raw, dict):
                # keep only entries of THIS schema; stale generations and
                # unknown formats are ignored, never an error
                entries = {k: v for k, v in raw.items()
                           if isinstance(v, dict)
                           and v.get("schema") == CACHE_SCHEMA}
        return cls(path, entries)

    def get(self, key: str) -> dict | None:
        return self.entries.get(key)

    def put(self, key: str, value: dict) -> None:
        """Insert + persist.  The write is atomic (temp file in the same
        directory + ``os.replace``): a reader — or a concurrent benchmark
        process — can never observe a truncated ``autotune_cache.json``,
        only the old or the new complete file.  Every entry is stamped
        with the current ``CACHE_SCHEMA``."""
        self.entries[key] = dict(value, schema=CACHE_SCHEMA)
        dirname = os.path.dirname(self.path) or "."
        os.makedirs(dirname, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".tmp",
                                   prefix=os.path.basename(self.path) + ".")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.entries, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


_CACHES: dict[str, AutotuneCache] = {}


def get_cache(cache_path: str | None = None) -> AutotuneCache:
    path = cache_path or _default_cache_path()
    if path not in _CACHES:
        _CACHES[path] = AutotuneCache.load(path)
    return _CACHES[path]


def clear_memory_caches() -> None:
    """Drop in-memory mirrors (tests use this to prove disk round-trips)."""
    _CACHES.clear()


def plan_key(kind: str, ns: Sequence[int], ms: Sequence[int],
             ranks: Sequence[int], dtype, B: int,
             weights: str = "fp") -> str:
    return "|".join([
        kind,
        "n" + "x".join(map(str, ns)),
        "m" + "x".join(map(str, ms)),
        "r" + "x".join(map(str, ranks)),
        jnp.dtype(dtype).name,
        f"B{B}",
        f"w{weights}",
        jax.default_backend(),
    ])


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------

def _median_time(fn: Callable[[], jax.Array], warmup: int = 1,
                 iters: int = 3) -> float:
    global N_MEASUREMENTS
    N_MEASUREMENTS += 1
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _pow2_neighbors(v: int, B: int, lo: int = 8, hi: int = 1024) -> list[int]:
    """The analytical pick and two octaves below it, clipped to
    [lo, min(hi, B-ish)].  Never above ``v``: for the fused kernels ``v``
    is the LARGEST VMEM-feasible tile, so any larger candidate would win
    interpret-mode timing (no VMEM there) and persist a plan that busts
    VMEM on real hardware."""
    cap = min(hi, v, max(lo, 1 << (max(B - 1, 1)).bit_length()))
    cands = {max(lo, min(c, cap)) for c in (v // 4, v // 2, v)}
    return sorted(cands)


# ---------------------------------------------------------------------------
# Fused-kernel batch-tile tuning (d=2 and d>=3)
# ---------------------------------------------------------------------------

def _weight_itemsize(weights: str, weight_itemsize: int | None) -> int | None:
    if weights not in WEIGHT_MODES:
        raise ValueError(
            f"weights must be one of {WEIGHT_MODES}, got {weights!r}")
    return 1 if weights == "int8" else weight_itemsize


def _weight_tag(weights: str, w_item: int | None, itemsize: int) -> str:
    """Cache-key tag for the resident weight dtype.  fp cores whose
    itemsize differs from the activation itemsize (bf16 cores under fp32
    accumulation) get their byte width in the tag — a tile measured under
    2-byte residency must not be served to a 4-byte-core model with the
    same shape signature."""
    if weights == "int8":
        return "int8"
    eff = itemsize if w_item is None else w_item
    return "fp" if eff == itemsize else f"fp{eff}"


def _fp_weight_dtype(w_item: int | None, itemsize: int):
    """Stand-in core dtype for fp measure-mode timing, matched to the
    weight itemsize actually being ranked."""
    eff = itemsize if w_item is None else w_item
    return jnp.bfloat16 if eff == 2 else jnp.float32


def fused_tile(ns: tuple[int, ...], ms: tuple[int, ...],
               ranks: tuple[int, ...], dtype, B: int,
               mode: str = "cached", interpret: bool | None = None,
               cache_path: str | None = None,
               weights: str = "fp",
               weight_itemsize: int | None = None) -> int | None:
    """Batch tile for the fused chain (see :func:`fused_tile_ex`)."""
    return fused_tile_ex(ns, ms, ranks, dtype, B, mode=mode,
                         interpret=interpret, cache_path=cache_path,
                         weights=weights,
                         weight_itemsize=weight_itemsize)[0]


def fused_tile_ex(ns: tuple[int, ...], ms: tuple[int, ...],
                  ranks: tuple[int, ...], dtype, B: int,
                  mode: str = "cached", interpret: bool | None = None,
                  cache_path: str | None = None,
                  weights: str = "fp",
                  weight_itemsize: int | None = None
                  ) -> tuple[int | None, str]:
    """Batch tile for the fused chain (any d ≥ 2), plus its provenance
    ('analytic' | 'cached' | 'measured') — the plan resolver records the
    provenance in the ``TTExecutionPlan``.  The tile is None when the
    chain is not VMEM-resident at any tile (caller falls back to per-step).

    ``weights='int8'`` prices the resident cores at 1 byte/elem in the
    analytic fit AND times the ``*_int8_pallas`` kernels in measure mode —
    chains that are step-fallback in fp32 can come back fused here.
    ``weight_itemsize`` overrides the fp weight pricing (e.g. 2 for bf16
    cores under fp32 activations)."""
    if mode not in TUNE_MODES:
        raise ValueError(f"tune mode must be one of {TUNE_MODES}: {mode!r}")
    itemsize = max(jnp.dtype(dtype).itemsize, 4)
    w_item = _weight_itemsize(weights, weight_itemsize)
    analytic = fused_chain_batch_tile(ns, ms, ranks, itemsize=itemsize,
                                      weight_itemsize=w_item)
    if analytic is None:
        return None, "analytic"
    if mode == "off":
        return analytic, "analytic"

    key = plan_key("fused_chain", ns, ms, ranks, dtype, B,
                   _weight_tag(weights, w_item, itemsize))
    cache = get_cache(cache_path)
    hit = cache.get(key)
    if hit is not None:
        return int(hit["block_b"]), "cached"
    if mode == "cached":
        return analytic, "analytic"

    # mode == 'measure': time the analytic pick ± one octave
    d = len(ns)
    keys = jax.random.split(jax.random.PRNGKey(0), d + 1)
    x = jax.random.normal(keys[0], (B, prod(ns)), jnp.float32).astype(dtype)
    pshapes = [(ns[t] * ranks[t + 1], ms[t] * ranks[t])
               for t in range(d - 1, -1, -1)]
    if weights == "int8":
        packed = [jax.random.randint(keys[1 + j], shp, -127, 128, jnp.int8)
                  for j, shp in enumerate(pshapes)]
        scales = [jnp.asarray(1.0, jnp.float32)] * d
    else:
        wdtype = _fp_weight_dtype(w_item, itemsize)
        packed = [jax.random.normal(keys[1 + j], shp, jnp.float32
                                    ).astype(wdtype)
                  for j, shp in enumerate(pshapes)]
        scales = None
    dims = (tuple(ns), tuple(ms), tuple(ranks))
    timed: dict[str, float] = {}
    for bb in _pow2_neighbors(analytic, B):
        if d == 2:
            n1, n2 = ns
            m1, m2 = ms
            d2 = (n1, n2, m1, m2, ranks[1])
            if weights == "int8":
                fn = lambda bb=bb: tt_fused2_int8_pallas(
                    x, packed[0], packed[1], scales, d2,
                    block_b=bb, interpret=interpret)
            else:
                fn = lambda bb=bb: tt_fused2_pallas(
                    x, packed[0], packed[1], d2,
                    block_b=bb, interpret=interpret)
        elif weights == "int8":
            fn = lambda bb=bb: tt_fused_chain_int8_pallas(
                x, packed, scales, dims, block_b=bb, interpret=interpret)
        else:
            fn = lambda bb=bb: tt_fused_chain_pallas(
                x, packed, dims, block_b=bb, interpret=interpret)
        timed[str(bb)] = _median_time(fn)
    best = int(min(timed, key=timed.get))
    cache.put(key, {"block_b": best, "time_s": timed[str(best)],
                    "source": "measured", "analytic_block_b": analytic,
                    "weights": weights, "candidates": timed})
    return best, "measured"


# ---------------------------------------------------------------------------
# Per-step BlockPlan tuning
# ---------------------------------------------------------------------------

def step_plan(mt: int, bt: int, nt: int, rt: int, rt_1: int, dtype,
              mode: str = "cached", interpret: bool | None = None,
              cache_path: str | None = None, k: int = 4,
              weights: str = "fp",
              weight_itemsize: int | None = None) -> BlockPlan:
    """Blocked-step plan (see :func:`step_plan_ex`)."""
    return step_plan_ex(mt, bt, nt, rt, rt_1, dtype, mode=mode,
                        interpret=interpret, cache_path=cache_path, k=k,
                        weights=weights,
                        weight_itemsize=weight_itemsize)[0]


def step_plan_ex(mt: int, bt: int, nt: int, rt: int, rt_1: int, dtype,
                 mode: str = "cached", interpret: bool | None = None,
                 cache_path: str | None = None, k: int = 4,
                 weights: str = "fp",
                 weight_itemsize: int | None = None
                 ) -> tuple[BlockPlan, str]:
    """Blocked-step plan plus its provenance ('analytic' | 'cached' |
    'measured'): analytical argmin, or the measured winner among the
    analytical top-k (the paper's §4.3.4 selection, but benchmarked).
    ``weights='int8'`` prices the G tile at 1 byte/elem and times the
    int8 step kernel."""
    if mode not in TUNE_MODES:
        raise ValueError(f"tune mode must be one of {TUNE_MODES}: {mode!r}")
    itemsize = max(jnp.dtype(dtype).itemsize, 4)
    w_item = _weight_itemsize(weights, weight_itemsize)
    cands = select_blocks_candidates(mt, bt, nt, rt, rt_1, itemsize, k=k,
                                     weight_itemsize=w_item)
    if mode == "off":
        return cands[0], "analytic"

    key = plan_key("step", (nt,), (mt,), (rt_1, rt), dtype, bt,
                   _weight_tag(weights, w_item, itemsize))
    cache = get_cache(cache_path)
    hit = cache.get(key)
    if hit is not None:
        return BlockPlan(int(hit["bm"]), int(hit["bb"]), int(hit["bn"]),
                         int(hit.get("traffic_bytes", 0)),
                         int(hit.get("vmem_bytes", 0))), "cached"
    if mode == "cached" or len(cands) == 1:
        return cands[0], "analytic"

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    X = jax.random.normal(k2, (bt, nt, rt), jnp.float32).astype(dtype)
    if weights == "int8":
        G = jax.random.randint(k1, (rt_1, nt, mt, rt), -127, 128, jnp.int8)
        one = jnp.asarray(1.0, jnp.float32)
        timed = [(_median_time(lambda p=p: tt_step_int8_pallas(
            G, one, X, p, interpret=interpret)), p) for p in cands]
    else:
        G = jax.random.normal(k1, (rt_1, nt, mt, rt), jnp.float32
                              ).astype(_fp_weight_dtype(w_item, itemsize))
        timed = [(_median_time(lambda p=p: tt_step_pallas(
            G, X, p, interpret=interpret)), p) for p in cands]
    t_best, best = min(timed, key=lambda tp: tp[0])
    cache.put(key, {"bm": best.bm, "bb": best.bb, "bn": best.bn,
                    "traffic_bytes": best.traffic_bytes,
                    "vmem_bytes": best.vmem_bytes,
                    "time_s": t_best, "source": "measured",
                    "weights": weights,
                    "candidates": {f"{p.bm}x{p.bb}x{p.bn}": t
                                   for t, p in timed}})
    return best, "measured"
