"""Plan-compile-execute pipeline for TT layer dispatch (DESIGN.md §10).

The paper's deployment story is ahead-of-time: prune the TTD design space,
pick a decomposition, apply the compiler optimizations once per layer,
then ship the compiled artifact.  This module is that split made explicit
for the kernel stack: every dispatch decision that used to be re-derived
at trace time from a ``"<backend>:<tune>:<weights>"`` string — backend
routing, the VMEM fit verdict, fused-chain eligibility, block/tile
selection, autotune cache lookups — is resolved ONCE into a frozen,
serializable :class:`TTExecutionPlan`, and every layer of the stack
(``kernels.ops.tt_forward``, ``models/layers.linear_apply``, the DSE's
measured rerank, the serving scheduler) consumes the plan instead of
re-deciding.

Three levels of API, outermost first:

``PlanBook``
    Per-model plan registry.  Built once at model-build time from the
    model's ``TTConfig`` + param dtype; ``prime()`` walks the param-spec
    tree and resolves a plan for every TT layer, so scanned stacks and the
    serving scheduler never plan inside a trace.  ``plan_for_cores`` is
    the trace-time lookup (a dict hit on the chain signature).

``resolve_plan``
    Process-wide memoized resolver — same inputs always return the same
    plan object.  ``clear_plan_memo()`` drops the memo (tests).

``plan_tt_forward``
    The actual resolver: subsumes the old ``parse_backend_spec`` + auto
    routing + ``select_blocks``/``chain_fits_vmem`` + autotune-cache
    lookup.  Every call increments ``PLAN_RESOLUTIONS`` so tests and the
    CI smoke can assert that serving performs ZERO re-planning.

Legacy ``"<backend>[:<tune>][:<weights>]"`` strings keep working through
``compile_spec`` (a deprecation shim): the string is parsed once and
compiled into a plan; new code passes explicit fields or a plan object.

Whole plans are persisted in the versioned autotune JSON cache
(``schema`` = :data:`PLAN_SCHEMA`, kind ``plan.<requested-backend>``) in
measure mode, so a deployment's resolved plans survive process restarts
exactly like measured tiles do.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import jax.numpy as jnp

from repro.core import hw
from repro.core.flops import prod
from repro.core.packing import BlockPlan, chain_fit_report
from . import autotune

# Bumped together with autotune.CACHE_SCHEMA / tt_contract.KERNEL_VERSION:
# a serialized plan is only valid for the kernel generation it was
# resolved against.
PLAN_SCHEMA = autotune.CACHE_SCHEMA

# Nominal batch the model stack plans at.  The kernels clamp every tile to
# the runtime extent (min(tile, dim) + padding), so one build-time plan
# serves prefill (large token batches) and decode (tiny ones) without
# re-resolution; 128 rows is one full MXU face, the natural anchor.
PLANNING_BATCH = 128

BACKENDS = ("xla", "pallas_step", "pallas_fused2", "pallas_fused", "auto")

# accepted weight-mode tokens ('fp32'/'float32' are aliases kept for
# TTConfig readability; canonical modes are autotune.WEIGHT_MODES)
WEIGHT_ALIASES = {"fp": "fp", "fp32": "fp", "float32": "fp", "int8": "int8"}

# number of plan resolutions actually executed (memo/PlanBook hits do not
# count).  Serving tests assert this stays flat across a decode run.
PLAN_RESOLUTIONS = 0


def plan_resolutions() -> int:
    return PLAN_RESOLUTIONS


def _token_help() -> str:
    """All valid spec tokens, in one place (satellite: malformed specs
    must name every accepted token class)."""
    return (f"backends {BACKENDS}, tune modes {autotune.TUNE_MODES}, "
            f"weight modes {tuple(WEIGHT_ALIASES)}")


def normalize_weights(weights: str | None) -> str | None:
    if weights is None:
        return None
    if weights not in WEIGHT_ALIASES:
        raise ValueError(
            f"unknown weight mode {weights!r}: expected one of "
            f"{tuple(WEIGHT_ALIASES)}")
    return WEIGHT_ALIASES[weights]


def compile_spec(backend: str, tune: str | None = None,
                 weights: str | None = None, *, warn: bool = False
                 ) -> tuple[str, str | None, str | None]:
    """DEPRECATION SHIM: split ``"<backend>[:<tune>][:<weights>]"`` into
    its (backend, tune, weights) parts, rejecting malformed specs.

    Suffix tokens are classified by membership (tune modes vs weight
    modes) so the order is free; explicit ``tune=``/``weights=`` arguments
    always win over suffix tokens.  Empty tokens (``"xla::int8"``, a
    trailing ``":"``, a leading ``":"``) are rejected outright.  New code
    should pass explicit fields to ``plan_tt_forward`` / ``resolve_plan``
    or hand a :class:`TTExecutionPlan` to ``tt_forward`` directly.
    """
    weights = normalize_weights(weights)
    if ":" in backend:
        if warn:
            warnings.warn(
                "string backend specs ('<backend>:<tune>:<weights>') are "
                "deprecated — resolve a TTExecutionPlan (kernels.plan) and "
                "pass plan=... instead", DeprecationWarning, stacklevel=3)
        backend, *suffix = backend.split(":")
        if not backend or any(not tok for tok in suffix):
            raise ValueError(
                f"malformed backend spec with empty token(s): expected "
                f"'<backend>[:<tune>][:<weights>]' built from "
                f"{_token_help()}")
        suffix_tune = suffix_weights = None
        for tok in suffix:
            if tok in autotune.TUNE_MODES:
                if suffix_tune is not None:
                    raise ValueError(
                        f"conflicting tune-mode suffixes "
                        f"{suffix_tune!r} and {tok!r} in backend spec")
                suffix_tune = tok
            elif tok in WEIGHT_ALIASES:
                if suffix_weights is not None:
                    raise ValueError(
                        f"conflicting weight-mode suffixes "
                        f"{suffix_weights!r} and {tok!r} in backend spec")
                suffix_weights = WEIGHT_ALIASES[tok]
            else:
                raise ValueError(
                    f"unknown backend suffix {tok!r}: valid tokens are "
                    f"{_token_help()}")
        tune = tune if tune is not None else suffix_tune
        weights = weights if weights is not None else suffix_weights
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}: valid tokens are {_token_help()}")
    return backend, tune, weights


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TTExecutionPlan:
    """Fully resolved execution recipe for one TT chain.

    Frozen and hashable (usable as a jit static argument / memo key);
    equality is field-wise, so 'same inputs → identical plan' is a simple
    ``==``.  ``backend`` is always CONCRETE — ``auto`` is resolved away at
    planning time and only survives in ``requested``.
    """
    ns: tuple[int, ...]            # input factors (core order, t = 1..d)
    ms: tuple[int, ...]            # output factors
    ranks: tuple[int, ...]         # r_0 .. r_d
    requested: str                 # what the caller asked for (may be 'auto')
    backend: str                   # resolved concrete backend
    weights: str                   # 'fp' | 'int8' (resident core dtype class)
    tune: str                      # autotune mode the plan was resolved under
    dtype: str                     # activation dtype name
    batch: int                     # planning batch (tiles clamp at runtime)
    weight_itemsize: int           # resident bytes/elem of the packed cores
    fused_eligible: bool           # whole-chain VMEM fit verdict (d >= 2)
    fit_weight_bytes: int          # packed-core residency the verdict priced
    fit_peak_state_bytes: int      # peak per-row state pair the verdict priced
    block_b: int | None = None     # fused-path batch tile
    step_plans: tuple[BlockPlan, ...] | None = None  # per-step (exec order)
    source: str = "analytic"       # 'analytic' | 'cached' | 'measured'

    @property
    def d(self) -> int:
        return len(self.ns)

    @property
    def N(self) -> int:
        return prod(self.ns)

    @property
    def M(self) -> int:
        return prod(self.ms)

    def describe(self) -> str:
        tile = (f"block_b={self.block_b}" if self.block_b is not None else
                f"steps={len(self.step_plans or ())}")
        return (f"TTExecutionPlan[{self.requested}->{self.backend} "
                f"d={self.d} n={'x'.join(map(str, self.ns))} "
                f"m={'x'.join(map(str, self.ms))} w={self.weights} "
                f"{tile} fused_ok={self.fused_eligible} src={self.source}]")

    # ------------------------------------------------------------- JSON
    def to_json_dict(self) -> dict:
        return {
            "schema": PLAN_SCHEMA,
            "ns": list(self.ns), "ms": list(self.ms),
            "ranks": list(self.ranks),
            "requested": self.requested, "backend": self.backend,
            "weights": self.weights, "tune": self.tune,
            "dtype": self.dtype, "batch": self.batch,
            "weight_itemsize": self.weight_itemsize,
            "fused_eligible": self.fused_eligible,
            "fit_weight_bytes": self.fit_weight_bytes,
            "fit_peak_state_bytes": self.fit_peak_state_bytes,
            "block_b": self.block_b,
            "step_plans": None if self.step_plans is None else [
                [p.bm, p.bb, p.bn, p.traffic_bytes, p.vmem_bytes]
                for p in self.step_plans],
            "source": self.source,
        }

    @classmethod
    def from_json_dict(cls, obj: dict) -> "TTExecutionPlan":
        if not isinstance(obj, dict) or obj.get("schema") != PLAN_SCHEMA:
            raise ValueError(
                f"unsupported plan schema {obj.get('schema') if isinstance(obj, dict) else obj!r}"
                f" (this build reads schema {PLAN_SCHEMA})")
        sp = obj["step_plans"]
        return cls(
            ns=tuple(obj["ns"]), ms=tuple(obj["ms"]),
            ranks=tuple(obj["ranks"]),
            requested=obj["requested"], backend=obj["backend"],
            weights=obj["weights"], tune=obj["tune"],
            dtype=obj["dtype"], batch=int(obj["batch"]),
            weight_itemsize=int(obj["weight_itemsize"]),
            fused_eligible=bool(obj["fused_eligible"]),
            fit_weight_bytes=int(obj["fit_weight_bytes"]),
            fit_peak_state_bytes=int(obj["fit_peak_state_bytes"]),
            block_b=None if obj["block_b"] is None else int(obj["block_b"]),
            step_plans=None if sp is None else tuple(
                BlockPlan(int(a), int(b), int(c), int(t), int(v))
                for a, b, c, t, v in sp),
            source=obj["source"],
        )


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def _validate_chain(ns, ms, ranks) -> None:
    d = len(ns)
    if d < 1 or len(ms) != d or len(ranks) != d + 1:
        raise ValueError(
            f"inconsistent chain signature: ns={ns} ms={ms} ranks={ranks}")


def plan_tt_forward(ns: Sequence[int], ms: Sequence[int],
                    ranks: Sequence[int], *,
                    batch: int = PLANNING_BATCH, dtype=jnp.float32,
                    backend: str = "auto", tune: str | None = None,
                    weights: str | None = None,
                    weight_itemsize: int | None = None,
                    interpret: bool | None = None,
                    vmem_budget: int | None = None,
                    cache_path: str | None = None) -> TTExecutionPlan:
    """Resolve ONE execution plan for the chain ``(ns, ms, ranks)``.

    Subsumes the old string-spec round-trip: backend routing (including
    ``auto``), the dtype-aware VMEM fit verdict, fused-chain eligibility,
    fused batch-tile / per-step block-plan selection, and the autotune
    cache consultation all happen here, once.  ``tune='measure'``
    additionally persists the WHOLE resolved plan in the versioned
    autotune cache, so a later ``tune='cached'`` resolution of the same
    signature deserializes it without touching the analytic model.

    ``vmem_budget`` overrides the hardware VMEM budget (tests); a
    non-default budget skips the autotuner and resolves purely
    analytically, since measured tiles are only valid for the real budget.
    """
    global PLAN_RESOLUTIONS
    ns, ms, ranks = tuple(map(int, ns)), tuple(map(int, ms)), \
        tuple(map(int, ranks))
    _validate_chain(ns, ms, ranks)
    requested, tune, weights = compile_spec(backend, tune, weights)
    tune = tune or "cached"
    if tune not in autotune.TUNE_MODES:
        raise ValueError(
            f"unknown tune mode {tune!r}: valid tokens are {_token_help()}")
    weights = weights or "fp"
    d = len(ns)
    dtype_name = jnp.dtype(dtype).name
    itemsize = max(jnp.dtype(dtype).itemsize, 4)
    w_item = 1 if weights == "int8" else (weight_itemsize or itemsize)
    budget = hw.VMEM_BUDGET_BYTES if vmem_budget is None else vmem_budget
    custom_budget = budget != hw.VMEM_BUDGET_BYTES
    wtag = autotune._weight_tag(weights, w_item, itemsize)

    # whole-plan cache: a measure-mode run persists its resolution; later
    # cached-mode resolutions of the same signature deserialize it.
    use_plan_cache = tune in ("cached", "measure") and not custom_budget
    pkey = autotune.plan_key(f"plan.{requested}", ns, ms, ranks, dtype,
                             batch, wtag)
    if use_plan_cache:
        hit = autotune.get_cache(cache_path).get(pkey)
        if hit is not None and hit.get("kind") == "plan":
            try:
                plan = TTExecutionPlan.from_json_dict(hit["plan"])
            except (ValueError, KeyError, TypeError):
                plan = None          # stale/unknown entry: ignore, re-resolve
            if plan is not None:
                PLAN_RESOLUTIONS += 1
                return plan

    fit = chain_fit_report(ns, ms, ranks, itemsize=itemsize,
                           vmem_budget=budget, weight_itemsize=w_item)
    fused_ok = d >= 2 and fit.fits

    resolved = requested
    if requested == "auto":
        if d < 2:
            resolved = "xla"          # a single core is a plain matmul
        elif d == 2:
            resolved = "pallas_fused2"
        elif fused_ok:
            resolved = "pallas_fused"
        else:
            resolved = "pallas_step"
    elif requested == "pallas_fused2" and d != 2:
        raise ValueError(
            f"fused2 backend requires a length-2 plan, got d={d}")
    elif requested == "pallas_fused":
        if d < 2:
            raise ValueError(
                f"fused chain backend requires d >= 2, got d={d}")
        if not fused_ok:
            raise ValueError(
                "chain does not fit VMEM — use pallas_step (or "
                "backend='auto')")

    block_b: int | None = None
    step_plans: tuple[BlockPlan, ...] | None = None
    source = "analytic"
    if resolved in ("pallas_fused2", "pallas_fused"):
        if custom_budget:
            block_b = fit.batch_tile
        else:
            block_b, source = autotune.fused_tile_ex(
                ns, ms, ranks, dtype, batch, mode=tune, interpret=interpret,
                cache_path=cache_path, weights=weights,
                weight_itemsize=weight_itemsize)
        # fused2 tolerates block_b=None (the kernel falls back to its own
        # d=2 analytic tile); the general chain must be VMEM-resident
        if resolved == "pallas_fused" and block_b is None:
            raise ValueError(
                "chain does not fit VMEM at any batch tile — use "
                "pallas_step (or backend='auto')")
    elif resolved == "pallas_step":
        plans, srcs = [], []
        b = batch * prod(ns)
        for t in range(d - 1, -1, -1):
            nt, mt = ns[t], ms[t]
            rt, rt_1 = ranks[t + 1], ranks[t]
            bt = max(b // (nt * rt), 1)
            sp, src = autotune.step_plan_ex(
                mt, bt, nt, rt, rt_1, dtype, mode=tune, interpret=interpret,
                cache_path=cache_path, weights=weights,
                weight_itemsize=weight_itemsize)
            plans.append(sp)
            srcs.append(src)
            b = mt * bt * rt_1
        step_plans = tuple(plans)
        for lvl in ("measured", "cached"):
            if lvl in srcs:
                source = lvl
                break

    plan = TTExecutionPlan(
        ns=ns, ms=ms, ranks=ranks, requested=requested, backend=resolved,
        weights=weights, tune=tune, dtype=dtype_name, batch=batch,
        weight_itemsize=w_item, fused_eligible=fused_ok,
        fit_weight_bytes=fit.weight_bytes,
        fit_peak_state_bytes=fit.peak_state_bytes,
        block_b=block_b, step_plans=step_plans, source=source)
    PLAN_RESOLUTIONS += 1
    if use_plan_cache and tune == "measure":
        autotune.get_cache(cache_path).put(
            pkey, {"kind": "plan", "plan": plan.to_json_dict()})
    return plan


# ---------------------------------------------------------------------------
# Process-wide memoized resolution
# ---------------------------------------------------------------------------

_PLAN_MEMO: dict = {}


def resolve_plan(ns, ms, ranks, *, batch: int = PLANNING_BATCH,
                 dtype=jnp.float32, backend: str = "auto",
                 tune: str | None = None, weights: str | None = None,
                 weight_itemsize: int | None = None,
                 interpret: bool | None = None,
                 cache_path: str | None = None) -> TTExecutionPlan:
    """Memoized :func:`plan_tt_forward`: the same planning inputs return
    the same plan object without re-resolution (and without incrementing
    ``PLAN_RESOLUTIONS``)."""
    key = (tuple(ns), tuple(ms), tuple(ranks), batch,
           jnp.dtype(dtype).name, backend, tune, weights, weight_itemsize,
           interpret, cache_path or autotune._default_cache_path())
    plan = _PLAN_MEMO.get(key)
    if plan is None:
        plan = plan_tt_forward(
            ns, ms, ranks, batch=batch, dtype=dtype, backend=backend,
            tune=tune, weights=weights, weight_itemsize=weight_itemsize,
            interpret=interpret, cache_path=cache_path)
        _PLAN_MEMO[key] = plan
    return plan


def clear_plan_memo() -> None:
    """Drop the process-wide plan memo (tests that monkeypatch the fit
    model or the autotune cache must clear it)."""
    _PLAN_MEMO.clear()


# ---------------------------------------------------------------------------
# Per-model plan registry
# ---------------------------------------------------------------------------

def chain_signature(core_shapes: Sequence[Sequence[int]]
                    ) -> tuple[tuple[int, ...], tuple[int, ...],
                               tuple[int, ...]]:
    """(ns, ms, ranks) of a core list given per-core shapes.  Only the
    trailing 4 dims are read, so stacked specs (scan layers, MoE experts)
    resolve to the per-layer chain they execute as."""
    quads = [tuple(int(v) for v in s[-4:]) for s in core_shapes]
    ns = tuple(q[1] for q in quads)
    ms = tuple(q[2] for q in quads)
    ranks = tuple(q[0] for q in quads) + (quads[-1][3],)
    return ns, ms, ranks


class PlanBook:
    """Build-time plan registry for one model.

    One PlanBook per Model: construction fixes the policy (requested
    backend, tune mode, configured weight mode, planning batch);
    ``prime()`` resolves every TT layer's plan from the param-spec tree so
    no plan is ever resolved inside a jit trace; ``plan_for_cores`` is the
    trace-time lookup the layer stack calls — a dict hit on the chain
    signature (per layer, per weight dtype), falling back to one memoized
    resolution for signatures that appear only at runtime (e.g. an int8
    twin after ``Model.quantize_params``).

    The object is deliberately opaque to jax: it threads through the model
    stack as a static python value (closure-captured by scan/vmap bodies),
    replacing the stringly-typed ``cfg.tt.backend_spec``.
    """

    def __init__(self, backend: str = "auto", tune: str = "cached",
                 weights: str = "fp", batch: int = PLANNING_BATCH,
                 weight_itemsize: int | None = None,
                 interpret: bool | None = None,
                 cache_path: str | None = None):
        self.backend, self.tune, cfg_weights = compile_spec(
            backend, tune, weights)
        self.weights = cfg_weights or "fp"
        self.batch = batch
        self.weight_itemsize = weight_itemsize
        self.interpret = interpret
        self.cache_path = cache_path
        self._plans: dict = {}

    @classmethod
    def from_tt_config(cls, tt, param_dtype=jnp.float32,
                       batch: int | None = None) -> "PlanBook":
        """Policy from a ``configs.base.TTConfig`` + the model's param
        dtype (which prices fp core residency: bf16 params plan at
        2 B/elem)."""
        backend, tune, weights = tt.plan_policy
        return cls(backend=backend, tune=tune, weights=weights,
                   batch=batch or PLANNING_BATCH,
                   weight_itemsize=jnp.dtype(param_dtype).itemsize)

    def __len__(self) -> int:
        return len(self._plans)

    @property
    def plans(self) -> dict:
        return dict(self._plans)

    def plan_for(self, ns, ms, ranks, *, weights: str | None = None,
                 weight_itemsize: int | None = None,
                 dtype=jnp.float32) -> TTExecutionPlan:
        weights = normalize_weights(weights) or self.weights
        w_item = (1 if weights == "int8"
                  else (weight_itemsize or self.weight_itemsize))
        key = (tuple(ns), tuple(ms), tuple(ranks), weights, w_item,
               jnp.dtype(dtype).name)
        plan = self._plans.get(key)
        if plan is None:
            plan = resolve_plan(
                ns, ms, ranks, batch=self.batch, dtype=dtype,
                backend=self.backend, tune=self.tune, weights=weights,
                weight_itemsize=w_item, interpret=self.interpret,
                cache_path=self.cache_path)
            self._plans[key] = plan
        return plan

    def plan_for_cores(self, cores) -> TTExecutionPlan:
        """Trace-time lookup for a concrete core list (jax arrays or
        tracers — only shapes/dtypes are read).  int8-stored cores force
        the int8 plan regardless of the configured mode."""
        ns, ms, ranks = chain_signature([c.shape for c in cores])
        if cores[0].dtype == jnp.int8:
            weights, w_item = "int8", 1
        else:
            weights = self.weights
            w_item = (1 if weights == "int8"
                      else jnp.dtype(cores[0].dtype).itemsize)
        return self.plan_for(ns, ms, ranks, weights=weights,
                             weight_itemsize=w_item)

    def prime(self, spec_tree) -> int:
        """Resolve a plan for every TT bundle in a param-spec tree
        (models/spec.ParamSpec leaves).  Returns the number of distinct
        plans resolved.  Called at model build; after this, serving
        performs zero plan resolutions."""
        before = len(self._plans)

        def walk(node):
            if not isinstance(node, dict):
                return
            for k, v in node.items():
                if k == "tt" and isinstance(v, dict):
                    d = sum(1 for kk in v if kk.startswith("c"))
                    specs = [v[f"c{t}"] for t in range(d)]
                    ns, ms, ranks = chain_signature(
                        [s.shape for s in specs])
                    w_item = jnp.dtype(specs[0].dtype).itemsize
                    self.plan_for(ns, ms, ranks,
                                  weight_itemsize=w_item)
                elif isinstance(v, dict):
                    walk(v)

        walk(spec_tree)
        return len(self._plans) - before
