"""Pallas TPU kernels for the TT einsum chain.

Three kernels (DESIGN.md §2 maps them onto the paper's §4.3 pipeline):

``tt_step_kernel``   — one einsum step ``out[m,b,r0] = Σ_{n,r1} G·X`` with
   explicit (bm, bb, bn) VMEM tiling chosen by the analytical model in
   ``core.packing.select_blocks`` (the paper's register-blocking / cache-
   tiling transfer).  Grid = (m-tiles, b-tiles, n-tiles), n innermost with
   fp32 accumulation in the revisited output block.

``tt_fused2_kernel`` — the whole d=2 chain fused: two MXU matmuls over
   *packed* cores with the inter-step relayout done in VMEM, zero HBM
   intermediates and zero HBM transposes.  This is the TPU-native answer to
   the paper's IREE critique: IREE's transpose-to-matmul layers live in HBM;
   ours live in vector registers.

``tt_fused_chain_kernel`` — the d≥2 generalization: ONE ``pallas_call``
   over a batch-tiled grid runs all d packed-core MXU matmuls with every
   inter-step relayout in VMEM.  Eligibility is decided by the fused-chain
   VMEM-fit test (``core.packing.fused_chain_batch_tile``, the paper's
   Eq. 26–28 analogue); chains that do not fit fall back to the per-step
   kernel, which round-trips intermediates through HBM.

Each kernel has an **int8-resident variant** (``*_int8_pallas``, DESIGN.md
§8): the packed cores arrive as int8 and STAY int8 in VMEM — residency is
1 byte/elem, so the fit test admits chains whose fp32 weights alone bust
the VMEM budget.  Per-core fp32 scales ride in SMEM ([d, 1] block);
dequantization happens inside the kernel body: the int8 block is widened
to fp32 feeding the MXU and the symmetric per-core scale is folded into
the matmul epilogue (``(s·Q)·x == s·(Q·x)``, exact — the scale multiplies
the [bb, m·r] output instead of materializing an fp32 copy of the core).
Accumulation is fp32 throughout.  Each fp/int8 pair shares ONE jitted call
(the padding / grid / BlockSpec scaffolding): the int8 trace only appends
the SMEM scale operand and swaps the body, so a fix to the tiling logic
can never reach one variant and miss the other.

Every public entry increments a module-level launch counter
(``LAUNCH_COUNTS``) so benchmarks/tests can assert how many ``pallas_call``
launches a given forward issues (fused d-chain ⇒ exactly one).

Kernels are written for TPU (BlockSpec/VMEM semantics) and validated on CPU
in interpret mode.
"""
from __future__ import annotations

import collections
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import (BlockPlan, fused2_batch_tile,
                                fused_chain_batch_tile)

# Kernel-generation version: bumped whenever tiling semantics, packed
# layouts or the BlockPlan contract change incompatibly.  The autotune
# cache schema (autotune.CACHE_SCHEMA) and serialized execution plans
# (plan.PLAN_SCHEMA) are stamped with it, so persisted tiles/plans from an
# older kernel generation are silently ignored rather than mis-executed.
KERNEL_VERSION = 2

# pallas_call launches per kernel kind, counted at the (non-jitted) wrapper
# level so cached-trace executions are counted too.
LAUNCH_COUNTS: collections.Counter = collections.Counter()


def reset_launch_counts() -> None:
    LAUNCH_COUNTS.clear()


def launch_counts() -> dict[str, int]:
    return dict(LAUNCH_COUNTS)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _scales_smem(scales, d: int) -> jax.Array:
    """Per-core scales (execution order) → ``[d, 1]`` fp32 array for the
    SMEM block the int8 kernel bodies index as ``s_ref[j, 0]``."""
    s = jnp.asarray(scales, jnp.float32).reshape(-1)
    if s.shape[0] != d:
        raise ValueError(
            f"expected {d} per-core scales, got {s.shape[0]}")
    return s.reshape(d, 1)


def _require_int8(arrays, what: str) -> None:
    for a in arrays:
        if a.dtype != jnp.int8:
            raise ValueError(
                f"{what} must be int8 (got {a.dtype}) — quantize with "
                f"core.quant.pack_core_int8 / quantize_cores")


# ---------------------------------------------------------------------------
# Kernel 1: single einsum step, blocked + accumulated
# ---------------------------------------------------------------------------

def _tt_step_body(g_ref, x_ref, o_ref):
    """out[m,b,r0] += einsum over the (n, r1) block."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.einsum(
        "rnmk,bnk->mbr", g_ref[...], x_ref[...],
        preferred_element_type=jnp.float32)
    o_ref[...] += part


def _tt_step_int8_body(g_ref, x_ref, s_ref, o_ref):
    """int8 step: G block stays int8 in VMEM; dequant = widen + epilogue
    scale from SMEM; fp32 accumulation in the revisited output block."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.einsum(
        "rnmk,bnk->mbr", g_ref[...].astype(jnp.float32),
        x_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)
    o_ref[...] += part * s_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def _tt_step_call(G: jax.Array, X: jax.Array, plan: BlockPlan,
                  interpret: bool, scale: jax.Array | None = None
                  ) -> jax.Array:
    """Shared fp/int8 scaffolding: padding, grid, BlockSpecs.  ``scale``
    (a [1, 1] fp32 array) selects the int8 body and appends its SMEM
    operand; the tiling logic is single-sourced for both variants."""
    r0, n, m, r1 = G.shape
    b = X.shape[0]
    bm, bb, bn = min(plan.bm, m), min(plan.bb, b), min(plan.bn, n)

    def pad_to(a, axis, mult):
        pad = (-a.shape[axis]) % mult
        if pad == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        return jnp.pad(a, widths)

    Gp = pad_to(pad_to(G, 1, bn), 2, bm)
    Xp = pad_to(pad_to(X, 0, bb), 1, bn)
    mp, np_, bp = Gp.shape[2], Gp.shape[1], Xp.shape[0]
    grid = (mp // bm, bp // bb, np_ // bn)

    in_specs = [
        pl.BlockSpec((r0, bn, bm, r1), lambda i, j, k: (0, k, i, 0)),
        pl.BlockSpec((bb, bn, r1), lambda i, j, k: (j, k, 0)),
    ]
    args = (Gp, Xp)
    if scale is None:
        body = _tt_step_body
    else:
        body = _tt_step_int8_body
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j, k: (0, 0),
                                     memory_space=pltpu.SMEM))
        args += (scale,)

    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bb, r0), lambda i, j, k: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, bp, r0), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:m, :b, :]


def tt_step_pallas(G: jax.Array, X: jax.Array, plan: BlockPlan,
                   interpret: bool | None = None) -> jax.Array:
    """``G [r0, n, m, r1]``, ``X [b, n, r1]`` → ``out [m, b, r0]`` (fp32).

    Inputs are zero-padded to block multiples (padding on n contributes 0 to
    the accumulation; padding on m/b is sliced off), so block shapes never
    have to divide the problem — the paper's "padding ukernel" (§4.3.4)
    replaced by masked tiles.
    """
    if interpret is None:
        interpret = _interpret_default()
    LAUNCH_COUNTS["step"] += 1
    return _tt_step_call(G, X, plan, interpret)


def tt_step_int8_pallas(G: jax.Array, scale, X: jax.Array, plan: BlockPlan,
                        interpret: bool | None = None) -> jax.Array:
    """int8 variant of ``tt_step_pallas``: ``G [r0, n, m, r1]`` **int8**
    with one symmetric fp32 ``scale``, ``X [b, n, r1]`` → ``out [m, b, r0]``
    (fp32).  G tiles are int8-resident in VMEM (4× the fp32 residency
    headroom in ``select_blocks``'s fit term); dequantization is the widen
    + epilogue scale inside the kernel body."""
    if interpret is None:
        interpret = _interpret_default()
    _require_int8([G], "step core G")
    LAUNCH_COUNTS["step_int8"] += 1
    return _tt_step_call(G, X, plan, interpret,
                         scale=_scales_smem([scale], 1))


# ---------------------------------------------------------------------------
# Kernel 2: fused d=2 chain
# ---------------------------------------------------------------------------

def _fused2_body(x_ref, p2_ref, p1_ref, o_ref, *, n1, n2, m1, m2, r1):
    bb = x_ref.shape[0]
    f32 = jnp.float32
    x = x_ref[...].astype(f32)
    # MXU matmul 1:  [bb·n1, n2] @ [n2, m2·r1]
    a = jnp.dot(x.reshape(bb * n1, n2), p2_ref[...].astype(f32),
                preferred_element_type=f32)
    # VMEM relayout (the chain's reshape, paper §4.3.2 — no HBM traffic)
    a = a.reshape(bb, n1, m2, r1).transpose(0, 2, 1, 3)
    # MXU matmul 2:  [bb·m2, n1·r1] @ [n1·r1, m1]
    y = jnp.dot(a.reshape(bb * m2, n1 * r1), p1_ref[...].astype(f32),
                preferred_element_type=f32)
    # final m-major relayout, still in VMEM
    y = y.reshape(bb, m2, m1).transpose(0, 2, 1).reshape(bb, m1 * m2)
    o_ref[...] = y.astype(o_ref.dtype)


def _fused2_int8_body(x_ref, p2_ref, p1_ref, s_ref, o_ref,
                      *, n1, n2, m1, m2, r1):
    """int8 fused d=2 body: both packed cores int8-resident; each MXU
    matmul widens its core to fp32 and applies the per-core SMEM scale on
    the (much smaller) output — exact for symmetric per-core scaling."""
    bb = x_ref.shape[0]
    f32 = jnp.float32
    x = x_ref[...].astype(f32)
    a = jnp.dot(x.reshape(bb * n1, n2), p2_ref[...].astype(f32),
                preferred_element_type=f32) * s_ref[0, 0]
    a = a.reshape(bb, n1, m2, r1).transpose(0, 2, 1, 3)
    y = jnp.dot(a.reshape(bb * m2, n1 * r1), p1_ref[...].astype(f32),
                preferred_element_type=f32) * s_ref[1, 0]
    y = y.reshape(bb, m2, m1).transpose(0, 2, 1).reshape(bb, m1 * m2)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("dims", "block_b", "interpret"))
def _tt_fused2_call(x: jax.Array, p2: jax.Array, p1: jax.Array,
                    dims: tuple[int, int, int, int, int],
                    block_b: int, interpret: bool,
                    scales: jax.Array | None = None) -> jax.Array:
    """Shared fp/int8 scaffolding (padding, grid, BlockSpecs); ``scales``
    ([2, 1] fp32, execution order) selects the int8 body + SMEM operand."""
    n1, n2, m1, m2, r1 = dims
    B = x.shape[0]
    bb = min(block_b, B)
    padB = (-B) % bb
    xp = jnp.pad(x, ((0, padB), (0, 0))) if padB else x
    Bp = xp.shape[0]

    kw = dict(n1=n1, n2=n2, m1=m1, m2=m2, r1=r1)
    in_specs = [
        pl.BlockSpec((bb, n1 * n2), lambda i: (i, 0)),
        pl.BlockSpec((n2, m2 * r1), lambda i: (0, 0)),
        pl.BlockSpec((n1 * r1, m1), lambda i: (0, 0)),
    ]
    args = (xp, p2, p1)
    if scales is None:
        body = functools.partial(_fused2_body, **kw)
    else:
        body = functools.partial(_fused2_int8_body, **kw)
        in_specs.append(pl.BlockSpec((2, 1), lambda i: (0, 0),
                                     memory_space=pltpu.SMEM))
        args += (scales,)

    out = pl.pallas_call(
        body,
        grid=(Bp // bb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, m1 * m2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, m1 * m2), x.dtype),
        interpret=interpret,
    )(*args)
    return out[:B]


def tt_fused2_pallas(x: jax.Array, p2: jax.Array, p1: jax.Array,
                     dims: tuple[int, int, int, int, int],
                     block_b: int | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """Fused d=2 TT layer.  ``x [B, n1·n2]`` → ``y [B, m1·m2]``.

    ``p2 [n2, m2·r1]``, ``p1 [n1·r1, m1]`` are the *packed* cores
    (core.packing.pack_core) — constant layout fixed at compile time.
    ``block_b=None`` selects the batch tile from the analytical VMEM model
    (``fused2_batch_tile``); callers with a measured winner (the autotuner)
    pass it explicitly.
    """
    if interpret is None:
        interpret = _interpret_default()
    n1, n2, m1, m2, r1 = dims
    if block_b is None:
        block_b = fused2_batch_tile(n1 * n2, m1 * m2, n1 * m2 * r1,
                                    p1.size + p2.size,
                                    itemsize=max(x.dtype.itemsize, 4))
    LAUNCH_COUNTS["fused2"] += 1
    return _tt_fused2_call(x, p2, p1, dims, block_b, interpret)


def tt_fused2_int8_pallas(x: jax.Array, p2: jax.Array, p1: jax.Array,
                          scales,
                          dims: tuple[int, int, int, int, int],
                          block_b: int | None = None,
                          interpret: bool | None = None) -> jax.Array:
    """int8 fused d=2 TT layer.  ``x [B, n1·n2]`` → ``y [B, m1·m2]``.

    ``p2 [n2, m2·r1]``, ``p1 [n1·r1, m1]`` are **int8** packed cores
    (core.quant.pack_core_int8); ``scales`` are their fp32 scales in the
    same (execution) order ``[s2, s1]``.  The cores stay int8 in VMEM, so
    the analytical tile prices them at 1 byte/elem."""
    if interpret is None:
        interpret = _interpret_default()
    _require_int8([p1, p2], "fused2 packed cores")
    n1, n2, m1, m2, r1 = dims
    if block_b is None:
        block_b = fused2_batch_tile(n1 * n2, m1 * m2, n1 * m2 * r1,
                                    p1.size + p2.size,
                                    itemsize=max(x.dtype.itemsize, 4),
                                    weight_itemsize=1)
    LAUNCH_COUNTS["fused2_int8"] += 1
    return _tt_fused2_call(x, p2, p1, dims, block_b, interpret,
                           scales=_scales_smem(scales, 2))


# ---------------------------------------------------------------------------
# Kernel 3: fused arbitrary-depth chain
# ---------------------------------------------------------------------------

def _fused_chain_body(*refs, ns, ms, ranks):
    """All d packed matmuls for one batch tile, relayouts in VMEM.

    State invariant (matches core.tt.tt_apply_batched): after the step on
    core t the per-row feature layout is [m_t, …, m_d, n_1, …, n_{t-1},
    r_{t-1}], so the trailing (n_t·r_t) block of the previous state is
    exactly the contraction dim of packed core P_t — every step is
    ``state.reshape(bb·b_t, n_t·r_t) @ P_t`` plus one VMEM transpose.
    """
    x_ref, *p_refs = refs[:-1]
    o_ref = refs[-1]
    d = len(ns)
    bb = x_ref.shape[0]
    f32 = jnp.float32
    state = x_ref[...].astype(f32)              # [bb, N]
    f = state.shape[1]
    for j, t in enumerate(range(d - 1, -1, -1)):
        nt, mt = ns[t], ms[t]
        rt, rt_1 = ranks[t + 1], ranks[t]
        bt = f // (nt * rt)
        # MXU matmul:  [bb·b_t, n_t·r_t] @ [n_t·r_t, m_t·r_{t-1}]
        a = jnp.dot(state.reshape(bb * bt, nt * rt),
                    p_refs[j][...].astype(f32), preferred_element_type=f32)
        # inter-step relayout [bb, b_t, m_t, r_{t-1}] → [bb, m_t, b_t, r_{t-1}]
        # — the paper's §4.3.2 transpose, kept in VMEM
        a = a.reshape(bb, bt, mt, rt_1).transpose(0, 2, 1, 3)
        f = mt * bt * rt_1
        state = a.reshape(bb, f)
    o_ref[...] = state.astype(o_ref.dtype)      # [bb, M] m-major


def _fused_chain_int8_body(*refs, ns, ms, ranks):
    """int8 chain body: identical state invariant to ``_fused_chain_body``,
    but the packed cores are int8-resident and every MXU matmul widens its
    core to fp32 + applies the per-core SMEM scale on the step output."""
    x_ref, *p_refs = refs[:-2]
    s_ref, o_ref = refs[-2], refs[-1]
    d = len(ns)
    bb = x_ref.shape[0]
    f32 = jnp.float32
    state = x_ref[...].astype(f32)              # [bb, N]
    f = state.shape[1]
    for j, t in enumerate(range(d - 1, -1, -1)):
        nt, mt = ns[t], ms[t]
        rt, rt_1 = ranks[t + 1], ranks[t]
        bt = f // (nt * rt)
        a = jnp.dot(state.reshape(bb * bt, nt * rt),
                    p_refs[j][...].astype(f32),
                    preferred_element_type=f32) * s_ref[j, 0]
        a = a.reshape(bb, bt, mt, rt_1).transpose(0, 2, 1, 3)
        f = mt * bt * rt_1
        state = a.reshape(bb, f)
    o_ref[...] = state.astype(o_ref.dtype)      # [bb, M] m-major


@functools.partial(jax.jit,
                   static_argnames=("dims", "block_b", "interpret"))
def _tt_fused_chain_call(x: jax.Array, packed: tuple[jax.Array, ...],
                         dims, block_b: int, interpret: bool,
                         scales: jax.Array | None = None) -> jax.Array:
    """Shared fp/int8 scaffolding (padding, grid, BlockSpecs); ``scales``
    ([d, 1] fp32, execution order) selects the int8 body + SMEM operand."""
    ns, ms, ranks = dims
    d = len(ns)
    N = x.shape[1]
    M = 1
    for m in ms:
        M *= m
    B = x.shape[0]
    bb = min(block_b, B)
    padB = (-B) % bb
    xp = jnp.pad(x, ((0, padB), (0, 0))) if padB else x
    Bp = xp.shape[0]

    # packed cores in execution order (core d first); each is one whole-array
    # block so it is resident in VMEM for every grid step.
    p_specs = [pl.BlockSpec(p.shape, lambda i: (0, 0)) for p in packed]
    in_specs = [pl.BlockSpec((bb, N), lambda i: (i, 0))] + p_specs
    args = (xp,) + tuple(packed)
    if scales is None:
        body = functools.partial(_fused_chain_body, ns=ns, ms=ms,
                                 ranks=ranks)
    else:
        body = functools.partial(_fused_chain_int8_body, ns=ns, ms=ms,
                                 ranks=ranks)
        in_specs.append(pl.BlockSpec((d, 1), lambda i: (0, 0),
                                     memory_space=pltpu.SMEM))
        args += (scales,)

    out = pl.pallas_call(
        body,
        grid=(Bp // bb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, M), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, M), x.dtype),
        interpret=interpret,
    )(*args)
    return out[:B]


def _check_chain_args(packed, ns) -> None:
    if not (len(packed) == len(ns) >= 2):
        raise ValueError(
            f"fused chain needs d >= 2 packed cores matching dims "
            f"(got {len(packed)} cores for {len(ns)} modes)")


def tt_fused_chain_pallas(x: jax.Array, packed: Sequence[jax.Array],
                          dims: tuple[tuple[int, ...], tuple[int, ...],
                                      tuple[int, ...]],
                          block_b: int | None = None,
                          interpret: bool | None = None) -> jax.Array:
    """Fused arbitrary-depth TT chain.  ``x [B, N] → y [B, M]``.

    ``packed`` are the pack_core() matrices in *execution* order (core d
    first): ``packed[j] = P_{d-j}`` of shape ``[n_t·r_t, m_t·r_{t-1}]``.
    ``dims = (ns, ms, ranks)`` is the TTPlan signature.  One ``pallas_call``
    over batch tiles runs the whole chain; intermediates never leave VMEM.

    ``block_b=None`` takes the analytical VMEM-fit tile
    (``fused_chain_batch_tile``); the autotuner passes a measured winner.
    Callers must ensure the chain fits (``fused_chain_batch_tile`` is not
    None) — the analytical fallback raises otherwise.
    """
    if interpret is None:
        interpret = _interpret_default()
    ns, ms, ranks = dims
    _check_chain_args(packed, ns)
    if block_b is None:
        block_b = fused_chain_batch_tile(
            ns, ms, ranks, itemsize=max(x.dtype.itemsize, 4))
        if block_b is None:
            raise ValueError(
                "chain does not fit VMEM at any batch tile — use the "
                "per-step kernel (or backend='auto')")
    LAUNCH_COUNTS["fused_chain"] += 1
    return _tt_fused_chain_call(x, tuple(packed), dims, block_b, interpret)


def tt_fused_chain_int8_pallas(x: jax.Array, packed: Sequence[jax.Array],
                               scales,
                               dims: tuple[tuple[int, ...], tuple[int, ...],
                                           tuple[int, ...]],
                               block_b: int | None = None,
                               interpret: bool | None = None) -> jax.Array:
    """int8 fused arbitrary-depth TT chain.  ``x [B, N] → y [B, M]``.

    ``packed`` are **int8** ``pack_core_int8`` matrices in *execution*
    order (core d first) and ``scales`` their fp32 scales in the same
    order.  One ``pallas_call`` runs the whole chain; the packed cores are
    int8-resident in VMEM for every grid step, so the default tile comes
    from the dtype-aware fit test (``weight_itemsize=1``) — chains whose
    fp32 weights bust the VMEM budget can still fuse here."""
    if interpret is None:
        interpret = _interpret_default()
    ns, ms, ranks = dims
    _check_chain_args(packed, ns)
    _require_int8(packed, "fused chain packed cores")
    if block_b is None:
        block_b = fused_chain_batch_tile(
            ns, ms, ranks, itemsize=max(x.dtype.itemsize, 4),
            weight_itemsize=1)
        if block_b is None:
            raise ValueError(
                "chain does not fit VMEM at any batch tile even with "
                "int8-resident cores — use the per-step kernel")
    LAUNCH_COUNTS["fused_chain_int8"] += 1
    return _tt_fused_chain_call(x, tuple(packed), dims, block_b, interpret,
                                scales=_scales_smem(scales, len(ns)))
