"""Pallas TPU kernels for the TT einsum chain.

Two kernels (DESIGN.md §2 maps them onto the paper's §4.3 pipeline):

``tt_step_kernel``   — one einsum step ``out[m,b,r0] = Σ_{n,r1} G·X`` with
   explicit (bm, bb, bn) VMEM tiling chosen by the analytical model in
   ``core.packing.select_blocks`` (the paper's register-blocking / cache-
   tiling transfer).  Grid = (m-tiles, b-tiles, n-tiles), n innermost with
   fp32 accumulation in the revisited output block.

``tt_fused2_kernel`` — the whole d=2 chain fused: two MXU matmuls over
   *packed* cores with the inter-step relayout done in VMEM, zero HBM
   intermediates and zero HBM transposes.  This is the TPU-native answer to
   the paper's IREE critique: IREE's transpose-to-matmul layers live in HBM;
   ours live in vector registers.

Kernels are written for TPU (BlockSpec/VMEM semantics) and validated on CPU
in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import BlockPlan


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Kernel 1: single einsum step, blocked + accumulated
# ---------------------------------------------------------------------------

def _tt_step_body(g_ref, x_ref, o_ref):
    """out[m,b,r0] += einsum over the (n, r1) block."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.einsum(
        "rnmk,bnk->mbr", g_ref[...], x_ref[...],
        preferred_element_type=jnp.float32)
    o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def tt_step_pallas(G: jax.Array, X: jax.Array, plan: BlockPlan,
                   interpret: bool | None = None) -> jax.Array:
    """``G [r0, n, m, r1]``, ``X [b, n, r1]`` → ``out [m, b, r0]`` (fp32).

    Inputs are zero-padded to block multiples (padding on n contributes 0 to
    the accumulation; padding on m/b is sliced off), so block shapes never
    have to divide the problem — the paper's "padding ukernel" (§4.3.4)
    replaced by masked tiles.
    """
    if interpret is None:
        interpret = _interpret_default()
    r0, n, m, r1 = G.shape
    b = X.shape[0]
    bm, bb, bn = min(plan.bm, m), min(plan.bb, b), min(plan.bn, n)

    def pad_to(a, axis, mult):
        pad = (-a.shape[axis]) % mult
        if pad == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        return jnp.pad(a, widths)

    Gp = pad_to(pad_to(G, 1, bn), 2, bm)
    Xp = pad_to(pad_to(X, 0, bb), 1, bn)
    mp, np_, bp = Gp.shape[2], Gp.shape[1], Xp.shape[0]
    grid = (mp // bm, bp // bb, np_ // bn)

    out = pl.pallas_call(
        _tt_step_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r0, bn, bm, r1), lambda i, j, k: (0, k, i, 0)),
            pl.BlockSpec((bb, bn, r1), lambda i, j, k: (j, k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bb, r0), lambda i, j, k: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, bp, r0), jnp.float32),
        interpret=interpret,
    )(Gp, Xp)
    return out[:m, :b, :]


# ---------------------------------------------------------------------------
# Kernel 2: fused d=2 chain
# ---------------------------------------------------------------------------

def _fused2_body(x_ref, p2_ref, p1_ref, o_ref, *, n1, n2, m1, m2, r1):
    bb = x_ref.shape[0]
    f32 = jnp.float32
    x = x_ref[...].astype(f32)
    # MXU matmul 1:  [bb·n1, n2] @ [n2, m2·r1]
    a = jnp.dot(x.reshape(bb * n1, n2), p2_ref[...].astype(f32),
                preferred_element_type=f32)
    # VMEM relayout (the chain's reshape, paper §4.3.2 — no HBM traffic)
    a = a.reshape(bb, n1, m2, r1).transpose(0, 2, 1, 3)
    # MXU matmul 2:  [bb·m2, n1·r1] @ [n1·r1, m1]
    y = jnp.dot(a.reshape(bb * m2, n1 * r1), p1_ref[...].astype(f32),
                preferred_element_type=f32)
    # final m-major relayout, still in VMEM
    y = y.reshape(bb, m2, m1).transpose(0, 2, 1).reshape(bb, m1 * m2)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("dims", "block_b", "interpret"))
def tt_fused2_pallas(x: jax.Array, p2: jax.Array, p1: jax.Array,
                     dims: tuple[int, int, int, int, int],
                     block_b: int = 64,
                     interpret: bool | None = None) -> jax.Array:
    """Fused d=2 TT layer.  ``x [B, n1·n2]`` → ``y [B, m1·m2]``.

    ``p2 [n2, m2·r1]``, ``p1 [n1·r1, m1]`` are the *packed* cores
    (core.packing.pack_core) — constant layout fixed at compile time.
    """
    if interpret is None:
        interpret = _interpret_default()
    n1, n2, m1, m2, r1 = dims
    B = x.shape[0]
    bb = min(block_b, B)
    padB = (-B) % bb
    xp = jnp.pad(x, ((0, padB), (0, 0))) if padB else x
    Bp = xp.shape[0]

    body = functools.partial(_fused2_body, n1=n1, n2=n2, m1=m1, m2=m2, r1=r1)
    out = pl.pallas_call(
        body,
        grid=(Bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, n1 * n2), lambda i: (i, 0)),
            pl.BlockSpec((n2, m2 * r1), lambda i: (0, 0)),
            pl.BlockSpec((n1 * r1, m1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, m1 * m2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, m1 * m2), x.dtype),
        interpret=interpret,
    )(xp, p2, p1)
    return out[:B]
