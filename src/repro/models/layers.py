"""Elementary layers: norms, RoPE, dense/TT linear, GLU MLP, embeddings.

Every projection goes through ``linear_spec``/``linear_apply`` which consult
the model's ``TTConfig`` — the paper's technique is a first-class, uniformly
available feature rather than a bolt-on (DESIGN.md §4).
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TTConfig
from repro.core.dse import DSEConfig, explore
from repro.core.flops import prod
from repro.core.tt import TTPlan, make_plan
from repro.kernels.ops import tt_forward
from repro.kernels.plan import PlanBook, TTExecutionPlan
from .spec import ParamSpec


# ---------------------------------------------------------------------------
# Activation statistics tap (data-aware DSE calibration, DESIGN.md §12)
# ---------------------------------------------------------------------------

# When a capture is active this holds the accumulator dict; linear_apply
# streams each projection's input second moment into it via
# jax.debug.callback, so the tap works inside lax.scan'd layer stacks and
# vmapped MoE experts (sums are order-invariant — callback ordering and
# batching don't matter).  None ⇒ zero overhead on every normal path.
_ACT_TAP: dict | None = None


@contextlib.contextmanager
def capture_activation_stats():
    """Collect per-projection input statistics during *eager* forward
    passes (``Model.activation_stats`` is the entry point).

    Yields the accumulator: ``{(N, M): {"gram": Σ xᵀx [N,N] float64,
    "count": rows}}`` keyed by projection signature, aggregated across
    every layer/expert sharing that shape.  The input covariance
    Σ = gram/count is exactly what activation-aware TT scoring needs
    (‖(W−Ŵ)X‖²_F = tr(Δ Σ Δᵀ)·count) without ever materializing X.

    Do NOT trace a jitted entry point while a capture is active: the
    callback would be baked into the cached executable with a stale
    store.  Call ``jax.effects_barrier()`` before reading the store (the
    callbacks are dispatched asynchronously); the caller-facing wrapper
    does this."""
    global _ACT_TAP
    prev, store = _ACT_TAP, {}
    _ACT_TAP = store
    try:
        yield store
    finally:
        _ACT_TAP = prev


def _tap_accumulate(store: dict, key: tuple, gram, count) -> None:
    """Host-side accumulator: sums away any leading batching axes the
    callback picked up under vmap, then folds into the store."""
    g = np.asarray(gram, np.float64)
    g = g.reshape((-1,) + g.shape[-2:]).sum(0)
    c = float(np.sum(np.asarray(count, np.float64)))
    slot = store.setdefault(key, {"gram": np.zeros(g.shape, np.float64),
                                  "count": 0.0})
    slot["gram"] += g
    slot["count"] += c


def _tap_record(params: dict, x: jax.Array) -> None:
    if "w" in params:
        N, M = (int(params["w"].shape[-2]), int(params["w"].shape[-1]))
    else:
        tt = params["tt"]
        d = sum(1 for k in tt if k.startswith("c"))
        shapes = [tt[f"c{t}"].shape[-4:] for t in range(d)]
        N = prod(int(s[1]) for s in shapes)
        M = prod(int(s[2]) for s in shapes)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    gram = x2.T @ x2
    rows = jnp.asarray(x2.shape[0], jnp.float32)
    jax.debug.callback(
        functools.partial(_tap_accumulate, _ACT_TAP, (N, M)), gram, rows)


# ---------------------------------------------------------------------------
# TT planning (offline, cached — the paper's design-tool step)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def plan_for(M: int, N: int, rank: int, length: int, min_factor: int
             ) -> TTPlan | None:
    cfg = DSEConfig(vl=rank, rank_step=rank, rank_cap=rank,
                    min_factor=min_factor, max_d=max(length, 4))
    res = explore(M, N, cfg, with_counts=False)
    sol = (res.best(length=length, rank=rank, default=None)
           or res.best(rank=rank, default=None))
    return sol.plan if sol else None


def _tt_core_specs(plan: TTPlan, dtype) -> dict[str, ParamSpec]:
    """Core ParamSpecs with the variance-preserving init of core.tt.tt_init."""
    target_std = float(np.sqrt(2.0 / (plan.M + plan.N)))
    rank_prod = prod(plan.ranks[1:-1]) if plan.d > 1 else 1
    sigma = (target_std ** 2 / max(rank_prod, 1)) ** (1.0 / (2 * plan.d))
    return {f"c{t}": ParamSpec(shape, ("tt_r", "tt_n", "tt_m", "tt_r"),
                               "normal", sigma, dtype)
            for t, shape in enumerate(plan.core_shapes)}


# ---------------------------------------------------------------------------
# Linear (dense or TT) — N in, M out
# ---------------------------------------------------------------------------

def linear_spec(in_dim: int, out_dim: int, tt: TTConfig | None,
                family: str, axes=("embed", "ff"), dtype=jnp.float32,
                bias: bool = False) -> dict:
    """Build the spec dict of one projection.  If the TTConfig covers this
    ``family`` and the DSE finds a surviving plan, emit TT cores instead of
    a dense weight."""
    use_tt = (tt is not None and tt.enabled and family in tt.families)
    if use_tt:
        if tt.plan_overrides:
            # Study-trial mode: only the overridden shape is factorized —
            # everything else stays dense so one candidate is measured in
            # isolation (TTConfig.plan_overrides contract).
            ov = tt.override_for(out_dim, in_dim)
            plan = (make_plan(list(ov[0]), list(ov[1]), list(ov[2]))
                    if ov is not None else None)
        else:
            plan = plan_for(out_dim, in_dim, tt.rank, tt.length,
                            tt.min_factor)
        if plan is not None:
            out = {"tt": _tt_core_specs(plan, dtype)}
            if bias:
                out["b"] = ParamSpec((out_dim,), (axes[1],), "zeros",
                                     dtype=dtype)
            return out
    out = {"w": ParamSpec((in_dim, out_dim), tuple(axes), "normal",
                          1.0 / np.sqrt(in_dim), dtype)}
    if bias:
        out["b"] = ParamSpec((out_dim,), (axes[1],), "zeros", dtype=dtype)
    return out


def linear_apply(params: dict, x: jax.Array,
                 backend: "str | PlanBook" = "xla",
                 tune: str | None = None,
                 plan: TTExecutionPlan | None = None) -> jax.Array:
    """Apply one projection (dense weight or TT cores).

    Dispatch is plan-first (DESIGN.md §10): ``plan`` executes a resolved
    ``TTExecutionPlan`` directly; ``backend`` may be the model's
    ``PlanBook`` (the normal path — a build-time-resolved plan is looked
    up by chain signature, so traces never plan) or, as a deprecation
    shim, a plain backend name / legacy ``"<backend>:<tune>[:<weights>]"``
    spec which is compiled to a plan per call; ``tune`` overrides the
    autotuner mode on the string path only.

    TT storage comes in two layouts (DESIGN.md §8): float cores
    ``{c0..c{d-1}}`` (training / fp serving — an int8 weight mode in the
    plan quantizes them on the fly), or the quantized layout
    ``{c0..c{d-1} int8, scales [d] fp32}`` produced by
    ``quantize_tt_params`` — the int8 cores are handed to the kernels
    as-is and stay int8 in VMEM."""
    if _ACT_TAP is not None and ("tt" in params or "w" in params):
        _tap_record(params, x)
    if "tt" in params:
        tt = params["tt"]
        d = sum(1 for k in tt if k.startswith("c"))
        cores = [tt[f"c{t}"] for t in range(d)]
        scales = list(tt["scales"]) if cores[0].dtype == jnp.int8 else None
        if plan is None and isinstance(backend, PlanBook):
            plan = backend.plan_for_cores(cores)
        if plan is not None:
            y = tt_forward(cores, x, plan=plan, scales=scales)
        elif scales is not None:
            y = tt_forward(cores, x, backend=backend, tune=tune,
                           weights="int8", scales=scales)
        else:
            y = tt_forward(cores, x, backend=backend, tune=tune)
    else:
        y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def quantize_tt_params(params):
    """Offline weight quantization of a parameter tree: every TT core
    bundle ``{c0..c{d-1}}`` is replaced by int8 cores + a ``scales [d]``
    fp32 leaf (``core.quant.quantize_cores``); dense weights, norms and
    embeddings are untouched.  The result is a drop-in parameter tree for
    the same ``Model`` — ``linear_apply`` detects the int8 storage and
    routes through the int8 kernel path (serving engine/scheduler
    included), so quantization is a checkpoint transform, never a model
    rebuild."""
    from repro.core.quant import quantize_core

    def quant_nd(G):
        """Quantize the trailing [r0, n, m, r1] core, vmapping over any
        leading stack axes (scan layers, MoE experts) so every per-layer /
        per-expert slice keeps its own scale — the scan/vmap machinery
        slices cores and scales consistently."""
        if G.ndim == 4:
            return quantize_core(G)
        return jax.vmap(quant_nd)(G)

    def quantize_bundle(tt: dict) -> dict:
        if "scales" in tt or tt["c0"].dtype == jnp.int8:
            # already quantized: re-quantizing the int8 codes would derive
            # a fresh ~1.0 scale from them and DROP the real per-core
            # scales — idempotence keeps a reloaded int8 checkpoint (or a
            # double-applied pipeline) correct instead of silently wrong
            return tt
        d = sum(1 for kk in tt if kk.startswith("c"))
        qs, ss = [], []
        for t in range(d):
            q, s = quant_nd(tt[f"c{t}"])
            qs.append(q)
            ss.append(jnp.asarray(s, jnp.float32))
        out = {f"c{t}": q for t, q in enumerate(qs)}
        out["scales"] = jnp.stack(ss, axis=-1)   # [*stack_axes, d]
        return out

    def walk(node):
        if not isinstance(node, dict):
            return node
        return {k: quantize_bundle(v) if k == "tt" and isinstance(v, dict)
                else walk(v)
                for k, v in node.items()}

    return walk(params)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(dim: int, axis: str = "embed", dtype=jnp.float32) -> dict:
    return {"scale": ParamSpec((dim,), (axis,), "ones", dtype=dtype)}


def rmsnorm_apply(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * params["scale"]).astype(dt)


def head_rmsnorm_apply(scale: jax.Array, x: jax.Array,
                       eps: float = 1e-6) -> jax.Array:
    """qk-norm: RMS over the head dim of [..., heads, head_dim]."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, hd], positions [..., S] → rotated x (pairwise halves)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs      # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                            # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GLU MLP
# ---------------------------------------------------------------------------

def mlp_spec(d: int, ff: int, tt: TTConfig | None, dtype=jnp.float32) -> dict:
    return {
        "gate": linear_spec(d, ff, tt, "ffn", ("embed", "ff"), dtype),
        "up": linear_spec(d, ff, tt, "ffn", ("embed", "ff"), dtype),
        "down": linear_spec(ff, d, tt, "ffn", ("ff", "embed"), dtype),
    }


def mlp_apply(params: dict, x: jax.Array, backend: str = "xla") -> jax.Array:
    g = linear_apply(params["gate"], x, backend)
    u = linear_apply(params["up"], x, backend)
    return linear_apply(params["down"], jax.nn.silu(g) * u, backend)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def embed_spec(vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"table": ParamSpec((vocab, d), ("vocab", "embed"), "normal",
                               1.0 / np.sqrt(d), dtype)}


def embed_apply(params: dict, tokens: jax.Array, d: int,
                scale: bool = False) -> jax.Array:
    out = params["table"][tokens]
    if scale:                       # gemma-style sqrt(d) input scaling
        out = out * jnp.asarray(np.sqrt(d), out.dtype)
    return out
