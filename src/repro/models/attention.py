"""Attention variants: GQA (+qk-norm, sliding window) and MLA (DeepSeek-V2).

All projections route through ``linear_spec`` so the paper's TT technique
applies uniformly ("attn" family).  MLA's down/up projections are excluded
from TT by construction — MLA *is already* a low-rank factorization of the
KV path (DESIGN.md §5); TT composes with it on q/o only.

Cache contract (serving/kv_cache.py builds the buffers):
  full  : k,v [B, S_max, KV, hd], write at ``pos``
  ring  : k,v [B, W, KV, hd], write at ``pos % W`` (SWA / gemma3 local)
  mla   : ckv [B, S_max, kv_lora], krope [B, S_max, rope_hd]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import model_axis_size, shard_act
from .layers import (head_rmsnorm_apply, linear_apply, linear_spec,
                     rmsnorm_spec, rmsnorm_apply, rope)
from .spec import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_spec(cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, q_dim, kv_dim = cfg.d_model, cfg.q_dim, cfg.kv_dim
    out = {
        "q": linear_spec(d, q_dim, cfg.tt, "attn", ("embed", "heads"), dtype),
        "k": linear_spec(d, kv_dim, cfg.tt, "attn", ("embed", "heads"), dtype),
        "v": linear_spec(d, kv_dim, cfg.tt, "attn", ("embed", "heads"), dtype),
        "o": linear_spec(q_dim, d, cfg.tt, "attn", ("heads", "embed"), dtype),
    }
    if cfg.qk_norm:
        out["q_norm"] = ParamSpec((cfg.head_dim,), (None,), "ones", dtype=dtype)
        out["k_norm"] = ParamSpec((cfg.head_dim,), (None,), "ones", dtype=dtype)
    return out


def _qkv(p, cfg: ModelConfig, x, positions, theta, backend):
    """Returns (q, k, v, heads_ok).  TP strategy: if H divides the model
    axis, attention tensors shard on heads; otherwise the query-sequence dim
    is sharded and k/v replicated across 'model' (GSPMD otherwise replicates
    the O(S²) score tensors — measured 100× collective blow-up)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear_apply(p["q"], x, backend).reshape(B, S, H, hd)
    k = linear_apply(p["k"], x, backend).reshape(B, S, KV, hd)
    v = linear_apply(p["v"], x, backend).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = head_rmsnorm_apply(p["q_norm"], q, cfg.norm_eps)
        k = head_rmsnorm_apply(p["k_norm"], k, cfg.norm_eps)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    msize = model_axis_size()
    heads_ok = H % msize == 0 and H >= msize
    if heads_ok:
        q = shard_act(q, ("act_batch", None, "act_heads", None))
    else:
        q = shard_act(q, ("act_batch", "act_seq", None, None))
    return q, k, v, heads_ok


def _expand_and_shard_kv(cfg, k, v, heads_ok):
    """Full-seq path: expand GQA k/v to H heads when heads shard cleanly so
    every attention tensor splits 16-way (no score-tensor replication)."""
    H, KV = cfg.num_heads, cfg.num_kv_heads
    if heads_ok:
        if KV < H:
            k = jnp.repeat(k, H // KV, axis=2)
            v = jnp.repeat(v, H // KV, axis=2)
        k = shard_act(k, ("act_batch", None, "act_heads", None))
        v = shard_act(v, ("act_batch", None, "act_heads", None))
    else:
        k = shard_act(k, ("act_batch", None, None, None))
        v = shard_act(v, ("act_batch", None, None, None))
    return k, v


def _gqa_scores_ctx(q, k, v, mask, scale):
    """q [B,S,H,hd], k/v [B,T,KV,hd], mask [B,1,1,S,T] or broadcastable."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return ctx.reshape(B, S, H * hd).astype(q.dtype)


def gqa_self_attn(p, cfg: ModelConfig, x, positions, *, window: int = 0,
                  theta: float | None = None, backend: str = "xla",
                  causal: bool = True):
    """Full-sequence self-attention (train / prefill / encoder)."""
    B, S, _ = x.shape
    theta = cfg.rope_theta if theta is None else theta
    q, k, v, heads_ok = _qkv(p, cfg, x, positions, theta, backend)
    k_cache, v_cache = k, v                       # pre-expansion, [B,S,KV,hd]
    k, v = _expand_and_shard_kv(cfg, k, v, heads_ok)
    i = positions[:, :, None]                     # [B,S,1] query pos
    j = positions[:, None, :]                     # [B,1,T] key pos
    mask = (j <= i) if causal else jnp.ones((B, S, S), bool)
    if window:
        mask = mask & (j > i - window)
    mask = mask[:, None, None]                    # [B,1,1,S,T]
    ctx = _gqa_scores_ctx(q, k, v, mask, 1.0 / np.sqrt(cfg.head_dim))
    y = linear_apply(p["o"], ctx, backend)
    return y, (k_cache, v_cache)


def gqa_decode_attn(p, cfg: ModelConfig, x, cache_k, cache_v, pos, *,
                    window: int = 0, theta: float | None = None,
                    backend: str = "xla", active=None):
    """One-token decode against a full or ring cache.

    x [B,1,d]; cache_k/v [B, T, KV, hd] (T = S_max or window W);
    pos: int32 — current absolute position, either a scalar shared by the
    whole batch or a per-row vector [B] (continuous-batching slots, each at
    its own depth).  ``active`` (optional [B] bool, per-slot mode) gates the
    cache write per row — slots mid-chunked-prefill must not have their
    partial K/V overwritten by the fused decode pass.
    Returns (y [B,1,d], new_k, new_v).
    """
    B = x.shape[0]
    T = cache_k.shape[1]
    theta = cfg.rope_theta if theta is None else theta
    per_slot = jnp.ndim(pos) == 1
    positions = (pos.astype(jnp.int32)[:, None] if per_slot
                 else jnp.full((B, 1), pos, jnp.int32))
    q, k, v, _ = _qkv(p, cfg, x, positions, theta, backend)
    idx = jnp.arange(T)
    if per_slot:
        pv = positions[:, 0]                      # [B]
        slot = pv % T if window else pv
        # per-row scatter: row b writes its [1,KV,hd] k/v at its own slot
        # (rows whose slot is out of range — retired/free slots at pos ≥ T —
        # simply don't write)
        wr = (idx[None, :] == slot[:, None])[:, :, None, None]
        if active is not None:
            wr = wr & active[:, None, None, None]
        cache_k = jnp.where(wr, k, cache_k)
        cache_v = jnp.where(wr, v, cache_v)
        if window:
            abs_pos = pv[:, None] - jnp.mod(pv[:, None] - idx[None, :], T)
            valid = abs_pos >= 0                  # [B,T]
        else:
            valid = idx[None, :] <= pv[:, None]
        mask = valid[:, None, None, None, :]      # [B,1,1,1,T]
    else:
        slot = pos % T if window else pos
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot,
                                                      axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot,
                                                      axis=1)
        if window:
            # ring: slot s holds absolute position pos - ((pos - s) mod T)
            abs_pos = pos - jnp.mod(pos - idx, T)
            valid = abs_pos >= 0
        else:
            valid = idx <= pos
        mask = valid[None, None, None, None, :]   # [1,1,1,1,T]
    ctx = _gqa_scores_ctx(q, cache_k, cache_v, mask,
                          1.0 / np.sqrt(cfg.head_dim))
    y = linear_apply(p["o"], ctx, backend)
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# Paged decode — block-table gather/scatter against a block arena
# ---------------------------------------------------------------------------
#
# Arena layout (DESIGN.md §7): per cache leaf, [num_blocks + 1, block, ...]
# at this (per-layer) level; logical token t of slot b lives at
# (bt[b, t // block], t % block).  The last arena block is the write
# sentinel: inactive slots are redirected there so a retired slot's stale
# block table can never corrupt storage reused by another request.

def gqa_decode_attn_paged(p, cfg: ModelConfig, x, arena_k, arena_v, bt, pos,
                          active, *, window: int = 0,
                          theta: float | None = None, backend: str = "xla"):
    """One-token decode against a block-paged cache.

    x [B,1,d]; arena_k/v [nb+1, block, KV, hd]; bt [B, max_blocks] int32;
    pos [B] int32; active [B] bool.  Windowed layers address the arena
    through the ring index ``pos % W`` (W = min(window, logical length)),
    reusing the low entries of the same block table — ring blocks are
    therefore never prefix-shared (the scheduler disables prefix caching
    for windowed models).  Returns (y [B,1,d], new arenas).
    """
    B = x.shape[0]
    nb1, blk, KV, hd = arena_k.shape
    sentinel = nb1 - 1
    theta = cfg.rope_theta if theta is None else theta
    T_logical = bt.shape[1] * blk
    W = min(window, T_logical) if window else T_logical
    positions = pos.astype(jnp.int32)[:, None]            # [B,1]
    q, k, v, _ = _qkv(p, cfg, x, positions, theta, backend)
    pv = positions[:, 0]
    wp = pv % W if window else pv
    phys = jnp.take_along_axis(bt, (wp // blk)[:, None], 1)[:, 0]
    phys = jnp.where(active, phys, sentinel)
    arena_k = arena_k.at[phys, wp % blk].set(k[:, 0])
    arena_v = arena_v.at[phys, wp % blk].set(v[:, 0])
    nblk = -(-W // blk)
    gk = arena_k[bt[:, :nblk]].reshape(B, nblk * blk, KV, hd)[:, :W]
    gv = arena_v[bt[:, :nblk]].reshape(B, nblk * blk, KV, hd)[:, :W]
    idx = jnp.arange(W)
    if window:
        abs_pos = pv[:, None] - jnp.mod(pv[:, None] - idx[None, :], W)
        valid = abs_pos >= 0
    else:
        valid = idx[None, :] <= pv[:, None]
    mask = valid[:, None, None, None, :]                  # [B,1,1,1,W]
    ctx = _gqa_scores_ctx(q, gk, gv, mask, 1.0 / np.sqrt(cfg.head_dim))
    y = linear_apply(p["o"], ctx, backend)
    return y, arena_k, arena_v


def mla_decode_attn_paged(p, cfg: ModelConfig, x, arena_ckv, arena_kr, bt,
                          pos, active, backend="xla"):
    """Absorbed-form MLA decode against block-paged latent arenas.

    arena_ckv [nb+1, block, kv_lora], arena_kr [nb+1, block, rope_hd];
    bt/pos/active as in gqa_decode_attn_paged.
    """
    m = cfg.mla
    B = x.shape[0]
    nb1, blk, _ = arena_ckv.shape
    sentinel = nb1 - 1
    positions = pos.astype(jnp.int32)[:, None]
    q_nope, q_rope = _mla_q(p, cfg, x, positions, backend)
    ckv, krope = _mla_compress(p, cfg, x, positions, backend)
    pv = positions[:, 0]
    phys = jnp.take_along_axis(bt, (pv // blk)[:, None], 1)[:, 0]
    phys = jnp.where(active, phys, sentinel)
    arena_ckv = arena_ckv.at[phys, pv % blk].set(ckv[:, 0])
    arena_kr = arena_kr.at[phys, pv % blk].set(krope[:, 0])
    T = bt.shape[1] * blk
    cckv = arena_ckv[bt].reshape(B, T, m.kv_lora)
    ckr = arena_kr[bt].reshape(B, T, m.rope_head_dim)
    valid = (jnp.arange(T)[None, :] <= positions)[:, None, None, :]
    ctx = _mla_absorbed_ctx(p, cfg, q_nope, q_rope, cckv, ckr, valid)
    y = linear_apply(p["o"], ctx.astype(x.dtype), backend)
    return y, arena_ckv, arena_kr


# ---------------------------------------------------------------------------
# Resume prefill — suffix attention over gathered prefix blocks (COW write)
# ---------------------------------------------------------------------------
#
# The prefix-reuse admission path: a request whose prompt prefix is already
# resident skips its prefill.  The suffix runs here — the logical cache is
# gathered densely through the *source* block table, the suffix K/V is
# computed and written into the dense buffer at its absolute positions,
# and the buffer is scattered back through the *destination* table.  A
# destination entry differing from its source entry IS the copy-on-write:
# content flows old block → dense buffer → new block, with the overwritten
# rows replaced in between.  Identical src/dst entries rewrite shared
# blocks with bitwise-identical gathered content (a no-op by value).

def _resume_dense(arena, src_b, S_pad):
    """Gather the logical cache [1, T_max + S_pad, ...] via src_b, with
    S_pad scratch rows appended so a dynamic_update_slice at start <= T_max
    never clamps/misaligns."""
    mb = src_b.shape[0]
    blk = arena.shape[1]
    dense = arena[src_b].reshape(1, mb * blk, *arena.shape[2:])
    pad = jnp.zeros((1, S_pad) + dense.shape[2:], dense.dtype)
    return jnp.concatenate([dense, pad], axis=1)


def _resume_scatter(arena, dst_b, dense):
    """Scatter the first T_max rows of the dense buffer back through the
    destination table (sentinel-padded entries collapse onto the scratch
    block)."""
    mb = dst_b.shape[0]
    blk = arena.shape[1]
    blocks = dense[0, :mb * blk].reshape(mb, blk, *arena.shape[2:])
    return arena.at[dst_b].set(blocks.astype(arena.dtype))


def gqa_chunk_attn(p, cfg: ModelConfig, x, dk, dv, start, *,
                   theta: float | None = None, backend: str = "xla"):
    """Chunk/suffix prefill against a *dense logical* cache buffer.

    x [1, S_pad, d] at absolute positions start + t; dk/dv
    [1, T + S_pad, KV, hd] — the logical cache with S_pad scratch rows
    appended so the write at ``start`` never clamps.  Writes the chunk K/V
    at its absolute positions and attends causally to prefix + itself.
    Full (non-windowed) attention only.  Returns (y, dk, dv).
    """
    B, S_pad, _ = x.shape
    theta = cfg.rope_theta if theta is None else theta
    positions = start + jnp.arange(S_pad)[None, :]        # [1, S_pad]
    q, k, v, heads_ok = _qkv(p, cfg, x, positions, theta, backend)
    dk = jax.lax.dynamic_update_slice(dk, k.astype(dk.dtype),
                                      (0, start, 0, 0))
    dv = jax.lax.dynamic_update_slice(dv, v.astype(dv.dtype),
                                      (0, start, 0, 0))
    kk, vv = _expand_and_shard_kv(cfg, dk, dv, heads_ok)
    j = jnp.arange(kk.shape[1])[None, None, :]            # [1,1,T]
    mask = (j <= positions[:, :, None])[:, None, None]    # [1,1,1,S,T]
    ctx = _gqa_scores_ctx(q, kk, vv, mask, 1.0 / np.sqrt(cfg.head_dim))
    y = linear_apply(p["o"], ctx, backend)
    return y, dk, dv


def gqa_resume_attn(p, cfg: ModelConfig, x, arena_k, arena_v, src_b, dst_b,
                    start, *, theta: float | None = None,
                    backend: str = "xla"):
    """Suffix prefill (x [1, S_pad, d] at absolute positions start + t)
    attending to the gathered prefix + itself; writes the suffix K/V back
    into the arenas through dst_b.  Full (non-windowed) attention only."""
    B, S_pad, _ = x.shape
    dk = _resume_dense(arena_k, src_b, S_pad)
    dv = _resume_dense(arena_v, src_b, S_pad)
    y, dk, dv = gqa_chunk_attn(p, cfg, x, dk, dv, start, theta=theta,
                               backend=backend)
    return y, _resume_scatter(arena_k, dst_b, dk), \
        _resume_scatter(arena_v, dst_b, dv)


def gqa_chunk_attn_ring(p, cfg: ModelConfig, x, ring_k, ring_v, start,
                        true_len, *, theta: float | None = None,
                        backend: str = "xla"):
    """Chunked prefill for a windowed-ring layer.

    x [1, C, d] at absolute positions start + t (rows >= true_len are
    right-padding); ring_k/v [1, W, KV, hd] hold the state *before* this
    chunk: slot w = K/V of the latest absolute position p <= start - 1 with
    p % W == w (zeros where no such p >= 0 exists — the `_ring_cache`
    convention).  A chunk may span more than W positions, so the ring is
    NOT updated in place (in-chunk overwrites would hide keys still inside
    an earlier query's window); instead the history is gathered densely,
    the chunk keys appended, every real query attends over absolute
    positions, and the ring is rebuilt for state after start + true_len - 1.
    Returns (y, new_ring_k, new_ring_v).
    """
    B, C, _ = x.shape
    W = ring_k.shape[1]
    theta = cfg.rope_theta if theta is None else theta
    positions = start + jnp.arange(C)[None, :]            # [1, C]
    q, k, v, heads_ok = _qkv(p, cfg, x, positions, theta, backend)
    # history entry i = absolute position start - W + i, stored at ring slot
    # (start - W + i) mod W == (start + i) mod W
    i_idx = jnp.arange(W)
    hist_slot = jnp.mod(start + i_idx, W)
    hk = jnp.take(ring_k, hist_slot, axis=1)
    hv = jnp.take(ring_v, hist_slot, axis=1)
    key_pos = jnp.concatenate([start - W + i_idx, start + jnp.arange(C)])
    ck = jnp.concatenate([hk, k.astype(hk.dtype)], axis=1)    # [1,W+C,KV,hd]
    cv = jnp.concatenate([hv, v.astype(hv.dtype)], axis=1)
    kk, vv = _expand_and_shard_kv(cfg, ck, cv, heads_ok)
    pq = positions[:, :, None]                            # [1,C,1]
    j = key_pos[None, None, :]                            # [1,1,W+C]
    mask = ((j <= pq) & (j > pq - W) & (j >= 0))[:, None, None]
    ctx = _gqa_scores_ctx(q, kk, vv, mask, 1.0 / np.sqrt(cfg.head_dim))
    y = linear_apply(p["o"], ctx, backend)
    # rebuild: slot w <- latest p <= L1 with p % W == w; that p indexes the
    # combined buffer at p - start + W (history region when p < start —
    # where it provably equals the old ring entry — chunk region otherwise)
    L1 = start + true_len - 1
    p_w = L1 - jnp.mod(L1 - i_idx, W)
    src = p_w - start + W
    nk = jnp.take(ck, src, axis=1)
    nv = jnp.take(cv, src, axis=1)
    ok = (p_w >= 0)[None, :, None, None]
    new_rk = jnp.where(ok, nk, jnp.zeros_like(nk)).astype(ring_k.dtype)
    new_rv = jnp.where(ok, nv, jnp.zeros_like(nv)).astype(ring_v.dtype)
    return y, new_rk, new_rv


def mla_chunk_attn(p, cfg: ModelConfig, x, dckv, dkr, start, backend="xla"):
    """MLA chunk/suffix prefill against dense latent buffers (absorbed
    form).  dckv [1, T + S_pad, kv_lora], dkr [1, T + S_pad, rope_hd] with
    S_pad scratch rows appended.  Returns (y, dckv, dkr)."""
    B, S_pad, _ = x.shape
    positions = start + jnp.arange(S_pad)[None, :]
    q_nope, q_rope = _mla_q(p, cfg, x, positions, backend)
    ckv, krope = _mla_compress(p, cfg, x, positions, backend)
    dckv = jax.lax.dynamic_update_slice(dckv, ckv.astype(dckv.dtype),
                                        (0, start, 0))
    dkr = jax.lax.dynamic_update_slice(dkr, krope.astype(dkr.dtype),
                                       (0, start, 0))
    j = jnp.arange(dckv.shape[1])[None, None, :]
    valid = (j <= positions[:, :, None])[:, None]         # [1,1,S,T]
    ctx = _mla_absorbed_ctx(p, cfg, q_nope, q_rope, dckv, dkr, valid)
    y = linear_apply(p["o"], ctx.astype(x.dtype), backend)
    return y, dckv, dkr


def mla_resume_attn(p, cfg: ModelConfig, x, arena_ckv, arena_kr, src_b,
                    dst_b, start, backend="xla"):
    """MLA suffix prefill over gathered latent arenas (absorbed form)."""
    B, S_pad, _ = x.shape
    dckv = _resume_dense(arena_ckv, src_b, S_pad)
    dkr = _resume_dense(arena_kr, src_b, S_pad)
    y, dckv, dkr = mla_chunk_attn(p, cfg, x, dckv, dkr, start,
                                  backend=backend)
    return y, _resume_scatter(arena_ckv, dst_b, dckv), \
        _resume_scatter(arena_kr, dst_b, dkr)


# ---------------------------------------------------------------------------
# Cross-attention (seamless decoder)
# ---------------------------------------------------------------------------

def cross_attn_spec(cfg: ModelConfig, dtype=jnp.float32) -> dict:
    return gqa_spec(cfg, dtype)


def cross_attn(p, cfg: ModelConfig, x, enc_k, enc_v, backend="xla"):
    """x [B,S,d] attends to precomputed encoder k/v [B,T,KV,hd]."""
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q = linear_apply(p["q"], x, backend).reshape(B, S, H, hd)
    mask = jnp.ones((1, 1, 1, 1, enc_k.shape[1]), bool)
    ctx = _gqa_scores_ctx(q, enc_k, enc_v, mask, 1.0 / np.sqrt(hd))
    return linear_apply(p["o"], ctx, backend)


def cross_kv(p, cfg: ModelConfig, enc_out, backend="xla"):
    B, T, _ = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    k = linear_apply(p["k"], enc_out, backend).reshape(B, T, KV, hd)
    v = linear_apply(p["v"], enc_out, backend).reshape(B, T, KV, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_spec(cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk_head = m.nope_head_dim + m.rope_head_dim
    return {
        "q": linear_spec(d, H * qk_head, cfg.tt, "attn",
                         ("embed", "heads"), dtype),
        # low-rank KV path: dense by construction (already factorized)
        "kv_down": linear_spec(d, m.kv_lora + m.rope_head_dim, None, "mla",
                               ("embed", None), dtype),
        "kv_norm": rmsnorm_spec(m.kv_lora, None, dtype),
        "kv_up": linear_spec(m.kv_lora,
                             H * (m.nope_head_dim + m.v_head_dim), None,
                             "mla", (None, "heads"), dtype),
        "o": linear_spec(H * m.v_head_dim, d, cfg.tt, "attn",
                         ("heads", "embed"), dtype),
    }


def _mla_q(p, cfg, x, positions, backend):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_head = m.nope_head_dim + m.rope_head_dim
    q = linear_apply(p["q"], x, backend).reshape(B, S, H, qk_head)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_compress(p, cfg, x, positions, backend):
    m = cfg.mla
    c = linear_apply(p["kv_down"], x, backend)
    ckv, krope = jnp.split(c, [m.kv_lora], axis=-1)
    ckv = rmsnorm_apply(p["kv_norm"], ckv, cfg.norm_eps)
    krope = rope(krope[:, :, None, :], positions,
                 cfg.rope_theta)[:, :, 0, :]
    return ckv, krope


def mla_self_attn(p, cfg: ModelConfig, x, positions, backend="xla"):
    """Expanded-form MLA for train/prefill.  Returns (y, (ckv, krope))."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(p, cfg, x, positions, backend)
    ckv, krope = _mla_compress(p, cfg, x, positions, backend)
    kv = linear_apply(p["kv_up"], ckv, backend).reshape(
        B, S, H, m.nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.nope_head_dim], axis=-1)
    scale = 1.0 / np.sqrt(m.nope_head_dim + m.rope_head_dim)
    i, j = positions[:, :, None], positions[:, None, :]
    mask = (j <= i)[:, None]                      # [B,1,S,T]
    s = (jnp.einsum("bshn,bthn->bhst", q_nope.astype(jnp.float32),
                    k_nope.astype(jnp.float32))
         + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                      krope.astype(jnp.float32))) * scale
    probs = jax.nn.softmax(jnp.where(mask, s, NEG_INF), axis=-1)
    ctx = jnp.einsum("bhst,bthv->bshv", probs, v.astype(jnp.float32))
    y = linear_apply(p["o"], ctx.reshape(B, S, -1).astype(x.dtype), backend)
    return y, (ckv, krope)


def _mla_absorbed_ctx(p, cfg: ModelConfig, q_nope, q_rope, cache_ckv,
                      cache_krope, valid):
    """Absorbed-form MLA scores/context over a latent cache.

    q_nope/q_rope [B,S,H,·], cache_ckv [B,T,kv_lora],
    cache_krope [B,T,rope_hd], valid broadcastable to [B,H,S,T].
    Returns the flattened context [B, S, H·v_head_dim] (pre-o-projection).
    """
    m = cfg.mla
    B, S = q_nope.shape[:2]
    H = cfg.num_heads
    w_up = p["kv_up"]["w"].reshape(m.kv_lora, H,
                                   m.nope_head_dim + m.v_head_dim)
    w_uk, w_uv = jnp.split(w_up, [m.nope_head_dim], axis=-1)
    q_eff = jnp.einsum("bshn,lhn->bshl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))          # [B,S,H,kv_lora]
    scale = 1.0 / np.sqrt(m.nope_head_dim + m.rope_head_dim)
    s = (jnp.einsum("bshl,btl->bhst", q_eff,
                    cache_ckv.astype(jnp.float32))
         + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                      cache_krope.astype(jnp.float32))) * scale
    probs = jax.nn.softmax(jnp.where(valid, s, NEG_INF), axis=-1)
    ctx_l = jnp.einsum("bhst,btl->bshl", probs,
                       cache_ckv.astype(jnp.float32))     # latent context
    ctx = jnp.einsum("bshl,lhv->bshv", ctx_l, w_uv.astype(jnp.float32))
    return ctx.reshape(B, S, -1)


def mla_decode_attn(p, cfg: ModelConfig, x, cache_ckv, cache_krope, pos,
                    backend="xla", active=None):
    """Absorbed-form MLA decode: scores/context live in the latent space, so
    per-step cost is O(T·kv_lora) not O(T·H·head_dim) — the production path.

    cache_ckv [B, S_max, kv_lora], cache_krope [B, S_max, rope_hd].
    ``pos`` is a scalar or a per-row vector [B] (see gqa_decode_attn).
    ``active`` (optional [B] bool) gates the per-slot cache write.
    """
    B = x.shape[0]
    per_slot = jnp.ndim(pos) == 1
    positions = (pos.astype(jnp.int32)[:, None] if per_slot
                 else jnp.full((B, 1), pos, jnp.int32))
    q_nope, q_rope = _mla_q(p, cfg, x, positions, backend)
    ckv, krope = _mla_compress(p, cfg, x, positions, backend)
    if per_slot:
        idx = jnp.arange(cache_ckv.shape[1])
        wr = (idx[None, :] == positions)[:, :, None]    # [B,T,1]
        if active is not None:
            wr = wr & active[:, None, None]
        cache_ckv = jnp.where(wr, ckv, cache_ckv)
        cache_krope = jnp.where(wr, krope, cache_krope)
    else:
        cache_ckv = jax.lax.dynamic_update_slice_in_dim(
            cache_ckv, ckv, pos, 1)
        cache_krope = jax.lax.dynamic_update_slice_in_dim(
            cache_krope, krope, pos, 1)
    T = cache_ckv.shape[1]
    if per_slot:
        valid = (jnp.arange(T)[None, :]
                 <= positions)[:, None, None, :]        # [B,1,1,T]
    else:
        valid = (jnp.arange(T) <= pos)[None, None, None, :]
    ctx = _mla_absorbed_ctx(p, cfg, q_nope, q_rope, cache_ckv, cache_krope,
                            valid)
    y = linear_apply(p["o"], ctx.astype(x.dtype), backend)
    return y, cache_ckv, cache_krope
