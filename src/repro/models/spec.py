"""Declarative parameter specs.

Models are described as nested dicts of ``ParamSpec`` (shape + logical axes +
init law).  From one spec tree we derive:

  * initialized parameter pytrees        (``init_tree``)
  * sharding PartitionSpecs per leaf     (``distributed.sharding``)
  * parameter counts                     (``count_params``)

Repeated layers are expressed by stacking a block's spec tree along a
leading ``'layers'`` axis (``stack``) and scanning the block apply function —
this keeps HLO size O(1) in depth, which the 512-device dry-run requires.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]     # logical axis names (len == ndim)
    init: str = "normal"             # normal | zeros | ones
    scale: float = 1.0               # stddev for 'normal'
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def stack(tree, n: int):
    """Add a leading ('layers',) axis of extent n to every spec leaf."""
    def f(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, shape=(n,) + s.shape,
                                   axes=("layers",) + s.axes)
    return jax.tree.map(f, tree, is_leaf=is_spec)


def init_leaf(key: jax.Array, s: ParamSpec) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "normal":
        return (jax.random.normal(key, s.shape, jnp.float32) * s.scale
                ).astype(s.dtype)
    raise ValueError(s.init)


def init_tree(key: jax.Array, tree):
    """Initialize every leaf with an independent fold_in'd key."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    out = []
    for i, leaf in enumerate(leaves):
        out.append(init_leaf(jax.random.fold_in(key, i), leaf))
    return jax.tree.unflatten(treedef, out)


def abstract_tree(tree):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        tree, is_leaf=is_spec)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def tree_axes(tree):
    """Same-structure tree of logical-axes tuples (for sharding rules)."""
    return jax.tree.map(lambda s: s.axes, tree, is_leaf=is_spec)


def cast_tree(params, dtype):
    def f(x):
        if isinstance(x, jax.Array) or isinstance(x, jax.ShapeDtypeStruct) \
                or hasattr(x, "dtype"):
            return x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) \
                else x
        return x
    return jax.tree.map(f, params)
