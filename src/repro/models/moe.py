"""Mixture-of-experts with capacity-based scatter dispatch (GShard-style).

Dispatch is built from scatters/gathers rather than the O(T·E·C) one-hot
einsum so the buffers stay at ``k/E`` of a dense-all-experts compute.
Expert weights are stacked on a leading ``experts`` axis → expert
parallelism falls out of the sharding rules ('experts' → 'model' when
divisible, else TP on the ff dim inside each expert).

Expert FFNs route through TT when the model's TTConfig covers the "ffn"
family: cores gain a leading experts axis and the chain is vmapped — the
paper's technique applied to expert stacks is a beyond-paper extension
(DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.distributed import sharding as shd
from repro.distributed.sharding import shard_act
from .layers import linear_spec, linear_apply, mlp_spec, mlp_apply
from .spec import ParamSpec, is_spec, stack

# jax >= 0.6 exposes shard_map at top level; older releases ship
# jax.experimental.shard_map.  The replication-check kwarg was renamed
# check_rep -> check_vma on its own schedule, so detect it by signature
# rather than by where shard_map lives.
try:
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
import inspect as _inspect

_SHARD_MAP_NOCHECK = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else {"check_rep": False})


def moe_spec(cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.moe
    d = cfg.d_model
    expert = mlp_spec(d, m.expert_ff, cfg.tt, dtype)
    # stack expert weights on a leading 'experts' axis
    def add_axis(s: ParamSpec) -> ParamSpec:
        import dataclasses
        return dataclasses.replace(s, shape=(m.num_experts,) + s.shape,
                                   axes=("experts",) + s.axes)
    experts = jax.tree.map(add_axis, expert, is_leaf=is_spec)
    out = {
        "router": ParamSpec((d, m.num_experts), ("embed", None), "normal",
                            1.0 / np.sqrt(d), dtype),
        "experts": experts,
    }
    if m.num_shared:
        out["shared"] = mlp_spec(d, m.shared_ff * m.num_shared, cfg.tt, dtype)
    return out


def _expert_mlp(experts_p, xs, backend):
    """xs [E, C, d] → [E, C, d] via per-expert GLU MLP (vmapped)."""
    return jax.vmap(lambda p, x: mlp_apply(p, x, backend))(experts_p, xs)


def dispatch_positions(e_flat: jax.Array, num_experts: int) -> jax.Array:
    """Position of each assignment within its expert's buffer, in flat
    (token-major) priority order — GShard semantics.

    Sort-based: a stable argsort by expert id preserves flat order within
    each expert, so `index_in_sorted − segment_start` IS the position.
    Replaces the cumsum-over-[T·k, E] formulation, which XLA lowers to an
    O(T·k·E·window) reduce-window — measured 93 % of the compiled MoE-layer
    FLOPs at 1M tokens (EXPERIMENTS.md §Perf, dsv2 hillclimb iter 1).
    """
    Tk = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)                      # [Tk]
    e_sorted = jnp.take(e_flat, order)
    seg_start = jnp.searchsorted(e_sorted, jnp.arange(num_experts))
    pos_sorted = jnp.arange(Tk, dtype=jnp.int32) \
        - seg_start[e_sorted].astype(jnp.int32)
    return jnp.zeros((Tk,), jnp.int32).at[order].set(pos_sorted)


def moe_apply(p, cfg: ModelConfig, x: jax.Array, backend="xla") -> jax.Array:
    """x [B, S, d] → [B, S, d]."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = xt @ p["router"]                                    # [T, E]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eidx = jax.lax.top_k(probs, m.top_k)                   # [T, k]
    gate = gate / jnp.sum(gate, -1, keepdims=True)

    C = int(np.ceil(m.top_k * T / m.num_experts * m.capacity_factor))
    # round capacity up to a lane multiple: keeps the buffer's capacity dim
    # shardable (E < model-axis archs shard C instead of E) and MXU-aligned
    C = max(-(-C // 128) * 128, 8) if T >= 128 else max(C, 8)
    e_flat = eidx.reshape(-1)
    pos_in_e = dispatch_positions(e_flat, m.num_experts)          # [T*k]
    keep = pos_in_e < C
    # overflow assignments point one past the end → dropped by mode="drop"
    pos_in_e = jnp.where(keep, pos_in_e, C)

    tok = jnp.repeat(jnp.arange(T), m.top_k)
    buf = jnp.zeros((m.num_experts, C, d), x.dtype)
    buf = buf.at[e_flat, pos_in_e].set(xt[tok], mode="drop")
    # experts → model when divisible (EP), else capacity → model
    buf = shard_act(buf, ("act_experts", "act_moe_cap", None))

    ys = _expert_mlp(p["experts"], buf, backend)                  # [E, C, d]
    ys = shard_act(ys, ("act_experts", "act_moe_cap", None))

    # gather back and combine with gate weights
    y_tok = ys.at[e_flat, jnp.minimum(pos_in_e, C - 1)].get(
        mode="fill", fill_value=0)                                # [T*k, d]
    y_tok = jnp.where(keep[:, None], y_tok, 0)
    w = gate.reshape(-1)[:, None].astype(y_tok.dtype)
    y = jnp.zeros_like(xt).at[tok].add(y_tok * w)

    if m.num_shared:
        y = y + mlp_apply(p["shared"], xt, backend)
    return y.reshape(B, S, d)


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map) — EXPERIMENTS.md §Perf iteration 2
# ---------------------------------------------------------------------------
#
# The global formulation above leaves the dispatch scatter to GSPMD, which
# (measured) replicates the [T·k, d] update tensor to every device — a
# 51 GB all-gather per MoE layer at 1M tokens.  Here the routing, the
# scatter AND the expert FFN are local to each (data, model) device and the
# only cross-device step is one psum over 'model':
#
#   case A (E % M == 0)  true EP: device j owns E/M experts; it scatters
#       only its experts' assignments; FFN weights arrive pre-sharded on
#       the experts axis; the psum returns rows to their token owners.
#   case B (E % M != 0, dense experts)  TP-inside-EP: every device holds
#       all experts' buffers but only ff/M of each weight matrix; the
#       down-projection partial sums ride the same psum.
#   case C (E % M != 0, TT experts)  capacity split: TT cores are tiny and
#       replicated (the paper's point), so each device computes complete
#       rows for the 1/M capacity slice `pos % M == j`.

def _experts_in_specs(cfg: ModelConfig, mesh, case: str):
    """shard_map in_specs for the expert-weight subtree."""
    spec_tree = moe_spec(cfg)["experts"]

    def f(s: ParamSpec):
        parts = [None] * len(s.shape)
        if case == "A":
            parts[0] = "model"                       # experts axis
        elif case == "B":
            if "ff" in s.axes:
                parts[s.axes.index("ff")] = "model"  # TP on ff
        # case C: fully replicated (TT cores)
        return P(*parts)

    return jax.tree.map(f, spec_tree, is_leaf=is_spec)


def moe_apply_ep(p, cfg: ModelConfig, x: jax.Array, backend="xla"
                 ) -> jax.Array:
    """Expert-parallel MoE.  Falls back to the global path when no mesh
    ctx is active or shapes don't divide."""
    ctx = shd.get_ctx()
    m = cfg.moe
    B, S, d = x.shape
    if ctx is None:
        return moe_apply(p, cfg, x, backend)
    mesh = ctx.mesh
    M = shd._axis_size(mesh, "model")
    batch_axes = shd._resolve_axis(mesh, ("pod", "data"))
    D = shd._axis_size(mesh, batch_axes)
    if M <= 1 or B % max(D, 1) != 0:
        return moe_apply(p, cfg, x, backend)

    tt = "tt" in p["experts"]["gate"] if "gate" in p["experts"] else False
    if m.num_experts % M == 0:
        case = "A"
    elif tt:
        case = "C"
    else:
        case = "B"

    E, k = m.num_experts, m.top_k
    T_loc = (B // max(D, 1)) * S
    # per-expert capacity per data shard; multiple of 8 (and of M in case C)
    C_e = int(np.ceil(k * T_loc / E * m.capacity_factor))
    mult = 8 * (M if case == "C" else 1)
    C_e = max(-(-C_e // mult) * mult, mult)

    E_own = E // M if case == "A" else E
    C_own = C_e // M if case == "C" else C_e

    def local_fn(x_loc, router_w, experts_p):
        j = jax.lax.axis_index("model")
        B_loc = x_loc.shape[0]
        xt = x_loc.reshape(B_loc * x_loc.shape[1], d)
        Tl = xt.shape[0]
        logits = xt @ router_w                               # [Tl, E]
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        gate, eidx = jax.lax.top_k(probs, k)
        gate = gate / jnp.sum(gate, -1, keepdims=True)
        e_flat = eidx.reshape(-1)
        pos = dispatch_positions(e_flat, E)                  # [Tl*k]
        tok = jnp.repeat(jnp.arange(Tl), k)

        if case == "A":
            e0 = j * E_own
            e_loc = e_flat - e0
            mine = (e_loc >= 0) & (e_loc < E_own) & (pos < C_e)
            row_e = jnp.where(mine, e_loc, 0)
            row_c = jnp.where(mine, pos, C_own)              # OOB → dropped
        elif case == "B":
            mine = pos < C_e
            row_e, row_c = e_flat, jnp.where(mine, pos, C_own)
        else:                                                # case C
            mine = (pos % M == j) & (pos < C_e)
            row_e = e_flat
            row_c = jnp.where(mine, pos // M, C_own)

        buf = jnp.zeros((E_own, C_own, d), x_loc.dtype)
        buf = buf.at[row_e, row_c].set(
            jnp.where(mine[:, None], xt[tok], 0), mode="drop")
        ys = _expert_mlp(experts_p, buf, backend)            # [E_own,C_own,d]
        y_tok = ys.at[row_e, jnp.minimum(row_c, C_own - 1)].get(
            mode="fill", fill_value=0)
        y_tok = jnp.where(mine[:, None], y_tok, 0)
        w = gate.reshape(-1)[:, None].astype(y_tok.dtype)
        y = jnp.zeros_like(xt).at[tok].add(y_tok * w)
        y = jax.lax.psum(y, "model")
        return y.reshape(x_loc.shape)

    bspec = P(batch_axes, None, None)
    fn = _shard_map(
        local_fn, mesh=mesh,
        in_specs=(bspec, P(None, None), _experts_in_specs(cfg, mesh, case)),
        out_specs=bspec, **_SHARD_MAP_NOCHECK)
    y = fn(x, p["router"], p["experts"])
    if m.num_shared:
        y = y + mlp_apply(p["shared"], x.reshape(-1, d), backend
                          ).reshape(x.shape)
    return y
