"""Mamba-2 (SSD — state-space duality) block.

Forward = chunked SSD (quadratic within chunks, linear recurrence across
chunks — the production algorithm); decode = O(1) recurrent update on a
persistent [B, H, N, P] state.  The in/out projections route through
``linear_spec`` — the paper's TT technique applies to the FC parts of the
block while the scan itself is untouched (DESIGN.md §5, mamba2 row).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_act
from .layers import linear_spec, linear_apply, rmsnorm_spec, rmsnorm_apply
from .spec import ParamSpec


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, heads, conv_dim


def ssm_spec(cfg: ModelConfig, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, heads, conv_dim = ssm_dims(cfg)
    in_dim = 2 * d_inner + 2 * s.n_groups * s.d_state + heads
    return {
        "in_proj": linear_spec(d, in_dim, cfg.tt, "ffn",
                               ("embed", "ssm_inner"), dtype),
        "conv_w": ParamSpec((s.d_conv, conv_dim), ("conv", "ssm_inner"),
                            "normal", 1.0 / np.sqrt(s.d_conv), dtype),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), "zeros", dtype=dtype),
        "A_log": ParamSpec((heads,), ("ssm_heads",), "zeros", dtype=dtype),
        "D": ParamSpec((heads,), ("ssm_heads",), "ones", dtype=dtype),
        "dt_bias": ParamSpec((heads,), ("ssm_heads",), "zeros", dtype=dtype),
        "norm": rmsnorm_spec(d_inner, "ssm_inner", dtype),
        "out_proj": linear_spec(d_inner, d, cfg.tt, "ffn",
                                ("ssm_inner", "embed"), dtype),
    }


def _split_in(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, heads, _ = ssm_dims(cfg)
    gN = s.n_groups * s.d_state
    z, xc, Bc, Cc, dt = jnp.split(
        zxbcdt, np.cumsum([d_inner, d_inner, gN, gN]).tolist(), axis=-1)
    return z, xc, Bc, Cc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, xbc [B,S,D], w [K,D]."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _segsum(a):
    """a [..., L] → lower-triangular cumulative sums S[i,j] = Σ_{j<k≤i} a_k."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    diff = cs[..., :, None] - cs[..., None, :]          # [..., i, j]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, s0=None):
    """Chunked SSD scan.

    x [B,S,H,P], dt [B,S,H] (post-softplus), A [H] (negative),
    Bm/Cm [B,S,G,N] with G dividing H.  ``s0`` (optional [B,H,N,P]) seeds
    the inter-chunk recurrence — the linear state recurrence is exact
    under any chunking, so running a sequence in pieces with the carried
    state is bitwise the same math as one pass (chunked-prefill resume).
    Returns y [B,S,H,P] and the final state [B,H,N,P].
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    # largest intra-chunk length <= ``chunk`` dividing S: arbitrary chunk
    # sizes (scheduler prefill chunks) stay exact instead of asserting
    L = min(chunk, S)
    while S % L:
        L -= 1
    nc = S // L
    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, L, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, L, H).astype(f32)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, L, G, N), rep, 3).astype(f32)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, L, G, N), rep, 3).astype(f32)
    a = dtc * A.astype(f32)                              # [B,nc,L,H] (log decay)
    a_t = a.transpose(0, 1, 3, 2)                        # [B,nc,H,L]
    a_cum = jnp.cumsum(a_t, -1)                          # Σ_{k≤l}

    # --- intra-chunk (quadratic within L) ---
    Lmat = jnp.exp(_segsum(a_t))                         # [B,nc,H,L,L]
    xdt = xc * dtc[..., None]
    Y_intra = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp",
                         Cc, Bc, Lmat, xdt)

    # --- chunk states ---
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)      # [B,nc,H,L]
    states = jnp.einsum("bclhn,bchl,bclhp->bchnp", Bc, decay_to_end, xdt)

    # --- inter-chunk recurrence (scan over nc) ---
    chunk_decay = jnp.exp(a_cum[..., -1])                # [B,nc,H]

    def step(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s_init = (jnp.zeros((Bsz, H, N, P), f32) if s0 is None
              else s0.astype(f32))
    s_final, s_prevs = jax.lax.scan(
        step, s_init, (states.transpose(1, 0, 2, 3, 4),
                       chunk_decay.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)           # [B,nc,H,N,P]

    decay_from_start = jnp.exp(a_cum)                    # [B,nc,H,L]
    Y_inter = jnp.einsum("bclhn,bchl,bchnp->bclhp",
                         Cc, decay_from_start, s_prevs)
    y = (Y_intra + Y_inter).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), s_final


def ssm_forward(p, cfg: ModelConfig, x, backend="xla", true_len=None,
                s0=None, conv_hist=None):
    """Full-sequence forward.  x [B,S,d] →
    (y [B,S,d], final_state, conv_tail [B, K-1, conv_dim]).

    ``conv_tail`` is the last K-1 *pre-conv* inputs — the decode path's conv
    ring must start from these, not from zeros, for prefill→decode parity.

    ``true_len`` (optional traced scalar) marks positions >= true_len as
    right-padding (bucketed prefill): their ``dt`` is forced to 0, which
    makes them exact no-ops on the recurrent state (decay exp(0·A)=1,
    input dt·B·x=0), and the conv tail is sliced at the true length — the
    returned state/tail are bitwise those of the unpadded sequence.

    ``s0`` [B,H,N,P] / ``conv_hist`` [B,K-1,conv_dim] resume a suffix from
    carried recurrent state + conv history (chunked prefill): the causal
    conv sees the real previous K-1 pre-conv rows instead of zero padding
    and the SSD scan is seeded with ``s0`` — exactly the state a single
    monolithic pass would have reached at this point.
    """
    s = cfg.ssm
    d_inner, heads, _ = ssm_dims(cfg)
    zxbcdt = linear_apply(p["in_proj"], x, backend)
    z, xc, Bc, Cc, dt = _split_in(cfg, zxbcdt)
    pre = jnp.concatenate([xc, Bc, Cc], -1)              # [B,S,conv_dim]
    K = s.d_conv
    if conv_hist is not None:
        full = jnp.concatenate([conv_hist.astype(pre.dtype), pre], 1)
    else:
        # left-pad K-1 zeros — the no-history case
        full = jnp.pad(pre, ((0, 0), (K - 1, 0), (0, 0)))
    if true_len is not None:
        # the K-1 rows ending at true_len are the tail (row t of ``pre``
        # sits at index K-1+t of ``full``, so the slice starts at true_len;
        # covers true_len < K-1 with the correct carried/zero history)
        conv_tail = jax.lax.dynamic_slice_in_dim(
            full, jnp.asarray(true_len, jnp.int32), K - 1, axis=1)
    else:
        conv_tail = full[:, pre.shape[1]:]
    Sx = pre.shape[1]
    out = sum(full[:, i:i + Sx, :] * p["conv_w"][i] for i in range(K))
    xbc = jax.nn.silu(out + p["conv_b"])
    xc, Bc, Cc = jnp.split(
        xbc, np.cumsum([d_inner, s.n_groups * s.d_state]).tolist(), axis=-1)
    B_, S, _ = x.shape
    xh = xc.reshape(B_, S, heads, s.head_dim)
    xh = shard_act(xh, ("act_batch", None, "act_heads", None))
    dt_ = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if true_len is not None:
        pad_ok = (jnp.arange(S) < true_len)[None, :, None]    # [1,S,1]
        dt_ = jnp.where(pad_ok, dt_, 0.0)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    Bm = Bc.reshape(B_, S, s.n_groups, s.d_state)
    Cm = Cc.reshape(B_, S, s.n_groups, s.d_state)
    y, state = ssd_chunked(xh, dt_, A, Bm, Cm, cfg.ssm.chunk, s0=s0)
    y = y + xh * p["D"][:, None]
    y = y.reshape(B_, S, d_inner)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return linear_apply(p["out_proj"], y, backend), state, conv_tail


def ssm_decode(p, cfg: ModelConfig, x, ssm_state, conv_state, backend="xla"):
    """One-token decode.  x [B,1,d]; ssm_state [B,H,N,P];
    conv_state [B, K-1, conv_dim] (ring of the last K-1 pre-conv inputs)."""
    s = cfg.ssm
    d_inner, heads, conv_dim = ssm_dims(cfg)
    B_ = x.shape[0]
    zxbcdt = linear_apply(p["in_proj"], x, backend)
    z, xc, Bc, Cc, dt = _split_in(cfg, zxbcdt)
    xbc_new = jnp.concatenate([xc, Bc, Cc], -1)          # [B,1,conv_dim]
    hist = jnp.concatenate([conv_state, xbc_new], 1)     # [B,K,conv_dim]
    out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(out)[:, None, :]
    conv_state = hist[:, 1:]
    xc, Bc, Cc = jnp.split(
        xbc, np.cumsum([d_inner, s.n_groups * s.d_state]).tolist(), axis=-1)
    xh = xc.reshape(B_, heads, s.head_dim).astype(jnp.float32)
    dt_ = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    rep = heads // s.n_groups
    Bm = jnp.repeat(Bc[:, 0].reshape(B_, s.n_groups, s.d_state), rep, 1)
    Cm = jnp.repeat(Cc[:, 0].reshape(B_, s.n_groups, s.d_state), rep, 1)
    dA = jnp.exp(dt_ * A)                                # [B,H]
    dBx = jnp.einsum("bhn,bhp,bh->bhnp", Bm.astype(jnp.float32), xh, dt_)
    ssm_state = ssm_state * dA[..., None, None] + dBx
    y = jnp.einsum("bhn,bhnp->bhp", Cm.astype(jnp.float32), ssm_state)
    y = y + xh * p["D"][:, None]
    y = y.reshape(B_, 1, d_inner).astype(x.dtype)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return linear_apply(p["out_proj"], y, backend), ssm_state, conv_state
