"""Model facade: one object per architecture exposing the four entry points
the launcher lowers — ``loss`` (train), ``prefill``, ``decode_step`` and
``init_cache`` — plus param-spec/init plumbing.

The layer plan (groups of scanned periods) comes from the arch config
(configs/<arch>.py::layer_plan); multimodal frontends are stubs operating on
precomputed embeddings supplied by input_specs (per the assignment brief).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_act
from repro.kernels.plan import PlanBook
from .layers import (embed_apply, embed_spec, linear_apply, linear_spec,
                     quantize_tt_params, rmsnorm_apply, rmsnorm_spec)
from .spec import ParamSpec, abstract_tree, count_params, init_tree
from .transformer import (BlockDef, Group, block_cache_shape, group_decode,
                          group_fwd, group_spec)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    groups: list[Group]                  # decoder (or only) stack
    enc_groups: list[Group] | None = None
    param_dtype: Any = jnp.float32
    # jitted entry-point cache: serving calls generate() repeatedly; the
    # jit wrappers must be built once per model (not per call) or every
    # generate() retraces prefill + decode_step from scratch.  The cache is
    # a bounded LRU: a long-running server sees arbitrarily many distinct
    # prompt/cache lengths, and every distinct ``cache_len`` keys a separate
    # jitted prefill (trace + compiled executable) — unbounded, that's a
    # slow leak.  Decode/splice entries (a handful, shape-stable) share the
    # same LRU but in practice never fall out of a size-8 window.
    jit_cache_size: int = 8
    _jit_cache: collections.OrderedDict = dataclasses.field(
        default_factory=collections.OrderedDict, repr=False, compare=False)
    # Per-model TT execution-plan registry (kernels.plan, DESIGN.md §10):
    # built lazily on first use from the TTConfig + param dtype, primed
    # from the param-spec tree so every TT layer's plan is resolved
    # exactly once at build time — prefill/decode traces and the serving
    # scheduler perform ZERO plan resolutions.
    _plan_book: Any = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def plan_book(self) -> PlanBook:
        if self._plan_book is None:
            book = PlanBook.from_tt_config(self.cfg.tt, self.param_dtype)
            book.prime(self.param_specs())
            self._plan_book = book
        return self._plan_book

    def _jit_get(self, key, build):
        """LRU lookup: hit refreshes recency, miss builds and may evict."""
        fn = self._jit_cache.get(key)
        if fn is not None:
            self._jit_cache.move_to_end(key)
            return fn
        fn = build()
        self._jit_cache[key] = fn
        while len(self._jit_cache) > max(self.jit_cache_size, 1):
            self._jit_cache.popitem(last=False)
        return fn

    # ------------------------------------------------------------------ specs
    def param_specs(self) -> dict:
        cfg, dt = self.cfg, self.param_dtype
        specs: dict = {"embed": embed_spec(cfg.vocab_size, cfg.d_model, dt),
                       "final_norm": rmsnorm_spec(cfg.d_model, "embed", dt)}
        if not cfg.tie_embeddings:
            specs["lm_head"] = linear_spec(cfg.d_model, cfg.vocab_size,
                                           cfg.tt, "lm_head",
                                           ("embed", "vocab"), dt)
        for gi, g in enumerate(self.groups):
            specs[f"g{gi}"] = group_spec(cfg, g, dt)
        if self.enc_groups is not None:
            specs["enc_norm"] = rmsnorm_spec(cfg.d_model, "embed", dt)
            for gi, g in enumerate(self.enc_groups):
                specs[f"enc_g{gi}"] = group_spec(cfg, g, dt)
        if cfg.frontend == "vit":
            specs["projector"] = linear_spec(cfg.frontend_dim, cfg.d_model,
                                             None, "frontend",
                                             (None, "embed"), dt)
        if cfg.frontend == "speech":
            specs["frontend_proj"] = linear_spec(cfg.frontend_dim,
                                                 cfg.d_model, None,
                                                 "frontend", (None, "embed"),
                                                 dt)
        return specs

    def init(self, key: jax.Array) -> dict:
        return init_tree(key, self.param_specs())

    def abstract_params(self) -> dict:
        return abstract_tree(self.param_specs())

    def num_params(self) -> int:
        return count_params(self.param_specs())

    def quantize_params(self, params: dict) -> dict:
        """int8-quantize every TT core bundle of a parameter tree
        (checkpoint transform, DESIGN.md §8).  The returned tree is served
        by the same entry points — prefill, decode_step and the
        continuous-batching scheduler all route through ``linear_apply``,
        which detects the int8 storage and runs the int8-resident kernel
        path."""
        return quantize_tt_params(params)

    # -------------------------------------------------------------- embedding
    def _embed_inputs(self, params, batch) -> tuple[jax.Array, jax.Array]:
        """Returns (x [B,S,d], loss_mask [B,S])."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed_apply(params["embed"], tokens, cfg.d_model,
                        scale=cfg.tie_embeddings)
        mask = jnp.ones(tokens.shape, bool)
        if cfg.frontend == "vit":
            img = linear_apply(params["projector"], batch["image_embeds"])
            x = jnp.concatenate([img.astype(x.dtype), x], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros(img.shape[:2], bool), mask], axis=1)
        return x, mask

    def _encode(self, params, batch) -> jax.Array:
        """Seamless encoder over precomputed speech-frame embeddings."""
        cfg = self.cfg
        frames = batch["speech_embeds"]
        x = linear_apply(params["frontend_proj"], frames)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        for gi, g in enumerate(self.enc_groups):
            x, _ = group_fwd(params[f"enc_g{gi}"], cfg, g, x, positions,
                             want_cache=False, plans=self.plan_book)
        return rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)

    def _logits(self, params, x) -> jax.Array:
        cfg = self.cfg
        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = x @ params["embed"]["table"].T
        else:
            logits = linear_apply(params["lm_head"], x, self.plan_book)
        return shard_act(logits.astype(jnp.float32),
                         ("act_batch", None, "act_vocab"))

    # ------------------------------------------------------------------ train
    def loss(self, params, batch, remat: bool = True) -> jax.Array:
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.enc_dec else None
        x, mask = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        x = shard_act(x, ("act_batch", "act_seq", "act_embed"))
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        for gi, g in enumerate(self.groups):
            x, _ = group_fwd(params[f"g{gi}"], cfg, g, x, positions,
                             enc_out=enc_out, want_cache=False, remat=remat,
                             plans=self.plan_book)
        logits = self._logits(params, x)
        tokens = batch["tokens"]
        off = S - tokens.shape[1]                    # frontend prefix length
        lg = logits[:, off:, :][:, :-1]
        tgt = tokens[:, 1:]
        msk = mask[:, off:][:, 1:]
        lse = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * msk
        return jnp.sum(nll) / jnp.maximum(jnp.sum(msk), 1)

    # ---------------------------------------------------------------- serving
    def prefill(self, params, batch) -> tuple[jax.Array, dict]:
        """Process the full prompt; return (last-token logits, cache)."""
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.enc_dec else None
        x, _ = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        cache: dict = {"pos": jnp.asarray(S, jnp.int32)}
        T = batch.get("cache_len", S)
        for gi, g in enumerate(self.groups):
            x, c = group_fwd(params[f"g{gi}"], cfg, g, x, positions,
                             enc_out=enc_out, want_cache=True, T_cache=T,
                             plans=self.plan_book)
            cache[f"g{gi}"] = c
        logits = self._logits(params, x[:, -1:, :])
        return logits, cache

    def decode_step(self, params, cache: dict, token: jax.Array,
                    active: jax.Array | None = None
                    ) -> tuple[jax.Array, dict]:
        """token [B,1] int32 → (logits [B,1,V], updated cache).

        ``cache["pos"]`` may be a scalar (classic fixed batch: every row at
        the same depth) or a per-row vector [B] (continuous-batching slot
        pool).  With vector positions an optional ``active`` mask [B] bool
        freezes retired/free slots: their position does not advance, so
        they re-write the same (dead) cache row every step until an
        admission splices fresh state over them.
        """
        cfg = self.cfg
        pos = cache["pos"]
        x = embed_apply(params["embed"], token, cfg.d_model,
                        scale=cfg.tie_embeddings)
        inc = 1 if active is None else active.astype(pos.dtype)
        new_cache = {"pos": pos + inc}
        for gi, g in enumerate(self.groups):
            x, c = group_decode(params[f"g{gi}"], cfg, g, x,
                                cache[f"g{gi}"], pos,
                                plans=self.plan_book)
            new_cache[f"g{gi}"] = c
        logits = self._logits(params, x)
        return logits, new_cache

    def splice_cache(self, cache: dict, row_cache: dict, slot) -> dict:
        """Write a single-request cache (batch dim 1, same ``cache_len``)
        into row ``slot`` of a slot-pool cache — the admission path of the
        continuous-batching scheduler.  Every leaf except ``pos`` is
        [layers, B, ...] (batch at axis 1); ``pos`` is [B] in the pool and
        a scalar (the prompt length) in the prefill output."""
        out = {"pos": cache["pos"].at[slot].set(
            row_cache["pos"].astype(cache["pos"].dtype))}
        for k, v in cache.items():
            if k == "pos":
                continue
            out[k] = jax.tree.map(
                lambda pool, new: pool.at[:, slot].set(
                    new[:, 0].astype(pool.dtype)), v, row_cache[k])
        return out

    # --------------------------------------------------- jitted entry points
    def jitted_prefill(self, cache_len: int | None = None,
                       shape_key=None):
        """jit(prefill) with the static ``cache_len`` closed over, cached
        per (model, cache_len) so repeated generate() calls reuse traces.

        ``shape_key`` splits the LRU entry further (the scheduler passes
        the prompt length): a jax.jit wrapper retains one executable per
        input shape it has seen, so a single long-lived wrapper fed many
        prompt lengths would accumulate them beyond the LRU's reach —
        per-length entries make eviction actually free the executables."""
        def build():
            def prefill(params, arrays):
                b = (dict(arrays, cache_len=cache_len)
                     if cache_len is not None else arrays)
                return self.prefill(params, b)
            return jax.jit(prefill)
        return self._jit_get(("prefill", cache_len, shape_key), build)

    def jitted_decode_step(self):
        """jit(decode_step) with the cache donated, cached per model."""
        return self._jit_get(
            "decode_step",
            lambda: jax.jit(lambda params, cache, token:
                            self.decode_step(params, cache, token),
                            donate_argnums=(1,)))

    def jitted_decode_step_masked(self):
        """jit(decode_step) with a per-slot ``active`` mask (vector-pos
        slot-pool cache), cache donated."""
        return self._jit_get(
            "decode_step_masked",
            lambda: jax.jit(self.decode_step, donate_argnums=(1,)))

    def jitted_splice(self):
        """jit(splice_cache) with the pool cache donated: admission writes
        one row in place instead of copying the whole pool."""
        return self._jit_get(
            "splice",
            lambda: jax.jit(self.splice_cache, donate_argnums=(0,)))

    # --------------------------------------------------------------- caching
    def cache_shapes(self, B: int, T: int, enc_T: int = 0,
                     dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct tree of a decode cache at context length T."""
        cfg = self.cfg
        out: dict = {"pos": jax.ShapeDtypeStruct((), jnp.int32)}
        for gi, (period, count) in enumerate(self.groups):
            g = {}
            for i, bd in enumerate(period):
                g[f"b{i}"] = block_cache_shape(cfg, bd, B, T, enc_T, dtype)
            out[f"g{gi}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((count,) + s.shape, s.dtype),
                g)
        return out

    def init_cache(self, B: int, T: int, enc_T: int = 0,
                   dtype=jnp.bfloat16) -> dict:
        shapes = self.cache_shapes(B, T, enc_T, dtype)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def build_model(cfg: ModelConfig, layer_plan: list[Group],
                enc_plan: list[Group] | None = None,
                param_dtype=jnp.float32) -> Model:
    return Model(cfg, layer_plan, enc_plan, param_dtype)
