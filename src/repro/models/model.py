"""Model facade: one object per architecture exposing the four entry points
the launcher lowers — ``loss`` (train), ``prefill``, ``decode_step`` and
``init_cache`` — plus param-spec/init plumbing.

The layer plan (groups of scanned periods) comes from the arch config
(configs/<arch>.py::layer_plan); multimodal frontends are stubs operating on
precomputed embeddings supplied by input_specs (per the assignment brief).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_act
from repro.kernels.plan import PlanBook
from .layers import (embed_apply, embed_spec, linear_apply, linear_spec,
                     quantize_tt_params, rmsnorm_apply, rmsnorm_spec)
from .spec import ParamSpec, abstract_tree, count_params, init_tree
from .transformer import (BlockDef, Group, block_cache_kinds,
                          block_cache_shape, block_paged_cache_shape,
                          group_chunk, group_decode, group_fwd,
                          group_resume, group_spec)


def bucket_length(S: int, limit: int, floor: int = 16) -> int:
    """Prompt-length bucket: next power of two >= S (min ``floor``),
    clamped to ``limit`` — varied-length traffic compiles O(log limit)
    prefill variants instead of one per distinct length."""
    if S > limit:
        raise ValueError(f"prompt length {S} exceeds cache length {limit}")
    b = max(floor, 1 << max(S - 1, 0).bit_length())
    return min(b, limit)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    groups: list[Group]                  # decoder (or only) stack
    enc_groups: list[Group] | None = None
    param_dtype: Any = jnp.float32
    # jitted entry-point cache: serving calls generate() repeatedly; the
    # jit wrappers must be built once per model (not per call) or every
    # generate() retraces prefill + decode_step from scratch.  The cache is
    # a bounded LRU: a long-running server sees arbitrarily many distinct
    # prompt/cache lengths, and every distinct ``cache_len`` keys a separate
    # jitted prefill (trace + compiled executable) — unbounded, that's a
    # slow leak.  Decode/splice entries (a handful, shape-stable) share the
    # same LRU but in practice never fall out of a size-8 window.
    jit_cache_size: int = 8
    _jit_cache: collections.OrderedDict = dataclasses.field(
        default_factory=collections.OrderedDict, repr=False, compare=False)
    # Per-model TT execution-plan registry (kernels.plan, DESIGN.md §10):
    # built lazily on first use from the TTConfig + param dtype, primed
    # from the param-spec tree so every TT layer's plan is resolved
    # exactly once at build time — prefill/decode traces and the serving
    # scheduler perform ZERO plan resolutions.
    _plan_book: Any = dataclasses.field(
        default=None, repr=False, compare=False)
    # prefill trace/compile counter: every jitted-prefill build (exact or
    # bucketed) increments it, so tests can assert bucketing bounds the
    # number of compiled variants to O(log cache_len)
    prefill_builds: int = 0

    @property
    def plan_book(self) -> PlanBook:
        if self._plan_book is None:
            book = PlanBook.from_tt_config(self.cfg.tt, self.param_dtype)
            book.prime(self.param_specs())
            self._plan_book = book
        return self._plan_book

    def _jit_get(self, key, build):
        """LRU lookup: hit refreshes recency, miss builds and may evict."""
        fn = self._jit_cache.get(key)
        if fn is not None:
            self._jit_cache.move_to_end(key)
            return fn
        fn = build()
        self._jit_cache[key] = fn
        while len(self._jit_cache) > max(self.jit_cache_size, 1):
            self._jit_cache.popitem(last=False)
        return fn

    # ------------------------------------------------------------------ specs
    def param_specs(self) -> dict:
        cfg, dt = self.cfg, self.param_dtype
        specs: dict = {"embed": embed_spec(cfg.vocab_size, cfg.d_model, dt),
                       "final_norm": rmsnorm_spec(cfg.d_model, "embed", dt)}
        if not cfg.tie_embeddings:
            specs["lm_head"] = linear_spec(cfg.d_model, cfg.vocab_size,
                                           cfg.tt, "lm_head",
                                           ("embed", "vocab"), dt)
        for gi, g in enumerate(self.groups):
            specs[f"g{gi}"] = group_spec(cfg, g, dt)
        if self.enc_groups is not None:
            specs["enc_norm"] = rmsnorm_spec(cfg.d_model, "embed", dt)
            for gi, g in enumerate(self.enc_groups):
                specs[f"enc_g{gi}"] = group_spec(cfg, g, dt)
        if cfg.frontend == "vit":
            specs["projector"] = linear_spec(cfg.frontend_dim, cfg.d_model,
                                             None, "frontend",
                                             (None, "embed"), dt)
        if cfg.frontend == "speech":
            specs["frontend_proj"] = linear_spec(cfg.frontend_dim,
                                                 cfg.d_model, None,
                                                 "frontend", (None, "embed"),
                                                 dt)
        return specs

    def init(self, key: jax.Array) -> dict:
        return init_tree(key, self.param_specs())

    def abstract_params(self) -> dict:
        return abstract_tree(self.param_specs())

    def num_params(self) -> int:
        return count_params(self.param_specs())

    def quantize_params(self, params: dict) -> dict:
        """int8-quantize every TT core bundle of a parameter tree
        (checkpoint transform, DESIGN.md §8).  The returned tree is served
        by the same entry points — prefill, decode_step and the
        continuous-batching scheduler all route through ``linear_apply``,
        which detects the int8 storage and runs the int8-resident kernel
        path."""
        return quantize_tt_params(params)

    # -------------------------------------------------------------- embedding
    def _embed_inputs(self, params, batch) -> tuple[jax.Array, jax.Array]:
        """Returns (x [B,S,d], loss_mask [B,S])."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed_apply(params["embed"], tokens, cfg.d_model,
                        scale=cfg.tie_embeddings)
        mask = jnp.ones(tokens.shape, bool)
        if cfg.frontend == "vit":
            img = linear_apply(params["projector"], batch["image_embeds"])
            x = jnp.concatenate([img.astype(x.dtype), x], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros(img.shape[:2], bool), mask], axis=1)
        return x, mask

    def _encode(self, params, batch) -> jax.Array:
        """Seamless encoder over precomputed speech-frame embeddings."""
        cfg = self.cfg
        frames = batch["speech_embeds"]
        x = linear_apply(params["frontend_proj"], frames)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        for gi, g in enumerate(self.enc_groups):
            x, _ = group_fwd(params[f"enc_g{gi}"], cfg, g, x, positions,
                             want_cache=False, plans=self.plan_book)
        return rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)

    def _logits(self, params, x) -> jax.Array:
        cfg = self.cfg
        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = x @ params["embed"]["table"].T
        else:
            logits = linear_apply(params["lm_head"], x, self.plan_book)
        return shard_act(logits.astype(jnp.float32),
                         ("act_batch", None, "act_vocab"))

    # ------------------------------------------------------------------ train
    def loss(self, params, batch, remat: bool = True) -> jax.Array:
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.enc_dec else None
        x, mask = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        x = shard_act(x, ("act_batch", "act_seq", "act_embed"))
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        for gi, g in enumerate(self.groups):
            x, _ = group_fwd(params[f"g{gi}"], cfg, g, x, positions,
                             enc_out=enc_out, want_cache=False, remat=remat,
                             plans=self.plan_book)
        logits = self._logits(params, x)
        tokens = batch["tokens"]
        off = S - tokens.shape[1]                    # frontend prefix length
        lg = logits[:, off:, :][:, :-1]
        tgt = tokens[:, 1:]
        msk = mask[:, off:][:, 1:]
        lse = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * msk
        return jnp.sum(nll) / jnp.maximum(jnp.sum(msk), 1)

    def activation_stats(self, params, batches: list[dict]) -> dict:
        """Per-projection input second moments over a calibration set —
        the data term of activation-aware DSE scoring (DESIGN.md §12).

        Runs the training forward *eagerly* (``remat=False``, no jit)
        under ``layers.capture_activation_stats`` so every
        ``linear_apply`` streams its input Gram matrix to the host; scan
        and vmap inside the stack are fine (the accumulator is
        order-invariant).  Returns ``{(N, M): {"sigma": [N, N] float64,
        "count": rows}}`` where sigma = E[x xᵀ] aggregated across all
        layers sharing that projection shape."""
        from .layers import capture_activation_stats
        with capture_activation_stats() as store:
            with jax.disable_jit():
                for b in batches:
                    self.loss(params, b, remat=False)
            jax.effects_barrier()
        return {key: {"sigma": slot["gram"] / max(slot["count"], 1.0),
                      "count": slot["count"]}
                for key, slot in store.items()}

    # ---------------------------------------------------------------- serving
    def prefill(self, params, batch) -> tuple[jax.Array, dict]:
        """Process the full prompt; return (last-token logits, cache).

        ``batch["prompt_len"]`` (optional, a traced int32 scalar) marks the
        true sequence length when the prompt was right-padded to a bucket
        (``bucket_length``): the window ring and SSM state are built at the
        true write head, ``cache["pos"]`` is the true length, and the
        logits are taken at position prompt_len - 1 — padded junk rows in
        full/MLA caches sit beyond ``pos`` and are masked by every decode
        path."""
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.enc_dec else None
        x, _ = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        plen = batch.get("prompt_len")
        cache: dict = {"pos": (jnp.asarray(S, jnp.int32) if plen is None
                               else jnp.asarray(plen, jnp.int32))}
        T = batch.get("cache_len", S)
        for gi, g in enumerate(self.groups):
            x, c = group_fwd(params[f"g{gi}"], cfg, g, x, positions,
                             enc_out=enc_out, want_cache=True, T_cache=T,
                             plans=self.plan_book, true_len=plen)
            cache[f"g{gi}"] = c
        if plen is None:
            xl = x[:, -1:, :]
        else:
            xl = jax.lax.dynamic_slice_in_dim(
                x, jnp.asarray(plen, jnp.int32) - 1, 1, axis=1)
        logits = self._logits(params, xl)
        return logits, cache

    def decode_step(self, params, cache: dict, token: jax.Array,
                    active: jax.Array | None = None
                    ) -> tuple[jax.Array, dict]:
        """token [B,1] int32 → (logits [B,1,V], updated cache).

        ``cache["pos"]`` may be a scalar (classic fixed batch: every row at
        the same depth) or a per-row vector [B] (continuous-batching slot
        pool).  With vector positions an optional ``active`` mask [B] bool
        freezes retired/free slots: their position does not advance, so
        they re-write the same (dead) cache row every step until an
        admission splices fresh state over them.

        A cache carrying ``block_tables`` is block-paged (DESIGN.md §7):
        attention leaves are arenas addressed through the per-slot table,
        and inactive slots' writes are redirected to the sentinel block —
        a retired slot's stale table must never touch storage reused by a
        later request.
        """
        cfg = self.cfg
        pos = cache["pos"]
        bt = cache.get("block_tables")
        x = embed_apply(params["embed"], token, cfg.d_model,
                        scale=cfg.tie_embeddings)
        inc = 1 if active is None else active.astype(pos.dtype)
        new_cache = {"pos": pos + inc}
        paged = None
        if bt is not None:
            new_cache["block_tables"] = bt
            act = (jnp.ones(pos.shape, bool) if active is None else active)
            paged = (bt, act)
        for gi, g in enumerate(self.groups):
            x, c = group_decode(params[f"g{gi}"], cfg, g, x,
                                cache[f"g{gi}"], pos,
                                plans=self.plan_book, paged=paged,
                                active=active)
            new_cache[f"g{gi}"] = c
        logits = self._logits(params, x)
        return logits, new_cache

    def splice_cache(self, cache: dict, row_cache: dict, slot) -> dict:
        """Write a single-request cache (batch dim 1, same ``cache_len``)
        into row ``slot`` of a slot-pool cache — the admission path of the
        continuous-batching scheduler.  Every leaf except ``pos`` is
        [layers, B, ...] (batch at axis 1); ``pos`` is [B] in the pool and
        a scalar (the prompt length) in the prefill output."""
        out = {"pos": cache["pos"].at[slot].set(
            row_cache["pos"].astype(cache["pos"].dtype))}
        for k, v in cache.items():
            if k == "pos":
                continue
            out[k] = jax.tree.map(
                lambda pool, new: pool.at[:, slot].set(
                    new[:, 0].astype(pool.dtype)), v, row_cache[k])
        return out

    def splice_cache_paged(self, cache: dict, row_cache: dict, slot,
                           blocks) -> dict:
        """Paged twin of :meth:`splice_cache`: scatter a single-request
        dense row cache (prefilled at the pool's logical ``cache_len``)
        into the arena blocks named by ``blocks`` [max_blocks] int32 (the
        slot's full table row, sentinel-padded past its allocation — the
        junk scattered there collapses onto the scratch block).  'slot'
        leaves (SSM state/conv, cross-attn KV) splice per-slot as before.
        """
        out = {"pos": cache["pos"].at[slot].set(
            row_cache["pos"].astype(cache["pos"].dtype)),
            "block_tables": cache["block_tables"].at[slot].set(
                blocks.astype(cache["block_tables"].dtype))}
        for gi, (period, _count) in enumerate(self.groups):
            g_new = {}
            for i, bd in enumerate(period):
                kinds = block_cache_kinds(bd)
                b_new = {}
                for name, pool in cache[f"g{gi}"][f"b{i}"].items():
                    row = row_cache[f"g{gi}"][f"b{i}"][name]
                    if kinds[name] == "slot":
                        b_new[name] = pool.at[:, slot].set(
                            row[:, 0].astype(pool.dtype))
                        continue
                    blk = pool.shape[2]
                    r = row[:, 0]                     # [layers, T_row, ...]
                    T_row = r.shape[1]
                    nblk = -(-T_row // blk)
                    pad = nblk * blk - T_row
                    if pad:
                        r = jnp.pad(r, ((0, 0), (0, pad))
                                    + ((0, 0),) * (r.ndim - 2))
                    r = r.reshape(r.shape[0], nblk, blk, *r.shape[2:])
                    b_new[name] = pool.at[:, blocks[:nblk]].set(
                        r.astype(pool.dtype))
                g_new[f"b{i}"] = b_new
            out[f"g{gi}"] = g_new
        return out

    def prefill_resume(self, params, arrays, cache: dict, slot, src_blocks,
                       dst_blocks, start, true_suf) -> tuple[jax.Array,
                                                             dict]:
        """Prefix-reuse admission (DESIGN.md §7): run prefill over only the
        *suffix* tokens (``arrays["tokens"]`` [1, S_pad], right-padded,
        ``true_suf`` real) starting at absolute position ``start``; the
        covered prefix is gathered from resident arena blocks through
        ``src_blocks`` and never recomputed.  The updated logical cache is
        scattered back through ``dst_blocks`` — entries differing from
        ``src_blocks`` are the copy-on-write blocks.  Returns (last-token
        logits [1,1,V], updated pool cache)."""
        cfg = self.cfg
        x, _ = self._embed_inputs(params, arrays)
        start = jnp.asarray(start, jnp.int32)
        new_cache = {
            "pos": cache["pos"].at[slot].set(
                (start + true_suf).astype(cache["pos"].dtype)),
            "block_tables": cache["block_tables"].at[slot].set(
                dst_blocks.astype(cache["block_tables"].dtype))}
        for gi, g in enumerate(self.groups):
            x, c = group_resume(params[f"g{gi}"], cfg, g, x,
                                cache[f"g{gi}"], src_blocks, dst_blocks,
                                start, plans=self.plan_book)
            new_cache[f"g{gi}"] = c
        xl = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(true_suf, jnp.int32) - 1, 1, axis=1)
        logits = self._logits(params, xl)
        return logits, new_cache

    def chunk_step(self, params, cache: dict, tokens, slot, start, true_len,
                   active, table=None) -> tuple[jax.Array, dict]:
        """One prefill chunk of one slot, in place in the serving pool.

        ``tokens`` [1, C] (rows >= true_len are right-padding) are the
        prompt slice [start, start + true_len); ``slot`` addresses the pool
        row, ``table`` [max_blocks] the paged arenas (None = dense layout;
        pass it sentinel-redirected when ``active`` is False).  ``active``
        (scalar bool) makes an unused lane a no-op by value.  Returns
        (logits [1,1,V] at position start + true_len - 1, updated cache) —
        the logits matter only on the final chunk, where the scheduler
        picks the first generated token from them.
        """
        cfg = self.cfg
        x = embed_apply(params["embed"], tokens, cfg.d_model,
                        scale=cfg.tie_embeddings)
        slot = jnp.asarray(slot, jnp.int32)
        start = jnp.asarray(start, jnp.int32)
        true_len = jnp.asarray(true_len, jnp.int32)
        pos = cache["pos"]
        new_cache = {"pos": pos.at[slot].set(jnp.where(
            active, (start + true_len).astype(pos.dtype), pos[slot]))}
        if table is not None:
            bt = cache["block_tables"]
            new_cache["block_tables"] = bt.at[slot].set(jnp.where(
                active, table.astype(bt.dtype), bt[slot]))
        for gi, g in enumerate(self.groups):
            x, c = group_chunk(params[f"g{gi}"], cfg, g, x, cache[f"g{gi}"],
                               slot, table, start, true_len, active,
                               plans=self.plan_book)
            new_cache[f"g{gi}"] = c
        xl = jax.lax.dynamic_slice_in_dim(x, true_len - 1, 1, axis=1)
        logits = self._logits(params, xl)
        return logits, new_cache

    def mixed_step(self, params, cache: dict, token, active, ck_tokens,
                   ck_slot, ck_start, ck_true, ck_active, ck_tables=None
                   ) -> tuple[jax.Array, jax.Array, dict]:
        """Fused serving step: K prefill-chunk lanes + the masked decode
        pass, one traced program (the chunked-prefill tentpole).

        ck_tokens [K, C] int32, ck_slot/ck_start/ck_true [K] int32,
        ck_active [K] bool, ck_tables [K, max_blocks] int32 (paged pools
        only; rows of unused lanes must be sentinel-filled).  Chunk lanes
        run before the decode pass, so a lane finishing its prompt this
        step is decodable the next; the decode pass masks every per-slot
        write with ``active``, leaving mid-prefill rows untouched.
        Returns (decode logits [B,1,V], chunk logits [K,V] at each lane's
        last true position, updated cache)."""
        K = ck_tokens.shape[0]
        ck_logits = []
        for j in range(K):
            tbl = None if ck_tables is None else ck_tables[j]
            lg, cache = self.chunk_step(
                params, cache, ck_tokens[j:j + 1], ck_slot[j], ck_start[j],
                ck_true[j], ck_active[j], table=tbl)
            ck_logits.append(lg[0, 0])
        dec_logits, cache = self.decode_step(params, cache, token, active)
        return dec_logits, jnp.stack(ck_logits), cache

    def copy_blocks(self, cache: dict, src, dst) -> dict:
        """Copy one arena block's content ``src`` → ``dst`` in every
        pageable leaf — the eager COW at chunked admission with a
        fully-covered prefix (the last matched block is about to be
        partially overwritten through the slot's own table)."""
        out = dict(cache)
        for gi, (period, _count) in enumerate(self.groups):
            g_new = {}
            for i, bd in enumerate(period):
                kinds = block_cache_kinds(bd)
                b_new = {}
                for name, pool in cache[f"g{gi}"][f"b{i}"].items():
                    if kinds[name] == "slot":
                        b_new[name] = pool
                    else:
                        b_new[name] = pool.at[:, dst].set(pool[:, src])
                g_new[f"b{i}"] = b_new
            out[f"g{gi}"] = g_new
        return out

    @property
    def supports_chunked_prefill(self) -> bool:
        """Chunked prefill covers every self-mixer — full attention, MLA,
        windowed-ring (history-gathered), SSM (state-threaded) — but not
        enc-dec cross-attention or multimodal frontends, whose admission
        stays monolithic."""
        if self.cfg.enc_dec or self.cfg.frontend is not None:
            return False
        return all(not bd.cross
                   for period, _count in self.groups for bd in period)

    @property
    def supports_prefix_reuse(self) -> bool:
        """Prefix blocks are shareable only when every mixer's cache rows
        are pure functions of the token prefix *and* are never overwritten
        in place: full attention and MLA qualify; window rings (contents
        cycle), SSM state (whole-history summary) and enc-dec/multimodal
        frontends do not."""
        if self.cfg.enc_dec or self.cfg.frontend is not None:
            return False
        for period, _count in self.groups:
            for bd in period:
                if bd.mixer == "ssm" or bd.cross or (
                        bd.mixer == "gqa" and bd.window):
                    return False
        return True

    # --------------------------------------------------- jitted entry points
    def jitted_prefill(self, cache_len: int | None = None,
                       shape_key=None):
        """jit(prefill) with the static ``cache_len`` closed over, cached
        per (model, cache_len) so repeated generate() calls reuse traces.

        ``shape_key`` splits the LRU entry further (the scheduler passes
        the prompt length): a jax.jit wrapper retains one executable per
        input shape it has seen, so a single long-lived wrapper fed many
        prompt lengths would accumulate them beyond the LRU's reach —
        per-length entries make eviction actually free the executables."""
        def build():
            self.prefill_builds += 1

            def prefill(params, arrays):
                b = (dict(arrays, cache_len=cache_len)
                     if cache_len is not None else arrays)
                return self.prefill(params, b)
            return jax.jit(prefill)
        return self._jit_get(("prefill", cache_len, shape_key), build)

    def jitted_prefill_bucketed(self, cache_len: int):
        """Host wrapper around jit(prefill) with prompt-length bucketing:
        the token prompt is right-padded to the next power of two (min 16,
        clamped to the cache length) and the true length rides along as a
        traced scalar, so varied-length traffic compiles O(log cache_len)
        prefill variants (``prefill_builds`` counts them) instead of one
        per distinct prompt length."""
        def build_for(S_pad):
            def build():
                self.prefill_builds += 1

                def prefill(params, arrays, plen):
                    return self.prefill(params, dict(
                        arrays, cache_len=cache_len, prompt_len=plen))
                return jax.jit(prefill)
            return self._jit_get(("prefill_b", cache_len, S_pad), build)

        def call(params, arrays):
            toks = arrays["tokens"]
            S_tok = int(toks.shape[1])
            extra = (int(arrays["image_embeds"].shape[1])
                     if self.cfg.frontend == "vit" else 0)
            S_pad = bucket_length(S_tok, cache_len - extra)
            if S_pad != S_tok:
                toks = jnp.pad(toks, ((0, 0), (0, S_pad - S_tok)))
                arrays = dict(arrays, tokens=toks)
            return build_for(S_pad)(
                params, arrays, jnp.asarray(extra + S_tok, jnp.int32))
        return call

    def jitted_decode_step(self):
        """jit(decode_step) with the cache donated, cached per model."""
        return self._jit_get(
            "decode_step",
            lambda: jax.jit(lambda params, cache, token:
                            self.decode_step(params, cache, token),
                            donate_argnums=(1,)))

    def jitted_decode_step_masked(self, mesh=None):
        """jit(decode_step) with a per-slot ``active`` mask (vector-pos
        slot-pool cache), cache donated.

        With a ``mesh`` the logits output is pinned replicated while the
        cache stays compiler-placed: the final all-gather of the
        tensor-parallel logits happens *inside* this executable (one step
        = one program, collectives compiled in), and the downstream pick
        never sees a vocab-sharded operand (a sharded top-k would compile
        into a distributed sort — tens of rendezvous per step)."""
        def build():
            out_shardings = None
            if mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec
                out_shardings = (NamedSharding(mesh, PartitionSpec()), None)
            return jax.jit(self.decode_step, donate_argnums=(1,),
                           out_shardings=out_shardings)
        return self._jit_get(("decode_step_masked", mesh), build)

    def jitted_mixed_step(self, K: int, C: int, mesh=None):
        """jit(mixed_step), cache donated, one LRU entry per chunk config
        (K lanes × C tokens) so distinct configs stay individually
        evictable.  With a mesh both logits outputs are pinned replicated
        (same rationale as :meth:`jitted_decode_step_masked`)."""
        def build():
            out_shardings = None
            if mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec
                rep = NamedSharding(mesh, PartitionSpec())
                out_shardings = (rep, rep, None)
            return jax.jit(self.mixed_step, donate_argnums=(1,),
                           out_shardings=out_shardings)
        return self._jit_get(("mixed_step", K, C, mesh), build)

    def jitted_copy_blocks(self):
        """jit(copy_blocks), pool donated — the eager COW block copy."""
        return self._jit_get(
            "copy_blocks",
            lambda: jax.jit(self.copy_blocks, donate_argnums=(0,)))

    def jitted_splice(self):
        """jit(splice_cache) with the pool cache donated: admission writes
        one row in place instead of copying the whole pool."""
        return self._jit_get(
            "splice",
            lambda: jax.jit(self.splice_cache, donate_argnums=(0,)))

    def jitted_splice_paged(self):
        """jit(splice_cache_paged), pool donated — admission scatters the
        prefilled row into its arena blocks in place."""
        return self._jit_get(
            "splice_paged",
            lambda: jax.jit(self.splice_cache_paged, donate_argnums=(0,)))

    def jitted_prefill_resume(self, cache_len: int):
        """Host wrapper around jit(prefill_resume) with the suffix bucketed
        like :meth:`jitted_prefill_bucketed` (one trace per suffix bucket),
        pool cache donated."""
        def build_for(S_pad):
            def build():
                self.prefill_builds += 1
                return jax.jit(self.prefill_resume, donate_argnums=(2,))
            return self._jit_get(("resume", cache_len, S_pad), build)

        def call(params, arrays, cache, slot, src_blocks, dst_blocks,
                 start, true_suf):
            toks = arrays["tokens"]
            S_tok = int(toks.shape[1])
            S_pad = bucket_length(S_tok, cache_len)
            if S_pad != S_tok:
                toks = jnp.pad(toks, ((0, 0), (0, S_pad - S_tok)))
                arrays = dict(arrays, tokens=toks)
            return build_for(S_pad)(
                params, arrays, cache, jnp.asarray(slot, jnp.int32),
                jnp.asarray(src_blocks, jnp.int32),
                jnp.asarray(dst_blocks, jnp.int32),
                jnp.asarray(start, jnp.int32),
                jnp.asarray(true_suf, jnp.int32))
        return call

    # --------------------------------------------------------------- caching
    def cache_shapes(self, B: int, T: int, enc_T: int = 0,
                     dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct tree of a decode cache at context length T."""
        cfg = self.cfg
        out: dict = {"pos": jax.ShapeDtypeStruct((), jnp.int32)}
        for gi, (period, count) in enumerate(self.groups):
            g = {}
            for i, bd in enumerate(period):
                g[f"b{i}"] = block_cache_shape(cfg, bd, B, T, enc_T, dtype)
            out[f"g{gi}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((count,) + s.shape, s.dtype),
                g)
        return out

    def paged_cache_shapes(self, num_slots: int, num_blocks: int,
                           block: int, cache_len: int, enc_T: int = 0,
                           dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct tree of a block-paged pool (DESIGN.md §7):
        attention leaves become arenas [layers, num_blocks + 1, block, ...]
        shared by all slots through per-slot block tables; SSM/cross
        leaves stay [layers, num_slots, ...]; ``pos`` is [num_slots] and
        ``block_tables`` [num_slots, ceil(cache_len/block)]."""
        cfg = self.cfg
        max_blocks = -(-cache_len // block)
        out: dict = {
            "pos": jax.ShapeDtypeStruct((num_slots,), jnp.int32),
            "block_tables": jax.ShapeDtypeStruct((num_slots, max_blocks),
                                                 jnp.int32)}
        for gi, (period, count) in enumerate(self.groups):
            g = {}
            for i, bd in enumerate(period):
                g[f"b{i}"] = block_paged_cache_shape(
                    cfg, bd, num_slots, num_blocks, block, cache_len,
                    enc_T, dtype)
            out[f"g{gi}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((count,) + s.shape, s.dtype),
                g)
        return out

    def init_cache(self, B: int, T: int, enc_T: int = 0,
                   dtype=jnp.bfloat16, *, paged: bool = False,
                   num_blocks: int | None = None, block: int = 64) -> dict:
        """Zeroed decode cache.  ``paged=True`` builds the block-paged pool
        instead (B = num_slots; block tables initialized to the sentinel),
        the layout the continuous-batching scheduler serves — see
        DESIGN.md §7 for the migration notes."""
        if not paged:
            shapes = self.cache_shapes(B, T, enc_T, dtype)
            return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                shapes)
        if num_blocks is None:
            num_blocks = B * (-(-T // block))
        shapes = self.paged_cache_shapes(B, num_blocks, block, T, enc_T,
                                         dtype)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        cache["block_tables"] = jnp.full(shapes["block_tables"].shape,
                                         num_blocks, jnp.int32)
        return cache


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def build_model(cfg: ModelConfig, layer_plan: list[Group],
                enc_plan: list[Group] | None = None,
                param_dtype=jnp.float32) -> Model:
    return Model(cfg, layer_plan, enc_plan, param_dtype)
