"""Block assembly and scanned layer stacks.

A model is a list of *groups*; each group is a (period, count) pair where
``period`` is a tuple of BlockDefs executed in order and ``count`` is how
many times the period repeats.  Parameters of a group are stacked on a
leading 'layers' axis and the period body is scanned — HLO size stays O(1)
in depth (DESIGN.md §9).  Uniform models have a single (block,) period;
hybrids (jamba 1:7 attn:mamba, gemma3 5:1 local:global) use longer periods.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_act
from .attention import (cross_attn, cross_attn_spec, cross_kv,
                        gqa_decode_attn, gqa_self_attn, gqa_spec,
                        mla_decode_attn, mla_self_attn, mla_spec)
from .layers import mlp_apply, mlp_spec, rmsnorm_apply, rmsnorm_spec
from .moe import moe_apply_ep as moe_apply, moe_spec
from .spec import stack
from .ssm import ssm_decode, ssm_dims, ssm_forward, ssm_spec


@dataclasses.dataclass(frozen=True)
class BlockDef:
    mixer: str = "gqa"        # gqa | mla | ssm
    window: int = 0           # >0 → sliding-window attention (ring cache)
    ffn: str = "mlp"          # mlp | moe | none
    cross: bool = False       # add cross-attention (decoder of enc-dec)
    causal: bool = True       # False → encoder self-attention
    theta: float | None = None


Group = tuple[tuple[BlockDef, ...], int]

# When True, layer scans fully unroll.  The dry-run's roofline accounting
# sets this: XLA cost_analysis counts a while-loop body exactly once
# (verified empirically), so FLOP/byte/collective totals must come from
# unrolled reduced-depth compiles + linear extrapolation (launch/dryrun.py).
SCAN_UNROLL = False


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def block_spec(cfg: ModelConfig, bd: BlockDef, dtype) -> dict:
    out = {"ln1": rmsnorm_spec(cfg.d_model, "embed", dtype)}
    if bd.mixer == "gqa":
        out["attn"] = gqa_spec(cfg, dtype)
    elif bd.mixer == "mla":
        out["attn"] = mla_spec(cfg, dtype)
    elif bd.mixer == "ssm":
        out["ssm"] = ssm_spec(cfg, dtype)
    else:
        raise ValueError(bd.mixer)
    if bd.cross:
        out["ln_x"] = rmsnorm_spec(cfg.d_model, "embed", dtype)
        out["xattn"] = cross_attn_spec(cfg, dtype)
    if bd.ffn != "none":
        out["ln2"] = rmsnorm_spec(cfg.d_model, "embed", dtype)
        if bd.ffn == "moe":
            out["ffn"] = moe_spec(cfg, dtype)
        else:
            ff = cfg.moe.first_dense_ff if (bd.ffn == "dense0" and cfg.moe) \
                else cfg.d_ff
            out["ffn"] = mlp_spec(cfg.d_model, ff, cfg.tt, dtype)
    return out


def group_spec(cfg: ModelConfig, group: Group, dtype) -> dict:
    period, count = group
    ps = {f"b{i}": block_spec(cfg, bd, dtype) for i, bd in enumerate(period)}
    return stack(ps, count)


# ---------------------------------------------------------------------------
# Cache structure per block
# ---------------------------------------------------------------------------

def block_cache_shape(cfg: ModelConfig, bd: BlockDef, B: int, T: int,
                      enc_T: int, dtype) -> dict:
    """ShapeDtypeStructs of one block's decode cache."""
    sd = jax.ShapeDtypeStruct
    out: dict = {}
    if bd.mixer == "gqa":
        W = min(bd.window, T) if bd.window else T
        kv = (B, W, cfg.num_kv_heads, cfg.head_dim)
        out["k"], out["v"] = sd(kv, dtype), sd(kv, dtype)
    elif bd.mixer == "mla":
        m = cfg.mla
        out["ckv"] = sd((B, T, m.kv_lora), dtype)
        out["krope"] = sd((B, T, m.rope_head_dim), dtype)
    elif bd.mixer == "ssm":
        s = cfg.ssm
        d_inner, heads, conv_dim = ssm_dims(cfg)
        out["state"] = sd((B, heads, s.d_state, s.head_dim), jnp.float32)
        out["conv"] = sd((B, s.d_conv - 1, conv_dim), dtype)
    if bd.cross:
        kv = (B, enc_T, cfg.num_kv_heads, cfg.head_dim)
        out["xk"], out["xv"] = sd(kv, dtype), sd(kv, dtype)
    return out


# ---------------------------------------------------------------------------
# Block apply — full sequence (train / prefill / encoder)
# ---------------------------------------------------------------------------

def block_fwd(p, cfg: ModelConfig, bd: BlockDef, x, positions, *,
              enc_out=None, want_cache: bool, T_cache: int = 0,
              plans=None):
    """Returns (x, cache_dict_or_None).

    ``plans`` is the model's PlanBook (kernels.plan): every projection in
    the block resolves its TT execution plan through it instead of a
    backend string.  ``plans=None`` keeps the legacy stringly-typed path
    (``cfg.tt.backend_spec``) for direct callers."""
    backend = plans if plans is not None else cfg.tt.backend_spec
    cache = {}
    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    if bd.mixer == "gqa":
        y, (k, v) = gqa_self_attn(p["attn"], cfg, h, positions,
                                  window=bd.window, theta=bd.theta,
                                  backend=backend, causal=bd.causal)
        if want_cache:
            W = min(bd.window, T_cache) if bd.window else T_cache
            S = k.shape[1]
            if S >= W:
                # ring slots: position p lives at slot p % W
                ck = jnp.roll(k[:, -W:], S % W, axis=1)
                cv = jnp.roll(v[:, -W:], S % W, axis=1)
            else:
                pad = ((0, 0), (0, W - S), (0, 0), (0, 0))
                ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
            cache.update(k=ck, v=cv)
    elif bd.mixer == "mla":
        y, (ckv, krope) = mla_self_attn(p["attn"], cfg, h, positions,
                                        backend=backend)
        if want_cache:
            padlen = T_cache - ckv.shape[1]
            cache["ckv"] = jnp.pad(ckv, ((0, 0), (0, padlen), (0, 0)))
            cache["krope"] = jnp.pad(krope, ((0, 0), (0, padlen), (0, 0)))
    else:  # ssm
        y, state, conv_tail = ssm_forward(p["ssm"], cfg, h, backend)
        if want_cache:
            cache["state"] = state
            cache["conv"] = conv_tail.astype(x.dtype)
    x = x + y
    if bd.cross:
        h = rmsnorm_apply(p["ln_x"], x, cfg.norm_eps)
        x = x + cross_attn(p["xattn"], cfg, h,
                           *_enc_kv(p, cfg, bd, enc_out, cache, want_cache,
                                    backend),
                           backend=backend)
    if bd.ffn != "none":
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if bd.ffn == "moe":
            x = x + moe_apply(p["ffn"], cfg, h, backend)
        else:
            x = x + mlp_apply(p["ffn"], h, backend)
    x = shard_act(x, ("act_batch", "act_seq", "act_embed"))
    return x, (cache if want_cache else None)


def _enc_kv(p, cfg, bd, enc_out, cache, want_cache, backend):
    k, v = cross_kv(p["xattn"], cfg, enc_out, backend)
    if want_cache:
        cache["xk"], cache["xv"] = k, v
    return k, v


# ---------------------------------------------------------------------------
# Block apply — single-token decode
# ---------------------------------------------------------------------------

def block_decode(p, cfg: ModelConfig, bd: BlockDef, x, cache: dict, pos,
                 plans=None):
    backend = plans if plans is not None else cfg.tt.backend_spec
    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if bd.mixer == "gqa":
        y, nk, nv = gqa_decode_attn(p["attn"], cfg, h, cache["k"], cache["v"],
                                    pos, window=bd.window, theta=bd.theta,
                                    backend=backend)
        new_cache.update(k=nk, v=nv)
    elif bd.mixer == "mla":
        y, nckv, nkr = mla_decode_attn(p["attn"], cfg, h, cache["ckv"],
                                       cache["krope"], pos, backend=backend)
        new_cache.update(ckv=nckv, krope=nkr)
    else:
        y, st, cv = ssm_decode(p["ssm"], cfg, h, cache["state"],
                               cache["conv"], backend)
        new_cache.update(state=st, conv=cv)
    x = x + y
    if bd.cross:
        h = rmsnorm_apply(p["ln_x"], x, cfg.norm_eps)
        x = x + cross_attn(p["xattn"], cfg, h, cache["xk"], cache["xv"],
                           backend=backend)
    if bd.ffn != "none":
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if bd.ffn == "moe":
            x = x + moe_apply(p["ffn"], cfg, h, backend)
        else:
            x = x + mlp_apply(p["ffn"], h, backend)
    return x, new_cache


# ---------------------------------------------------------------------------
# Group (scanned) application
# ---------------------------------------------------------------------------

def group_fwd(params, cfg: ModelConfig, group: Group, x, positions, *,
              enc_out=None, want_cache: bool, T_cache: int = 0,
              remat: bool = False, plans=None):
    """Scan the period body over the group's stacked params.
    Returns (x, stacked_caches_or_None).  ``plans`` (the model's PlanBook)
    is closure-captured by the scan body: one build-time-resolved plan per
    chain signature serves every scanned layer."""
    period, count = group

    def body(x, layer_params):
        caches = {}
        for i, bd in enumerate(period):
            x, c = block_fwd(layer_params[f"b{i}"], cfg, bd, x, positions,
                             enc_out=enc_out, want_cache=want_cache,
                             T_cache=T_cache, plans=plans)
            if want_cache:
                caches[f"b{i}"] = c
        return x, (caches if want_cache else None)

    if remat:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params, unroll=SCAN_UNROLL or 1)
    return x, caches


def group_decode(params, cfg: ModelConfig, group: Group, x, caches, pos,
                 plans=None):
    """Scan decode over stacked (params, caches).  Returns (x, new_caches)."""
    period, count = group

    def body(x, inp):
        layer_params, layer_caches = inp
        new = {}
        for i, bd in enumerate(period):
            x, c = block_decode(layer_params[f"b{i}"], cfg, bd, x,
                                layer_caches[f"b{i}"], pos, plans=plans)
            new[f"b{i}"] = c
        return x, new

    x, new_caches = jax.lax.scan(body, x, (params, caches),
                                 unroll=SCAN_UNROLL or 1)
    return x, new_caches
