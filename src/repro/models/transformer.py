"""Block assembly and scanned layer stacks.

A model is a list of *groups*; each group is a (period, count) pair where
``period`` is a tuple of BlockDefs executed in order and ``count`` is how
many times the period repeats.  Parameters of a group are stacked on a
leading 'layers' axis and the period body is scanned — HLO size stays O(1)
in depth (DESIGN.md §9).  Uniform models have a single (block,) period;
hybrids (jamba 1:7 attn:mamba, gemma3 5:1 local:global) use longer periods.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_act
from .attention import (_resume_dense, _resume_scatter, cross_attn,
                        cross_attn_spec, cross_kv, gqa_chunk_attn,
                        gqa_chunk_attn_ring, gqa_decode_attn,
                        gqa_decode_attn_paged, gqa_resume_attn,
                        gqa_self_attn, gqa_spec, mla_chunk_attn,
                        mla_decode_attn, mla_decode_attn_paged,
                        mla_resume_attn, mla_self_attn, mla_spec)
from .layers import mlp_apply, mlp_spec, rmsnorm_apply, rmsnorm_spec
from .moe import moe_apply_ep as moe_apply, moe_spec
from .spec import stack
from .ssm import ssm_decode, ssm_dims, ssm_forward, ssm_spec


@dataclasses.dataclass(frozen=True)
class BlockDef:
    mixer: str = "gqa"        # gqa | mla | ssm
    window: int = 0           # >0 → sliding-window attention (ring cache)
    ffn: str = "mlp"          # mlp | moe | none
    cross: bool = False       # add cross-attention (decoder of enc-dec)
    causal: bool = True       # False → encoder self-attention
    theta: float | None = None


Group = tuple[tuple[BlockDef, ...], int]

# When True, layer scans fully unroll.  The dry-run's roofline accounting
# sets this: XLA cost_analysis counts a while-loop body exactly once
# (verified empirically), so FLOP/byte/collective totals must come from
# unrolled reduced-depth compiles + linear extrapolation (launch/dryrun.py).
SCAN_UNROLL = False


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def block_spec(cfg: ModelConfig, bd: BlockDef, dtype) -> dict:
    out = {"ln1": rmsnorm_spec(cfg.d_model, "embed", dtype)}
    if bd.mixer == "gqa":
        out["attn"] = gqa_spec(cfg, dtype)
    elif bd.mixer == "mla":
        out["attn"] = mla_spec(cfg, dtype)
    elif bd.mixer == "ssm":
        out["ssm"] = ssm_spec(cfg, dtype)
    else:
        raise ValueError(bd.mixer)
    if bd.cross:
        out["ln_x"] = rmsnorm_spec(cfg.d_model, "embed", dtype)
        out["xattn"] = cross_attn_spec(cfg, dtype)
    if bd.ffn != "none":
        out["ln2"] = rmsnorm_spec(cfg.d_model, "embed", dtype)
        if bd.ffn == "moe":
            out["ffn"] = moe_spec(cfg, dtype)
        else:
            ff = cfg.moe.first_dense_ff if (bd.ffn == "dense0" and cfg.moe) \
                else cfg.d_ff
            out["ffn"] = mlp_spec(cfg.d_model, ff, cfg.tt, dtype)
    return out


def group_spec(cfg: ModelConfig, group: Group, dtype) -> dict:
    period, count = group
    ps = {f"b{i}": block_spec(cfg, bd, dtype) for i, bd in enumerate(period)}
    return stack(ps, count)


# ---------------------------------------------------------------------------
# Cache structure per block
# ---------------------------------------------------------------------------

def block_cache_shape(cfg: ModelConfig, bd: BlockDef, B: int, T: int,
                      enc_T: int, dtype) -> dict:
    """ShapeDtypeStructs of one block's decode cache."""
    sd = jax.ShapeDtypeStruct
    out: dict = {}
    if bd.mixer == "gqa":
        W = min(bd.window, T) if bd.window else T
        kv = (B, W, cfg.num_kv_heads, cfg.head_dim)
        out["k"], out["v"] = sd(kv, dtype), sd(kv, dtype)
    elif bd.mixer == "mla":
        m = cfg.mla
        out["ckv"] = sd((B, T, m.kv_lora), dtype)
        out["krope"] = sd((B, T, m.rope_head_dim), dtype)
    elif bd.mixer == "ssm":
        s = cfg.ssm
        d_inner, heads, conv_dim = ssm_dims(cfg)
        out["state"] = sd((B, heads, s.d_state, s.head_dim), jnp.float32)
        out["conv"] = sd((B, s.d_conv - 1, conv_dim), dtype)
    if bd.cross:
        kv = (B, enc_T, cfg.num_kv_heads, cfg.head_dim)
        out["xk"], out["xv"] = sd(kv, dtype), sd(kv, dtype)
    return out


def block_cache_kinds(bd: BlockDef) -> dict[str, str]:
    """Paging kind of each cache leaf of one block (DESIGN.md §7):

      'paged' — token-indexed, block-pageable and prefix-shareable
      'ring'  — window ring, block-pageable through the low table entries
                but never prefix-shared (contents are overwritten in place)
      'slot'  — fixed-size per-slot state (SSM state/conv tail, cross-attn
                encoder KV): stays [layers, num_slots, ...], unpaged
    """
    out: dict[str, str] = {}
    if bd.mixer == "gqa":
        out["k"] = out["v"] = "ring" if bd.window else "paged"
    elif bd.mixer == "mla":
        out["ckv"] = out["krope"] = "paged"
    elif bd.mixer == "ssm":
        out["state"] = out["conv"] = "slot"
    if bd.cross:
        out["xk"] = out["xv"] = "slot"
    return out


def block_paged_cache_shape(cfg: ModelConfig, bd: BlockDef, num_slots: int,
                            num_blocks: int, block: int, T: int, enc_T: int,
                            dtype) -> dict:
    """Paged twin of :func:`block_cache_shape`: pageable leaves become
    arenas [num_blocks + 1, block, ...] (the +1 is the write sentinel),
    'slot' leaves keep the dense per-slot layout."""
    sd = jax.ShapeDtypeStruct
    dense = block_cache_shape(cfg, bd, num_slots, T, enc_T, dtype)
    kinds = block_cache_kinds(bd)
    out = {}
    for name, s in dense.items():
        if kinds[name] == "slot":
            out[name] = s
        else:
            out[name] = sd((num_blocks + 1, block) + s.shape[2:], s.dtype)
    return out


# ---------------------------------------------------------------------------
# Block apply — full sequence (train / prefill / encoder)
# ---------------------------------------------------------------------------

def _ring_cache(k, W, true_len):
    """Build a ring layout (position p at slot p % W) from full-sequence
    k [B,S,...] with the write head at a *traced* true length — the
    bucketed-prefill twin of the static roll/pad construction.  Slot s
    receives the latest position p <= true_len-1 with p % W == s, or zeros
    if no such position exists."""
    L1 = jnp.asarray(true_len, jnp.int32) - 1
    s_idx = jnp.arange(W)
    p_idx = L1 - jnp.mod(L1 - s_idx, W)                   # [W]
    valid = p_idx >= 0
    g = jnp.take(k, jnp.clip(p_idx, 0), axis=1)
    vshape = (1, W) + (1,) * (k.ndim - 2)
    return jnp.where(valid.reshape(vshape), g, 0)


def block_fwd(p, cfg: ModelConfig, bd: BlockDef, x, positions, *,
              enc_out=None, want_cache: bool, T_cache: int = 0,
              plans=None, true_len=None):
    """Returns (x, cache_dict_or_None).

    ``plans`` is the model's PlanBook (kernels.plan): every projection in
    the block resolves its TT execution plan through it instead of a
    backend string.  ``plans=None`` keeps the legacy stringly-typed path
    (``cfg.tt.backend_spec``) for direct callers.

    ``true_len`` (optional traced scalar) marks positions >= true_len as
    right-padding from prompt-length bucketing: the window ring is built
    at the true write head, the SSM state treats padded steps as exact
    no-ops, and full/MLA cache rows beyond it are junk masked downstream
    by the cache position."""
    backend = plans if plans is not None else cfg.tt.backend_spec
    cache = {}
    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    if bd.mixer == "gqa":
        y, (k, v) = gqa_self_attn(p["attn"], cfg, h, positions,
                                  window=bd.window, theta=bd.theta,
                                  backend=backend, causal=bd.causal)
        if want_cache:
            W = min(bd.window, T_cache) if bd.window else T_cache
            S = k.shape[1]
            if bd.window and true_len is not None:
                ck, cv = _ring_cache(k, W, true_len), _ring_cache(v, W,
                                                                  true_len)
            elif S >= W:
                # ring slots: position p lives at slot p % W
                ck = jnp.roll(k[:, -W:], S % W, axis=1)
                cv = jnp.roll(v[:, -W:], S % W, axis=1)
            else:
                pad = ((0, 0), (0, W - S), (0, 0), (0, 0))
                ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
            cache.update(k=ck, v=cv)
    elif bd.mixer == "mla":
        y, (ckv, krope) = mla_self_attn(p["attn"], cfg, h, positions,
                                        backend=backend)
        if want_cache:
            padlen = T_cache - ckv.shape[1]
            cache["ckv"] = jnp.pad(ckv, ((0, 0), (0, padlen), (0, 0)))
            cache["krope"] = jnp.pad(krope, ((0, 0), (0, padlen), (0, 0)))
    else:  # ssm
        y, state, conv_tail = ssm_forward(p["ssm"], cfg, h, backend,
                                          true_len=true_len)
        if want_cache:
            cache["state"] = state
            cache["conv"] = conv_tail.astype(x.dtype)
    x = x + y
    if bd.cross:
        h = rmsnorm_apply(p["ln_x"], x, cfg.norm_eps)
        x = x + cross_attn(p["xattn"], cfg, h,
                           *_enc_kv(p, cfg, bd, enc_out, cache, want_cache,
                                    backend),
                           backend=backend)
    if bd.ffn != "none":
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if bd.ffn == "moe":
            x = x + moe_apply(p["ffn"], cfg, h, backend)
        else:
            x = x + mlp_apply(p["ffn"], h, backend)
    x = shard_act(x, ("act_batch", "act_seq", "act_embed"))
    return x, (cache if want_cache else None)


def _enc_kv(p, cfg, bd, enc_out, cache, want_cache, backend):
    k, v = cross_kv(p["xattn"], cfg, enc_out, backend)
    if want_cache:
        cache["xk"], cache["xv"] = k, v
    return k, v


# ---------------------------------------------------------------------------
# Block apply — single-token decode
# ---------------------------------------------------------------------------

def block_decode(p, cfg: ModelConfig, bd: BlockDef, x, cache: dict, pos,
                 plans=None, paged=None, active=None):
    """``paged``: None for the dense slot-pool layout, else
    ``(block_tables [B, max_blocks], active [B])`` — attention leaves are
    block arenas addressed through the table; SSM/cross leaves are
    slot-indexed in both layouts.  ``active`` (optional [B] bool) gates
    every per-slot cache write: rows mid-chunked-prefill (and retired/free
    rows) must not have their state touched by the fused decode pass —
    paged attention leaves are already protected by the sentinel-block
    redirect, dense attention rows and SSM state/conv need the mask."""
    backend = plans if plans is not None else cfg.tt.backend_spec
    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if bd.mixer == "gqa":
        if paged is not None:
            bt, pact = paged
            y, nk, nv = gqa_decode_attn_paged(
                p["attn"], cfg, h, cache["k"], cache["v"], bt, pos, pact,
                window=bd.window, theta=bd.theta, backend=backend)
        else:
            y, nk, nv = gqa_decode_attn(p["attn"], cfg, h, cache["k"],
                                        cache["v"], pos, window=bd.window,
                                        theta=bd.theta, backend=backend,
                                        active=active)
        new_cache.update(k=nk, v=nv)
    elif bd.mixer == "mla":
        if paged is not None:
            bt, pact = paged
            y, nckv, nkr = mla_decode_attn_paged(
                p["attn"], cfg, h, cache["ckv"], cache["krope"], bt, pos,
                pact, backend=backend)
        else:
            y, nckv, nkr = mla_decode_attn(p["attn"], cfg, h, cache["ckv"],
                                           cache["krope"], pos,
                                           backend=backend, active=active)
        new_cache.update(ckv=nckv, krope=nkr)
    else:
        y, st, cv = ssm_decode(p["ssm"], cfg, h, cache["state"],
                               cache["conv"], backend)
        if active is not None:
            st = jnp.where(active[:, None, None, None], st, cache["state"])
            cv = jnp.where(active[:, None, None], cv, cache["conv"])
        new_cache.update(state=st, conv=cv)
    x = x + y
    if bd.cross:
        h = rmsnorm_apply(p["ln_x"], x, cfg.norm_eps)
        x = x + cross_attn(p["xattn"], cfg, h, cache["xk"], cache["xv"],
                           backend=backend)
    if bd.ffn != "none":
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if bd.ffn == "moe":
            x = x + moe_apply(p["ffn"], cfg, h, backend)
        else:
            x = x + mlp_apply(p["ffn"], h, backend)
    return x, new_cache


# ---------------------------------------------------------------------------
# Group (scanned) application
# ---------------------------------------------------------------------------

def group_fwd(params, cfg: ModelConfig, group: Group, x, positions, *,
              enc_out=None, want_cache: bool, T_cache: int = 0,
              remat: bool = False, plans=None, true_len=None):
    """Scan the period body over the group's stacked params.
    Returns (x, stacked_caches_or_None).  ``plans`` (the model's PlanBook)
    is closure-captured by the scan body: one build-time-resolved plan per
    chain signature serves every scanned layer."""
    period, count = group

    def body(x, layer_params):
        caches = {}
        for i, bd in enumerate(period):
            x, c = block_fwd(layer_params[f"b{i}"], cfg, bd, x, positions,
                             enc_out=enc_out, want_cache=want_cache,
                             T_cache=T_cache, plans=plans,
                             true_len=true_len)
            if want_cache:
                caches[f"b{i}"] = c
        return x, (caches if want_cache else None)

    if remat:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params, unroll=SCAN_UNROLL or 1)
    return x, caches


def group_decode(params, cfg: ModelConfig, group: Group, x, caches, pos,
                 plans=None, paged=None, active=None):
    """Scan decode over stacked (params, caches).  Returns (x, new_caches).
    ``paged`` = (block_tables, active) switches attention leaves to the
    block-arena layout; ``active`` masks per-slot writes (see
    block_decode)."""
    period, count = group

    def body(x, inp):
        layer_params, layer_caches = inp
        new = {}
        for i, bd in enumerate(period):
            x, c = block_decode(layer_params[f"b{i}"], cfg, bd, x,
                                layer_caches[f"b{i}"], pos, plans=plans,
                                paged=paged, active=active)
            new[f"b{i}"] = c
        return x, new

    x, new_caches = jax.lax.scan(body, x, (params, caches),
                                 unroll=SCAN_UNROLL or 1)
    return x, new_caches


# ---------------------------------------------------------------------------
# Resume prefill over paged caches (prefix-reuse admission)
# ---------------------------------------------------------------------------

def block_resume(p, cfg: ModelConfig, bd: BlockDef, x, cache: dict, src_b,
                 dst_b, start, plans=None):
    """Suffix prefill of one block against its paged arenas: attends to the
    prefix gathered through ``src_b`` and scatters the updated logical
    cache back through ``dst_b`` (COW where the tables differ).  Only
    prefix-shareable mixers are legal here — the scheduler gates the
    resume path on ``Model.supports_prefix_reuse``."""
    backend = plans if plans is not None else cfg.tt.backend_spec
    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if bd.mixer == "gqa" and not bd.window:
        y, nk, nv = gqa_resume_attn(p["attn"], cfg, h, cache["k"],
                                    cache["v"], src_b, dst_b, start,
                                    theta=bd.theta, backend=backend)
        new_cache.update(k=nk, v=nv)
    elif bd.mixer == "mla":
        y, nckv, nkr = mla_resume_attn(p["attn"], cfg, h, cache["ckv"],
                                       cache["krope"], src_b, dst_b, start,
                                       backend=backend)
        new_cache.update(ckv=nckv, krope=nkr)
    else:
        raise ValueError(
            f"mixer {bd.mixer!r} (window={bd.window}) does not support "
            "prefix-resume prefill")
    x = x + y
    if bd.ffn != "none":
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if bd.ffn == "moe":
            x = x + moe_apply(p["ffn"], cfg, h, backend)
        else:
            x = x + mlp_apply(p["ffn"], h, backend)
    return x, new_cache


def group_resume(params, cfg: ModelConfig, group: Group, x, caches, src_b,
                 dst_b, start, plans=None):
    """Scan resume prefill over stacked (params, caches)."""
    period, count = group

    def body(x, inp):
        layer_params, layer_caches = inp
        new = {}
        for i, bd in enumerate(period):
            x, c = block_resume(layer_params[f"b{i}"], cfg, bd, x,
                                layer_caches[f"b{i}"], src_b, dst_b, start,
                                plans=plans)
            new[f"b{i}"] = c
        return x, new

    x, new_caches = jax.lax.scan(body, x, (params, caches),
                                 unroll=SCAN_UNROLL or 1)
    return x, new_caches


# ---------------------------------------------------------------------------
# Chunked prefill — one prompt chunk of one slot, inside the serving pool
# ---------------------------------------------------------------------------
#
# The chunked-prefill twin of block_resume, generalized two ways: it runs
# against either pool layout (``table=None`` → dense slot pool, else the
# slot's block table into the paged arenas), and it covers every mixer —
# windowed-ring layers rebuild their ring from gathered history (a chunk
# may span more than W positions) and SSM layers thread the recurrent
# state + conv tail across chunks, both exactly the state a monolithic
# prefill would have reached.  All tensor shapes are static in (C, layout),
# so the scheduler's mixed step stays one traced program per chunk config.

def block_chunk(p, cfg: ModelConfig, bd: BlockDef, x, cache: dict, slot,
                table, start, true_len, active, plans=None):
    """One prefill chunk of one slot through one block.

    x [1, C, d] at absolute positions start + t (rows >= true_len are
    right-padding); ``slot`` scalar int32 selects the row of slot-indexed
    leaves; ``table`` [max_blocks] int32 addresses paged arenas (None for
    the dense layout; callers redirect it to the write sentinel when the
    lane is inactive).  ``active`` (scalar bool) gates dense-row and
    slot-state writes so an unused lane is a no-op by value.
    """
    if bd.cross:
        raise ValueError("chunked prefill does not support cross-attention")
    backend = plans if plans is not None else cfg.tt.backend_spec
    C = x.shape[1]
    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    new_cache = dict(cache)

    def _row(leaf):
        return jnp.take(leaf, slot, axis=0)[None]

    def _put(leaf, new_row):
        old = jnp.take(leaf, slot, axis=0)
        return leaf.at[slot].set(
            jnp.where(active, new_row.astype(leaf.dtype), old))

    if bd.mixer == "gqa" and not bd.window:
        if table is not None:
            dk = _resume_dense(cache["k"], table, C)
            dv = _resume_dense(cache["v"], table, C)
            y, dk, dv = gqa_chunk_attn(p["attn"], cfg, h, dk, dv, start,
                                       theta=bd.theta, backend=backend)
            new_cache["k"] = _resume_scatter(cache["k"], table, dk)
            new_cache["v"] = _resume_scatter(cache["v"], table, dv)
        else:
            T = cache["k"].shape[1]
            pad = lambda r: jnp.concatenate(
                [r, jnp.zeros((1, C) + r.shape[2:], r.dtype)], axis=1)
            dk, dv = pad(_row(cache["k"])), pad(_row(cache["v"]))
            y, dk, dv = gqa_chunk_attn(p["attn"], cfg, h, dk, dv, start,
                                       theta=bd.theta, backend=backend)
            new_cache["k"] = _put(cache["k"], dk[0, :T])
            new_cache["v"] = _put(cache["v"], dv[0, :T])
    elif bd.mixer == "gqa":
        if table is not None:
            blk = cache["k"].shape[1]
            W = min(bd.window, table.shape[0] * blk)
            nblk = -(-W // blk)

            def _gather_ring(arena):
                g = arena[table[:nblk]].reshape(
                    1, nblk * blk, *arena.shape[2:])
                return g, g[:, :W]

            gk, rk = _gather_ring(cache["k"])
            gv, rv = _gather_ring(cache["v"])
            y, nk, nv = gqa_chunk_attn_ring(p["attn"], cfg, h, rk, rv,
                                            start, true_len, theta=bd.theta,
                                            backend=backend)

            def _scatter_ring(arena, g, new_ring):
                merged = g.at[:, :W].set(new_ring.astype(g.dtype))
                blocks = merged[0].reshape(nblk, blk, *arena.shape[2:])
                return arena.at[table[:nblk]].set(blocks)

            new_cache["k"] = _scatter_ring(cache["k"], gk, nk)
            new_cache["v"] = _scatter_ring(cache["v"], gv, nv)
        else:
            rk, rv = _row(cache["k"]), _row(cache["v"])
            y, nk, nv = gqa_chunk_attn_ring(p["attn"], cfg, h, rk, rv,
                                            start, true_len, theta=bd.theta,
                                            backend=backend)
            new_cache["k"] = _put(cache["k"], nk[0])
            new_cache["v"] = _put(cache["v"], nv[0])
    elif bd.mixer == "mla":
        if table is not None:
            dckv = _resume_dense(cache["ckv"], table, C)
            dkr = _resume_dense(cache["krope"], table, C)
            y, dckv, dkr = mla_chunk_attn(p["attn"], cfg, h, dckv, dkr,
                                          start, backend=backend)
            new_cache["ckv"] = _resume_scatter(cache["ckv"], table, dckv)
            new_cache["krope"] = _resume_scatter(cache["krope"], table, dkr)
        else:
            T = cache["ckv"].shape[1]
            pad = lambda r: jnp.concatenate(
                [r, jnp.zeros((1, C) + r.shape[2:], r.dtype)], axis=1)
            dckv = pad(_row(cache["ckv"]))
            dkr = pad(_row(cache["krope"]))
            y, dckv, dkr = mla_chunk_attn(p["attn"], cfg, h, dckv, dkr,
                                          start, backend=backend)
            new_cache["ckv"] = _put(cache["ckv"], dckv[0, :T])
            new_cache["krope"] = _put(cache["krope"], dkr[0, :T])
    else:  # ssm — slot-indexed state in both layouts
        st, cv = _row(cache["state"]), _row(cache["conv"])
        fresh = start == 0
        st = jnp.where(fresh, jnp.zeros_like(st), st)
        cv = jnp.where(fresh, jnp.zeros_like(cv), cv)
        y, st2, tail = ssm_forward(p["ssm"], cfg, h, backend,
                                   true_len=true_len, s0=st, conv_hist=cv)
        new_cache["state"] = _put(cache["state"], st2[0])
        new_cache["conv"] = _put(cache["conv"], tail[0])
    x = x + y
    if bd.ffn != "none":
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if bd.ffn == "moe":
            x = x + moe_apply(p["ffn"], cfg, h, backend)
        else:
            x = x + mlp_apply(p["ffn"], h, backend)
    return x, new_cache


def group_chunk(params, cfg: ModelConfig, group: Group, x, caches, slot,
                table, start, true_len, active, plans=None):
    """Scan one prefill chunk over stacked (params, caches)."""
    period, count = group

    def body(x, inp):
        layer_params, layer_caches = inp
        new = {}
        for i, bd in enumerate(period):
            x, c = block_chunk(layer_params[f"b{i}"], cfg, bd, x,
                               layer_caches[f"b{i}"], slot, table, start,
                               true_len, active, plans=plans)
            new[f"b{i}"] = c
        return x, new

    x, new_caches = jax.lax.scan(body, x, (params, caches),
                                 unroll=SCAN_UNROLL or 1)
    return x, new_caches
