"""Logical-axis sharding rules (DP / FSDP / TP / SP / EP / pod).

Parameters carry logical axis names in their ``ParamSpec.axes``; activations
are annotated at call sites via ``shard_act``.  This module resolves both to
``PartitionSpec``s for the active mesh, dropping any assignment whose dim is
not divisible by the mesh axis (GSPMD could pad, but even sharding keeps the
roofline analysis honest).

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  The ``pod`` axis behaves as an outer data-parallel axis: batch
and FSDP shards extend onto it; no tensor is ever sharded across pods along
a model dimension (cross-pod DCI is the slow hop — gradient all-reduce only).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.spec import ParamSpec, is_spec

# --- parameter rules: logical name -> mesh axis (tensor parallel class) ----
PARAM_RULES: dict[str, Any] = {
    "vocab": "model",
    "ff": "model",
    "heads": "model",        # fused head*head_dim projections
    "experts": "model",      # EP when divisible
    "embed": None,
    "layers": None,
    # TT cores: ranks/input factors replicated (KB-scale — the compressed
    # object), but the *output-factor* dim m_t is tensor-parallel when it
    # divides the model axis.  In an aligned plan only the heavy
    # last-executed core has m_t ≥ mesh size, so exactly one chain step is
    # m-sharded and the big [T, M] chain output is born sharded instead of
    # replicated per model rank (EXPERIMENTS §Perf it. 4: TT activations
    # were replicated → +280 GB/dev/layer).
    "tt_r": None, "tt_n": None, "tt_m": "model",
    "conv": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
}

# --- activation rules ------------------------------------------------------
ACT_RULES_TRAIN = {
    "act_batch": ("pod", "data"),
    "act_seq": "model",          # sequence parallelism on the residual stream
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_ff": "model",
    "act_vocab": "model",
    "act_experts": "model",
    "act_moe_cap": "model",      # MoE buffer capacity — fallback EP axis
    "act_kv_seq": None,
}

ACT_RULES_DECODE = {
    **ACT_RULES_TRAIN,
    "act_seq": None,             # S == 1
    "act_kv_seq": "model",       # shard the KV cache along sequence
}


@dataclasses.dataclass
class ShardCtx:
    mesh: Mesh
    act_rules: dict[str, Any]
    data_axes: tuple[str, ...]       # FSDP axes, e.g. ("data",) or ("pod","data")


_CTX: ShardCtx | None = None


def set_ctx(ctx: ShardCtx | None):
    global _CTX
    _CTX = ctx


def get_ctx() -> ShardCtx | None:
    return _CTX


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([_axis_size(mesh, a) for a in axis]))
    return mesh.shape[axis] if axis in mesh.shape else 1


def _resolve_axis(mesh: Mesh, axis):
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' single-pod)."""
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = [a for a in axis if a in mesh.shape]
        return tuple(kept) if len(kept) > 1 else (kept[0] if kept else None)
    return axis if axis in mesh.shape else None


def model_axis_size() -> int:
    """Extent of the 'model' mesh axis under the active ctx (1 if none)."""
    ctx = _CTX
    if ctx is None:
        return 1
    return _axis_size(ctx.mesh, "model")


def shard_act(x: jax.Array, names: tuple[str | None, ...]) -> jax.Array:
    """Annotate an activation with logical axis names (no-op without ctx)."""
    ctx = _CTX
    if ctx is None:
        return x
    assert len(names) == x.ndim, (names, x.shape)
    parts = []
    used: set = set()
    for dim, name in zip(x.shape, names):
        axis = _resolve_axis(ctx.mesh, ctx.act_rules.get(name))
        if axis is not None and dim % _axis_size(ctx.mesh, axis) != 0:
            axis = None
        # one mesh axis per tensor — leftmost logical dim wins (e.g. MoE
        # buffers [E, C, d]: EP on E when divisible, else C picks it up)
        flat = set(axis) if isinstance(axis, tuple) else {axis}
        if axis is not None and flat & used:
            axis = None
        if axis is not None:
            used |= flat
        parts.append(axis)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*parts)))


# ---------------------------------------------------------------------------
# Parameter sharding from spec trees
# ---------------------------------------------------------------------------

def param_pspec(spec: ParamSpec, mesh: Mesh, fsdp_axes: tuple[str, ...] = (),
                rules: dict | None = None) -> P:
    if rules is None:
        rules = PARAM_RULES
    parts = []
    used: set = set()
    for dim, name in zip(spec.shape, spec.axes):
        axis = _resolve_axis(mesh, rules.get(name))
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            axis = None
        # one mesh axis per tensor: leftmost logical dim wins (e.g. stacked
        # MoE experts [L, E, d, ff] → EP on E, TP dropped on ff)
        flat = set(axis) if isinstance(axis, tuple) else {axis}
        if axis is not None and flat & used:
            axis = None
        if axis is not None:
            used |= flat
        parts.append(axis)
    if fsdp_axes:
        fs = _resolve_axis(mesh, tuple(fsdp_axes))
        if fs is not None:
            size = _axis_size(mesh, fs)
            # largest still-unsharded dim divisible by the FSDP extent
            cands = [(dim, i) for i, (dim, p) in
                     enumerate(zip(spec.shape, parts))
                     if p is None and dim % size == 0 and dim >= size]
            if cands:
                _, i = max(cands)
                parts[i] = fs
    return P(*parts)


def param_shardings(spec_tree, mesh: Mesh, fsdp: bool = False):
    """NamedSharding tree matching a ParamSpec tree."""
    fsdp_axes = ("pod", "data") if fsdp else ()

    def f(s: ParamSpec):
        return NamedSharding(mesh, param_pspec(s, mesh, fsdp_axes))
    return jax.tree.map(f, spec_tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Serving shardings (DESIGN.md §14)
# ---------------------------------------------------------------------------
#
# Serving is pure data placement: params and the KV pool are device_put with
# the trees below and GSPMD partitions the *unchanged* jitted entry points
# (prefill / masked decode / splice / resume) — the traced programs are
# byte-identical to the single-device ones, only the compiler-inserted
# collectives differ.  No ShardCtx is set, so shard_act stays a no-op and
# MoE expert parallelism falls out of the "experts" parameter axis alone.

# TT cores are fully replicated when serving (the compressed object is
# KB-scale by construction); training keeps the tt_m output-factor TP rule.
SERVE_PARAM_RULES: dict[str, Any] = {**PARAM_RULES, "tt_m": None}

# cache leaves carrying a KV-head axis at dim -2 in every pool layout:
# dense/ring slots [layers, B, T, KV, hd] and paged arenas
# [layers, num_blocks+1, block, KV, hd] — Megatron-style head partitioning.
_KV_HEAD_LEAVES = frozenset({"k", "v", "xk", "xv"})


def serve_param_shardings(spec_tree, params, mesh: Mesh):
    """NamedSharding tree for a *serving* parameter tree under
    ``SERVE_PARAM_RULES`` (embeddings/LM head, fused head projections, MLP
    ff and MoE expert stacks sharded on 'model'; TT cores, norms and
    biases replicated).  Walks ``params`` (not the spec tree) so
    checkpoint transforms survive: an int8-quantized tree keeps every
    core's path/shape and its extra ``scales`` leaves — or any leaf whose
    shape no longer matches its spec — fall back to replicated."""
    ktr = jax.tree_util.keystr
    flat, _ = jax.tree_util.tree_flatten_with_path(spec_tree, is_leaf=is_spec)
    by_path = {ktr(p): s for p, s in flat}

    def f(path, leaf):
        s = by_path.get(ktr(path))
        if s is None or tuple(s.shape) != tuple(leaf.shape):
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, param_pspec(s, mesh, rules=SERVE_PARAM_RULES))
    return jax.tree_util.tree_map_with_path(f, params)


def serve_cache_shardings(cache, mesh: Mesh, batch: int | None = None):
    """NamedSharding tree for a scheduler pool cache (dense or paged).

    Attention KV leaves are partitioned on the KV-head axis (dim -2 in
    both the slot and arena layouts) when it divides the 'model' extent;
    everything else — ``pos``, host-logical ``block_tables``, MLA latents
    (shared across heads by design), SSM state/conv — is replicated.  The
    same tree re-constrains the pool after resize/restore so the decode
    executable always sees one stable input sharding.

    ``batch`` (dense pools only — the scheduler passes ``num_slots``)
    additionally partitions the slot axis (dim 1 of every ``[layers, B,
    ...]`` leaf) over the 'data' mesh axis: each device owns the KV of
    ``B / data`` slots and decode is batch-parallel — no per-layer
    collectives, only the final logits gather.  Paged pools never pass
    ``batch``: arena blocks are pooled across slots by the host-side
    allocator, so the block axis has no slot alignment to exploit and is
    partitioned on KV heads instead."""
    msize = _axis_size(mesh, "model")
    dsize = _axis_size(mesh, "data")

    def f(path, leaf):
        name = (path[-1].key if isinstance(path[-1], jax.tree_util.DictKey)
                else None)
        dims: list = [None] * leaf.ndim
        if (batch is not None and dsize > 1 and leaf.ndim >= 2
                and leaf.shape[1] == batch and batch % dsize == 0):
            dims[1] = "data"
        if (name in _KV_HEAD_LEAVES and leaf.ndim >= 2
                and leaf.shape[-2] % msize == 0):
            dims[-2] = "model"
        return NamedSharding(mesh, P(*dims))
    return jax.tree_util.tree_map_with_path(f, cache)
