"""Deterministic synthetic token pipeline.

Every batch is a pure function of (config, step, shard_id, num_shards):
no host state, no files — which is exactly what straggler re-assignment and
bit-identical restart require (training/fault.py §4).  Sequences follow a
per-sequence affine rule ``tok_{t+1} = (a·tok_t + b) mod V`` so a model can
actually learn them (examples/train_tt_lm.py drives the loss down).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataState:
    step: int = 0

    def as_dict(self) -> dict:
        return {"step": int(self.step)}

    @classmethod
    def from_dict(cls, d: dict) -> "DataState":
        return cls(step=int(d.get("step", 0)))


def synth_tokens(key: jax.Array, B: int, S: int, vocab: int) -> jax.Array:
    """Affine-rule sequences (vectorized closed form — no scan)."""
    k1, k2, k3 = jax.random.split(key, 3)
    a = 1 + 2 * jax.random.randint(k1, (B, 1), 0, min(vocab // 2, 64))
    b = jax.random.randint(k2, (B, 1), 0, vocab)
    t0 = jax.random.randint(k3, (B, 1), 0, vocab)
    # closed form of the affine recurrence mod V would need modular inverse;
    # use the simpler additive rule when a == 1 else iterate in log space:
    # for learnability an additive progression suffices.
    stride = 1 + jax.random.randint(k1, (B, 1), 0, 16)
    idx = jnp.arange(S)[None, :]
    return (t0 + stride * idx + b * 0 + a * 0) % vocab


def make_batch(cfg: ModelConfig, B: int, S: int, step: int,
               shard_id: int = 0, num_shards: int = 1, seed: int = 1234
               ) -> dict:
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), step), shard_id)
    out: dict = {}
    if cfg.frontend == "vit":
        S_img = min(cfg.frontend_tokens, S // 2)
        k1, key = jax.random.split(key)
        out["image_embeds"] = jax.random.normal(
            k1, (B, S_img, cfg.frontend_dim), jnp.float32).astype(jnp.bfloat16)
        out["tokens"] = synth_tokens(key, B, S - S_img, cfg.vocab_size
                                     ).astype(jnp.int32)
    elif cfg.frontend == "speech":
        k1, key = jax.random.split(key)
        out["speech_embeds"] = jax.random.normal(
            k1, (B, S, cfg.frontend_dim), jnp.float32).astype(jnp.bfloat16)
        out["tokens"] = synth_tokens(key, B, S, cfg.vocab_size
                                     ).astype(jnp.int32)
    else:
        out["tokens"] = synth_tokens(key, B, S, cfg.vocab_size
                                     ).astype(jnp.int32)
    return out


def calibration_batches(cfg: ModelConfig, B: int, S: int, n: int,
                        seed: int = 7777) -> list[dict]:
    """A fixed, seed-determined calibration set for data-aware DSE
    (DESIGN.md §12).  Deliberately a *list*, not an iterator: the study
    engine evaluates many candidate plans against the SAME batches, and
    resume-determinism requires the set to be a pure function of
    (cfg, B, S, n, seed).  Uses a seed space disjoint from the training
    default so calibration never aliases training data."""
    return [make_batch(cfg, B, S, step=i, seed=seed) for i in range(n)]


class DataIterator:
    """Checkpointable iterator facade over make_batch."""

    def __init__(self, cfg: ModelConfig, B: int, S: int, state: DataState
                 | None = None, shard_id: int = 0, num_shards: int = 1):
        self.cfg, self.B, self.S = cfg, B, S
        self.state = state or DataState()
        self.shard_id, self.num_shards = shard_id, num_shards

    def __next__(self) -> dict:
        batch = make_batch(self.cfg, self.B, self.S, self.state.step,
                           self.shard_id, self.num_shards)
        self.state.step += 1
        return batch
