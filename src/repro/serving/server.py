"""Minimal HTTP/SSE serving front-end over :class:`StreamEngine`.

Stdlib-only (``http.server``): the repo's serving stack must run in the
bare container.  Protocol (DESIGN.md §15):

    POST /generate   JSON {"tokens": [...], "max_new_tokens": N,
                           "temperature"?, "top_k"?, "priority"?,
                           "uid"?, "stream"? (default true)}
                     → SSE stream of per-token events
                       ``data: {"uid", "i", "token", "lp"}`` ending with
                       ``data: {"uid", "done": reason}``; or, with
                       ``"stream": false``, one JSON result object.
    GET /stream/<uid>?from=N
                     → SSE replay of the request's events from index N,
                       then the live tail — the *reconnect* endpoint.  A
                       client that lost its connection (or its server:
                       buffers recovered from the durable journal are
                       replayable the same way) resumes the token stream
                       exactly where it left off.
    GET /stats       → scheduler + engine counters as JSON.
    POST /shutdown   → acknowledge, then stop the HTTP loop; the caller
                       is responsible for draining the engine.

Events carry explicit indices rather than relying on SSE ``id:``/
``Last-Event-ID`` so reconnect works through any plain HTTP client.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import jax.numpy as jnp
import numpy as np

from .engine import StreamEngine
from .scheduler import Request


def _sse(event: dict) -> bytes:
    return f"data: {json.dumps(event)}\n\n".encode()


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    engine: StreamEngine = None           # injected by make_server
    quiet: bool = True

    def log_message(self, fmt, *args):    # pragma: no cover - noise control
        if not self.quiet:
            super().log_message(fmt, *args)

    # ------------------------------------------------------------- plumbing
    def _json_body(self) -> dict:
        n = int(self.headers.get("Content-Length") or 0)
        return json.loads(self.rfile.read(n) or b"{}")

    def _send_json(self, obj: dict, code: int = 200) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_sse_events(self, uid: int, start: int) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        try:
            for ev in self.engine.stream(uid, start=start):
                self.wfile.write(_sse(ev))
                self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError):
            pass                          # client went away: buffers keep
                                          # the stream replayable
        except (KeyError, TimeoutError) as e:
            self.wfile.write(_sse({"uid": uid, "error": str(e)}))

    # ------------------------------------------------------------ endpoints
    def do_POST(self):                    # noqa: N802 (http.server API)
        path = urlparse(self.path).path
        if path == "/generate":
            try:
                body = self._json_body()
                toks = np.asarray(body["tokens"], np.int32)
                if toks.ndim == 1:
                    toks = toks[None]
                uid = (int(body["uid"]) if "uid" in body
                       else self.engine.alloc_uid())
                req = Request(
                    uid=uid, inputs={"tokens": jnp.asarray(toks)},
                    max_new_tokens=int(body["max_new_tokens"]),
                    temperature=float(body.get("temperature", 0.0)),
                    top_k=int(body.get("top_k", 0)),
                    priority=int(body.get("priority", 0)),
                    deadline_s=(None if body.get("deadline_s") is None
                                else float(body["deadline_s"])))
            except (KeyError, ValueError, TypeError,
                    json.JSONDecodeError) as e:
                self._send_json({"error": str(e)}, code=400)
                return
            self.engine.submit(req)
            if body.get("stream", True):
                self._send_sse_events(uid, start=0)
                return
            f = self.engine.result(uid)
            self._send_json({
                "uid": uid, "tokens": [int(t) for t in f.tokens],
                "logprobs": [float(x) for x in f.logprobs],
                "finish_reason": f.finish_reason})
            return
        if path == "/shutdown":
            self._send_json({"ok": True})
            threading.Thread(target=self.server.shutdown,
                             daemon=True).start()
            return
        self._send_json({"error": f"unknown path {path}"}, code=404)

    def do_GET(self):                     # noqa: N802 (http.server API)
        parsed = urlparse(self.path)
        parts = [p for p in parsed.path.split("/") if p]
        if parsed.path == "/stats":
            self._send_json(self.engine.stats())
            return
        if len(parts) == 2 and parts[0] == "stream":
            try:
                uid = int(parts[1])
            except ValueError:
                self._send_json({"error": "uid must be an int"}, code=400)
                return
            q = parse_qs(parsed.query)
            start = int(q.get("from", ["0"])[0])
            self._send_sse_events(uid, start=start)
            return
        self._send_json({"error": f"unknown path {parsed.path}"}, code=404)


def make_server(engine: StreamEngine, host: str = "127.0.0.1",
                port: int = 0, quiet: bool = True) -> ThreadingHTTPServer:
    """Build (not start) the SSE server; ``port=0`` picks an ephemeral
    port (``server.server_address[1]`` has the real one).  Run with
    ``server.serve_forever()``; stop via POST /shutdown or
    ``server.shutdown()``."""
    handler = type("Handler", (_Handler,), {"engine": engine,
                                            "quiet": quiet})
    srv = ThreadingHTTPServer((host, port), handler)
    srv.daemon_threads = True
    return srv
