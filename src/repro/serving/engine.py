"""Batched serving engine.

``generate`` is a thin wrapper over the continuous-batching scheduler
(serving/scheduler.py): the pre-batched input is split into one request per
row, all submitted at t=0 into a pool with one slot per row, and the
results are reassembled into the classic ``[B, steps]`` tensors.  Sampling
uses per-request PRNG streams (``fold_in(key, row)``); greedy decoding
consumes no randomness, so temperature=0 output is key-independent.

``generate_fixed`` keeps the pre-scheduler fixed-batch loop (scalar
position, no admission/retirement) as the benchmark baseline the
continuous-batching path is compared against (benchmarks/bench_serve_tt).

``StreamEngine`` is the async serving front-end: the scheduler's step
loop runs on a background thread, submissions arrive from any thread,
and per-token events stream out through ``Request.on_token`` into
per-request buffers that ``stream()`` replays from any index — the
reconnect contract the SSE server (serving/server.py) is built on.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from .scheduler import FinishedRequest, Request, Scheduler, make_requests


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array          # [B, steps]
    logprobs: jax.Array        # [B, steps]


def generate(model: Model, params, batch: dict, steps: int,
             temperature: float = 0.0, key: jax.Array | None = None,
             top_k: int = 0, paged: bool = False, block_size: int = 64,
             num_blocks: int | None = None, prefix_cache: bool = True,
             priority: int = 0, deadline_s: float | None = None,
             mesh=None) -> GenerateResult:
    """Decode ``steps`` tokens for every row of ``batch`` (no EOS: fixed
    budget, so the result is rectangular).  ``paged=True`` serves through
    the block-paged KV pool (DESIGN.md §7) — output is token-identical to
    the dense pool; ``temperature``/``top_k`` become per-request sampling
    params on the scheduler's requests, ``priority``/``deadline_s`` their
    lifecycle params (DESIGN.md §11) — a row retired past its TTL comes
    back shorter than ``steps``, so the result is only rectangular when
    every row survives; a ragged batch raises with the expired uids."""
    B = batch["tokens"].shape[0]
    if steps <= 0:
        return GenerateResult(jnp.zeros((B, 0), jnp.int32),
                              jnp.zeros((B, 0), jnp.float32))
    model.plan_book          # resolve all TT plans before the serving loop
    cache_len = batch.get("cache_len")
    if cache_len is None:
        S = batch["tokens"].shape[1]
        if model.cfg.frontend == "vit":       # image prefix occupies cache
            S += batch["image_embeds"].shape[1]
        cache_len = S + steps
    sched = Scheduler(model, params, num_slots=B, cache_len=cache_len,
                      key=key, paged=paged, block_size=block_size,
                      num_blocks=num_blocks, prefix_cache=prefix_cache,
                      mesh=mesh)
    for req in make_requests(batch, max_new_tokens=steps, key=key,
                             temperature=temperature, top_k=top_k,
                             priority=priority, deadline_s=deadline_s):
        sched.submit(req)
    finished = sched.run()
    short = [b for b in range(B) if len(finished[b].tokens) != steps]
    if short:
        raise RuntimeError(
            f"rows {short} retired early "
            f"({[finished[b].finish_reason for b in short]}) — generate() "
            f"returns rectangular batches; drive the Scheduler directly "
            f"for deadline-bound workloads")
    toks = np.stack([finished[b].tokens for b in range(B)])
    lps = np.stack([finished[b].logprobs for b in range(B)])
    return GenerateResult(jnp.asarray(toks), jnp.asarray(lps))


def generate_fixed(model: Model, params, batch: dict, steps: int,
                   temperature: float = 0.0, key: jax.Array | None = None
                   ) -> GenerateResult:
    """Fixed-batch greedy/temperature loop (every row in lockstep, scalar
    cache position, no request admission) — the baseline decode loop."""
    cache_len = batch.get("cache_len")
    arrays = {k: v for k, v in batch.items() if k != "cache_len"}
    B = arrays["tokens"].shape[0]
    if steps <= 0:
        return GenerateResult(jnp.zeros((B, 0), jnp.int32),
                              jnp.zeros((B, 0), jnp.float32))

    model.plan_book          # resolve all TT plans before the serving loop
    logits, cache = model.jitted_prefill(cache_len)(params, arrays)
    step_fn = model.jitted_decode_step()

    key = key if key is not None else jax.random.PRNGKey(0)

    def pick(logits, key):
        """Only splits the stream when actually sampling: the same ``key``
        must mean the same stream regardless of temperature."""
        lg = logits[:, -1, :]
        if temperature == 0.0:
            tok = jnp.argmax(lg, -1)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, lg / temperature, -1)
        lp = jax.nn.log_softmax(lg, -1)
        return tok.astype(jnp.int32), jnp.take_along_axis(
            lp, tok[:, None], -1)[:, 0], key

    toks, lps = [], []
    tok, lp, key = pick(logits, key)
    toks.append(tok)
    lps.append(lp)
    for _ in range(steps - 1):
        logits, cache = step_fn(params, cache, tok[:, None])
        tok, lp, key = pick(logits, key)
        toks.append(tok)
        lps.append(lp)
    return GenerateResult(jnp.stack(toks, 1), jnp.stack(lps, 1))


class StreamEngine:
    """Async serving front-end over a (Durable)Scheduler.

    The scheduler is single-threaded by design; the engine confines every
    scheduler call to one background loop thread and exposes thread-safe
    edges: ``submit()`` enqueues from any thread (applied by the loop
    before its next step), per-token events land in per-uid buffers via
    ``Request.on_token``, and ``stream(uid, start)`` replays a buffer
    from any index then follows the live tail — so a client that
    reconnects mid-generation resumes exactly where it left off.  When
    constructed over a recovered ``DurableScheduler`` the buffers are
    seeded from the journal/snapshot state (finished results and partial
    streams of in-flight requests), making reconnect journal-aware: a
    token acknowledged before the crash is replayable after it."""

    def __init__(self, sched, poll_s: float = 0.002,
                 autostart: bool = True):
        self.sched = sched
        self.poll_s = poll_s
        self._cond = threading.Condition()
        self._pending: deque[Request] = deque()
        self._buffers: dict[int, list[tuple[int, float]]] = {}
        self._done: dict[int, str] = {}
        self._results: dict[int, FinishedRequest] = {}
        self._stop = False
        self._drain = True
        self._thread: threading.Thread | None = None
        inner = getattr(sched, "sched", sched)
        for f in inner.finished:
            self._buffers[f.uid] = list(zip(
                (int(t) for t in np.asarray(f.tokens)),
                (float(x) for x in np.asarray(f.logprobs))))
            self._done[f.uid] = f.finish_reason
            self._results[f.uid] = f
        for s in inner.slots:
            if s is not None:
                self._buffers[s.uid] = list(zip(s.tokens, s.logprobs))
                s.req.on_token = self._on_token
        for q in inner.queue:
            r = q.resume
            self._buffers[q.req.uid] = ([] if r is None else
                                        list(zip(r.tokens, r.logprobs)))
            q.req.on_token = self._on_token
        self._next_uid = 1 + max(self._buffers, default=-1)
        if autostart:
            self.start()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop,
                                            name="stream-engine",
                                            daemon=True)
            self._thread.start()

    def close(self, drain: bool = True) -> None:
        """Stop the loop thread — after draining in-flight work by
        default — and close a durable scheduler's journal."""
        with self._cond:
            self._stop = True
            self._drain = drain
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if hasattr(self.sched, "close"):
            self.sched.close()

    def _loop(self) -> None:
        while True:
            with self._cond:
                while self._pending:
                    req = self._pending.popleft()
                    try:
                        self.sched.submit(req)
                    except ValueError as e:
                        self._done[req.uid] = f"rejected: {e}"
                        self._cond.notify_all()
                if self._stop and (not self._drain or self.sched.idle):
                    return
                idle = self.sched.idle
            if idle:
                time.sleep(self.poll_s)
                continue
            done = self.sched.step()      # outside the lock: slow
            if done:
                with self._cond:
                    for f in done:
                        self._buffers.setdefault(f.uid, [])
                        self._done[f.uid] = f.finish_reason
                        self._results[f.uid] = f
                    self._cond.notify_all()

    # -------------------------------------------------------------- ingress
    def alloc_uid(self) -> int:
        with self._cond:
            uid = self._next_uid
            self._next_uid += 1
            return uid

    def submit(self, req: Request) -> int:
        """Queue a request for the loop thread; tokens stream into its
        buffer as they are generated.  Returns the uid."""
        with self._cond:
            if self._stop:
                raise RuntimeError("engine is shutting down")
            req.on_token = self._on_token
            self._buffers.setdefault(req.uid, [])
            self._pending.append(req)
            self._next_uid = max(self._next_uid, req.uid + 1)
            self._cond.notify_all()
        return req.uid

    def _on_token(self, uid: int, idx: int, tok: int, lp: float) -> None:
        # called on the loop thread, mid-step; buffers only ever append
        with self._cond:
            buf = self._buffers.setdefault(uid, [])
            if idx >= len(buf):           # resume replays are already seeded
                buf.append((int(tok), float(lp)))
            self._cond.notify_all()

    # --------------------------------------------------------------- egress
    def stream(self, uid: int, start: int = 0, timeout: float = 60.0):
        """Yield ``{"uid", "i", "token", "lp"}`` events from index
        ``start`` (buffered history first, then live), ending with
        ``{"uid", "done": reason}``.  Unknown uid raises KeyError;
        ``timeout`` bounds the wait for each next token."""
        i = max(0, int(start))
        with self._cond:
            known = (uid in self._buffers or uid in self._done
                     or any(r.uid == uid for r in self._pending))
        if not known:
            raise KeyError(f"unknown uid {uid}")
        while True:
            with self._cond:
                ok = self._cond.wait_for(
                    lambda: i < len(self._buffers.get(uid, ()))
                    or uid in self._done, timeout)
                if not ok:
                    raise TimeoutError(f"uid {uid}: no token for "
                                       f"{timeout}s")
                buf = list(self._buffers.get(uid, ()))
                done = self._done.get(uid)
            for j in range(i, len(buf)):
                tok, lp = buf[j]
                yield {"uid": uid, "i": j, "token": tok, "lp": lp}
            i = len(buf)
            if done is not None:
                yield {"uid": uid, "done": done}
                return

    def result(self, uid: int, timeout: float = 300.0) -> FinishedRequest:
        """Block until ``uid`` finishes; raises on rejection/timeout."""
        with self._cond:
            ok = self._cond.wait_for(lambda: uid in self._done, timeout)
            if not ok:
                raise TimeoutError(f"uid {uid} not finished in {timeout}s")
            if uid not in self._results:
                raise RuntimeError(self._done[uid])
            return self._results[uid]

    def stats(self) -> dict:
        out = self.sched.stats()
        with self._cond:
            out["requests_buffered"] = len(self._buffers)
            out["requests_pending"] = len(self._pending)
            out["requests_done"] = len(self._done)
        return out
