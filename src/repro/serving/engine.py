"""Batched serving engine.

``generate`` is a thin wrapper over the continuous-batching scheduler
(serving/scheduler.py): the pre-batched input is split into one request per
row, all submitted at t=0 into a pool with one slot per row, and the
results are reassembled into the classic ``[B, steps]`` tensors.  Sampling
uses per-request PRNG streams (``fold_in(key, row)``); greedy decoding
consumes no randomness, so temperature=0 output is key-independent.

``generate_fixed`` keeps the pre-scheduler fixed-batch loop (scalar
position, no admission/retirement) as the benchmark baseline the
continuous-batching path is compared against (benchmarks/bench_serve_tt).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from .scheduler import Scheduler, make_requests


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array          # [B, steps]
    logprobs: jax.Array        # [B, steps]


def generate(model: Model, params, batch: dict, steps: int,
             temperature: float = 0.0, key: jax.Array | None = None,
             top_k: int = 0, paged: bool = False, block_size: int = 64,
             num_blocks: int | None = None, prefix_cache: bool = True,
             priority: int = 0, deadline_s: float | None = None,
             mesh=None) -> GenerateResult:
    """Decode ``steps`` tokens for every row of ``batch`` (no EOS: fixed
    budget, so the result is rectangular).  ``paged=True`` serves through
    the block-paged KV pool (DESIGN.md §7) — output is token-identical to
    the dense pool; ``temperature``/``top_k`` become per-request sampling
    params on the scheduler's requests, ``priority``/``deadline_s`` their
    lifecycle params (DESIGN.md §11) — a row retired past its TTL comes
    back shorter than ``steps``, so the result is only rectangular when
    every row survives; a ragged batch raises with the expired uids."""
    B = batch["tokens"].shape[0]
    if steps <= 0:
        return GenerateResult(jnp.zeros((B, 0), jnp.int32),
                              jnp.zeros((B, 0), jnp.float32))
    model.plan_book          # resolve all TT plans before the serving loop
    cache_len = batch.get("cache_len")
    if cache_len is None:
        S = batch["tokens"].shape[1]
        if model.cfg.frontend == "vit":       # image prefix occupies cache
            S += batch["image_embeds"].shape[1]
        cache_len = S + steps
    sched = Scheduler(model, params, num_slots=B, cache_len=cache_len,
                      key=key, paged=paged, block_size=block_size,
                      num_blocks=num_blocks, prefix_cache=prefix_cache,
                      mesh=mesh)
    for req in make_requests(batch, max_new_tokens=steps, key=key,
                             temperature=temperature, top_k=top_k,
                             priority=priority, deadline_s=deadline_s):
        sched.submit(req)
    finished = sched.run()
    short = [b for b in range(B) if len(finished[b].tokens) != steps]
    if short:
        raise RuntimeError(
            f"rows {short} retired early "
            f"({[finished[b].finish_reason for b in short]}) — generate() "
            f"returns rectangular batches; drive the Scheduler directly "
            f"for deadline-bound workloads")
    toks = np.stack([finished[b].tokens for b in range(B)])
    lps = np.stack([finished[b].logprobs for b in range(B)])
    return GenerateResult(jnp.asarray(toks), jnp.asarray(lps))


def generate_fixed(model: Model, params, batch: dict, steps: int,
                   temperature: float = 0.0, key: jax.Array | None = None
                   ) -> GenerateResult:
    """Fixed-batch greedy/temperature loop (every row in lockstep, scalar
    cache position, no request admission) — the baseline decode loop."""
    cache_len = batch.get("cache_len")
    arrays = {k: v for k, v in batch.items() if k != "cache_len"}
    B = arrays["tokens"].shape[0]
    if steps <= 0:
        return GenerateResult(jnp.zeros((B, 0), jnp.int32),
                              jnp.zeros((B, 0), jnp.float32))

    model.plan_book          # resolve all TT plans before the serving loop
    logits, cache = model.jitted_prefill(cache_len)(params, arrays)
    step_fn = model.jitted_decode_step()

    key = key if key is not None else jax.random.PRNGKey(0)

    def pick(logits, key):
        """Only splits the stream when actually sampling: the same ``key``
        must mean the same stream regardless of temperature."""
        lg = logits[:, -1, :]
        if temperature == 0.0:
            tok = jnp.argmax(lg, -1)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, lg / temperature, -1)
        lp = jax.nn.log_softmax(lg, -1)
        return tok.astype(jnp.int32), jnp.take_along_axis(
            lp, tok[:, None], -1)[:, 0], key

    toks, lps = [], []
    tok, lp, key = pick(logits, key)
    toks.append(tok)
    lps.append(lp)
    for _ in range(steps - 1):
        logits, cache = step_fn(params, cache, tok[:, None])
        tok, lp, key = pick(logits, key)
        toks.append(tok)
        lps.append(lp)
    return GenerateResult(jnp.stack(toks, 1), jnp.stack(lps, 1))
