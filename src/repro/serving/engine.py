"""Batched serving engine: prefill + greedy/temperature decode loop.

The per-token step is one jitted function (model.decode_step) whose cache is
donated; the Python loop only feeds tokens — standard continuous-batching
inner loop, minus the scheduler (requests arrive pre-batched here).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.model import Model


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array          # [B, steps]
    logprobs: jax.Array        # [B, steps]


def generate(model: Model, params, batch: dict, steps: int,
             temperature: float = 0.0, key: jax.Array | None = None
             ) -> GenerateResult:
    # cache_len is a *static* shape (it sizes the KV cache): close over it
    # rather than letting jit trace it.  The jitted callables live on the
    # Model (jitted_prefill / jitted_decode_step) so repeated generate()
    # calls hit the trace cache instead of rebuilding jit wrappers.
    cache_len = batch.get("cache_len")
    arrays = {k: v for k, v in batch.items() if k != "cache_len"}

    logits, cache = model.jitted_prefill(cache_len)(params, arrays)

    step_fn = model.jitted_decode_step()

    def pick(logits, key):
        lg = logits[:, -1, :]
        if temperature == 0.0:
            tok = jnp.argmax(lg, -1)
        else:
            tok = jax.random.categorical(key, lg / temperature, -1)
        lp = jax.nn.log_softmax(lg, -1)
        return tok.astype(jnp.int32), jnp.take_along_axis(
            lp, tok[:, None], -1)[:, 0]

    key = key if key is not None else jax.random.PRNGKey(0)
    toks, lps = [], []
    key, sub = jax.random.split(key)
    tok, lp = pick(logits, sub)
    toks.append(tok)
    lps.append(lp)
    for _ in range(steps - 1):
        logits, cache = step_fn(params, cache, tok[:, None])
        key, sub = jax.random.split(key)
        tok, lp = pick(logits, sub)
        toks.append(tok)
        lps.append(lp)
    return GenerateResult(jnp.stack(toks, 1), jnp.stack(lps, 1))
