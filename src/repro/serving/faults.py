"""Deterministic fault-injection harness for the serving stack (DESIGN.md §11).

A :class:`FaultPlan` is a *seeded, fully pre-computed* schedule of faults
keyed by scheduler step index: forced allocation failures, admission
holds, mid-decode cancellations, live pool resizes, and simulated process
restarts (snapshot → tear down → :meth:`Scheduler.from_snapshot`).  Because
the plan is data — not wall-clock races — every scenario replays exactly,
which is what lets :func:`run_with_faults` assert hard invariants after
the dust settles:

  * zero leaked blocks (``BlockAllocator.assert_quiescent``)
  * zero TT plan re-resolutions (``kernels.plan.plan_resolutions``)
  * every *surviving* request (not cancelled / expired) finishes with
    tokens bit-identical to an uninterrupted run of the same requests

The scheduler runs on a virtual step clock (one "second" per tick), so
deadlines fire at deterministic steps and restarts preserve remaining
TTLs without any real-time dependence.

Disk persistence (:func:`save_snapshot` / :func:`load_snapshot`) rides
the generation-based durable store (``core.durable``, DESIGN.md §13):
each save commits a new checksummed generation under the snapshot root
(chunked ``arrays.bin`` + JSON manifest, temp + fsync + atomic rename),
and each load verifies every array checksum, falling back to the newest
*clean* generation when the latest is truncated or bit-flipped — a torn
or corrupted write can never be restored.  The pre-PR-8 single-dir
layout (``arrays.npz`` + ``manifest.json``) is still readable.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zipfile

import numpy as np

from repro.core import durable
from repro.kernels import plan as ttplan
from .scheduler import FinishedRequest, Request, Scheduler


# ----------------------------------------------------------------- fault plan
@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of faults, keyed by scheduler step index.

    ``alloc_fail_steps`` — steps during which the allocator refuses fresh
    allocations (``refuse_fresh``): admissions defer exactly as under pool
    exhaustion, nothing mid-admission to roll back.
    ``hold_steps`` — steps during which admission is gated entirely
    (``hold_admissions``), modelling an external backpressure signal.
    ``cancels`` — ``(step, uid)`` pairs: ``cancel(uid)`` fires before the
    step runs (a no-op if the request already finished).
    ``resizes`` — ``(step, num_slots, num_blocks)`` triples (either value
    may be None to leave that axis alone).
    ``restart_steps`` — before each of these steps the scheduler is
    snapshotted, discarded, and rebuilt via ``Scheduler.from_snapshot``
    (a *graceful* restart: the in-memory state is captured first).
    ``kill_steps`` — ``kill -9`` at these steps: the scheduler is
    discarded with NO snapshot taken, and recovery must come entirely
    from the durable store (last committed snapshot generation + journal
    replay, ``serving.durable.DurableScheduler.recover``) — requires
    ``run_with_faults(durable_dir=...)``.
    """
    alloc_fail_steps: frozenset = frozenset()
    hold_steps: frozenset = frozenset()
    cancels: tuple = ()                   # ((step, uid), ...)
    resizes: tuple = ()                   # ((step, slots|None, blocks|None), ...)
    restart_steps: frozenset = frozenset()
    kill_steps: frozenset = frozenset()

    @classmethod
    def random(cls, seed: int, *, horizon: int, uids=(),
               n_alloc_fail: int = 2, n_hold: int = 1, n_cancel: int = 1,
               resize_to: tuple | None = None,
               with_restart: bool = True,
               with_kill: bool = False) -> "FaultPlan":
        """Sample a plan from a seeded generator.  ``horizon`` bounds the
        step indices faults land on (keep it well under the expected drain
        length so every fault actually fires)."""
        rng = np.random.default_rng(seed)
        steps = lambda n: frozenset(
            int(s) for s in rng.choice(horizon, size=min(n, horizon),
                                       replace=False))
        cancels = ()
        if n_cancel and len(uids):
            picked = rng.choice(len(uids), size=min(n_cancel, len(uids)),
                                replace=False)
            cancels = tuple(
                (int(rng.integers(1, horizon)), int(uids[i]))
                for i in picked)
        resizes = ()
        if resize_to is not None:
            resizes = ((int(rng.integers(1, horizon)),
                        resize_to[0], resize_to[1]),)
        return cls(
            alloc_fail_steps=steps(n_alloc_fail),
            hold_steps=steps(n_hold),
            cancels=cancels, resizes=resizes,
            restart_steps=(frozenset({int(rng.integers(1, horizon))})
                           if with_restart else frozenset()),
            kill_steps=(frozenset({int(rng.integers(1, horizon))})
                        if with_kill else frozenset()))


# -------------------------------------------------------------------- harness
@dataclasses.dataclass
class FaultReport:
    finished: dict                        # uid -> FinishedRequest
    baseline: dict                        # uid -> FinishedRequest (no faults)
    survivors: list                      # uids checked for token identity
    steps: int
    restarts: int
    preemptions: int
    cancelled: int
    expired: int
    replans: int
    kills: int = 0                        # hard kills recovered from disk


def step_clock(state: dict):
    """A virtual clock for Scheduler(clock=...): one unit per tick."""
    return lambda: float(state["t"])


def run_with_faults(model, params, requests: list[Request], plan: FaultPlan,
                    *, sched_kwargs: dict, max_steps: int = 2000,
                    arrival_steps: list[int] | None = None,
                    baseline: dict | None = None,
                    check_identity: bool = True,
                    durable_dir: str | None = None,
                    snapshot_every: int | None = None,
                    corruptor=None) -> FaultReport:
    """Drive a scheduler through ``plan`` on a virtual step clock, then
    assert the invariant suite.  ``sched_kwargs`` configures both the
    faulted scheduler and (unless ``baseline`` results are passed in) an
    uninterrupted reference run of the same requests.

    ``arrival_steps`` (aligned with ``requests``, default all-0) staggers
    submissions across steps — a late high-priority arrival is how the
    preemption path gets exercised.  Token streams are arrival-invariant
    (per-request PRNG streams), so the baseline submits everything
    upfront regardless.

    ``durable_dir`` wraps the scheduler in a
    ``serving.durable.DurableScheduler`` (journal + periodic snapshots
    every ``snapshot_every`` decode steps), which is what makes
    ``plan.kill_steps`` — hard kills recovered purely from disk —
    possible.  ``corruptor(durable_dir, step)``, if given, runs between
    each kill and its recovery: durability fault injection (truncating a
    committed ``arrays.bin`` mid-file, flipping a bit) exercises the
    checksum-verified fallback path.

    Surviving requests — everything not retired with ``finish_reason`` in
    {"cancelled", "deadline"} — must match the baseline bit-for-bit.
    """
    if plan.kill_steps and durable_dir is None:
        raise ValueError("plan.kill_steps require durable_dir (a hard "
                         "kill recovers from the durable store only)")
    if baseline is None:
        ref = Scheduler(model, params, **sched_kwargs)
        for r in requests:
            # the reference never expires anything: strip TTLs so faulted
            # slowdowns (holds, restarts) don't change its outcomes
            ref.submit(dataclasses.replace(r, deadline_s=None))
        baseline = ref.run()
        if ref.paged:
            ref.allocator.assert_quiescent()

    plans_warm = ttplan.plan_resolutions()
    clk = {"t": 0.0}
    sched = Scheduler(model, params, clock=step_clock(clk), **sched_kwargs)
    if durable_dir is not None:
        from .durable import DurableScheduler
        sched = DurableScheduler(sched, durable_dir,
                                 snapshot_every=snapshot_every)
    pending = sorted(
        zip(arrival_steps or [0] * len(requests), requests),
        key=lambda p: p[0])

    due_cancels = [(int(s), int(uid)) for s, uid in plan.cancels]
    resizes_by_step: dict[int, tuple] = {
        int(s): (slots, blocks) for s, slots, blocks in plan.resizes}

    step = 0
    restarts = 0
    kills = 0
    while pending or not sched.idle:
        if step >= max_steps:
            raise RuntimeError(
                f"fault run did not drain within {max_steps} steps "
                f"(queue={len(sched.queue)}, active={sched.num_active})")
        if step in plan.kill_steps:
            # kill -9: NOTHING in memory survives — no snapshot is taken.
            # Recovery = newest clean snapshot generation + journal replay.
            from .durable import DurableScheduler
            sched.close()                 # the OS would flush fds anyway
            del sched
            if corruptor is not None:
                corruptor(durable_dir, step)
            sched = DurableScheduler.recover(
                durable_dir, model, params, clock=step_clock(clk),
                snapshot_every=snapshot_every)
            kills += 1
        if step in plan.restart_steps:
            snap = sched.snapshot()
            carry = (sched.preemptions, sched.cancelled, sched.expired)
            del sched
            sched = Scheduler.from_snapshot(model, params, snap,
                                            clock=step_clock(clk))
            if durable_dir is not None:
                from .durable import DurableScheduler
                sched = DurableScheduler(sched, durable_dir,
                                         snapshot_every=snapshot_every)
            assert (sched.preemptions, sched.cancelled,
                    sched.expired) == carry
            restarts += 1
        while pending and pending[0][0] <= step:
            sched.submit(pending.pop(0)[1])
        still_due = []
        for s, uid in due_cancels:
            if s > step:
                still_due.append((s, uid))
            elif not sched.cancel(uid) and any(
                    r.uid == uid for _, r in pending):
                still_due.append((s, uid))    # not arrived yet: retry later
        due_cancels = still_due
        if step in resizes_by_step:
            slots, blocks = resizes_by_step[step]
            sched.resize(num_slots=slots, num_blocks=blocks)
        if sched.paged:
            sched.allocator.refuse_fresh = step in plan.alloc_fail_steps
        sched.hold_admissions = step in plan.hold_steps
        clk["t"] += 1.0
        sched.step()
        step += 1

    # ------------------------------------------------------------ invariants
    if sched.paged:
        sched.allocator.refuse_fresh = False
        sched.allocator.assert_quiescent()
    replans = ttplan.plan_resolutions() - plans_warm
    if replans:
        raise AssertionError(
            f"{replans} TT plan re-resolutions during the fault run — "
            f"faulted paths must reuse the primed PlanBook")
    finished = {f.uid: f for f in sched.finished}
    missing = {r.uid for r in requests} - set(finished)
    if missing:
        raise AssertionError(f"requests lost by the fault run: "
                             f"{sorted(missing)}")
    survivors = [u for u, f in finished.items()
                 if f.finish_reason not in ("cancelled", "deadline")]
    if check_identity:
        for u in survivors:
            got, ref_f = finished[u], baseline[u]
            if not np.array_equal(got.tokens, ref_f.tokens):
                raise AssertionError(
                    f"survivor uid={u} tokens diverged from the "
                    f"uninterrupted run: {got.tokens.tolist()} != "
                    f"{ref_f.tokens.tolist()}")
    return FaultReport(
        finished=finished, baseline=baseline, survivors=survivors,
        steps=step, restarts=restarts, preemptions=sched.preemptions,
        cancelled=sched.cancelled, expired=sched.expired, replans=replans,
        kills=kills)


# ------------------------------------------------------------------- on disk
_ARR = "__arr__"


def _split_arrays(obj, arrays: dict, path: str):
    """Recursively replace ndarray leaves with ``{"__arr__": key}`` markers,
    collecting the arrays keyed by their tree path."""
    if isinstance(obj, np.ndarray):
        arrays[path] = obj
        return {_ARR: path}
    if isinstance(obj, dict):
        return {k: _split_arrays(v, arrays, f"{path}/{k}")
                for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_split_arrays(v, arrays, f"{path}/{i}")
                for i, v in enumerate(obj)]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    return obj


def _join_arrays(obj, arrays: dict):
    if isinstance(obj, dict):
        if set(obj) == {_ARR}:
            return arrays[obj[_ARR]]
        return {k: _join_arrays(v, arrays) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_join_arrays(v, arrays) for v in obj]
    return obj


def _array_refs(tree, out: set) -> set:
    """Array keys referenced by ``{"__arr__": key}`` markers in ``tree``."""
    if isinstance(tree, dict):
        if set(tree) == {_ARR}:
            out.add(tree[_ARR])
        else:
            for v in tree.values():
                _array_refs(v, out)
    elif isinstance(tree, list):
        for v in tree:
            _array_refs(v, out)
    return out


def _validate_snapshot(path: str, tree, arrays: dict) -> None:
    """The manifest tree and the array payload must reference exactly the
    same key set — a mismatch (partial write, mixed-up files, manual
    edits) fails HERE with the offending keys, not as a ``KeyError`` deep
    inside ``_join_arrays``."""
    if not isinstance(tree, dict) or "version" not in tree:
        raise RuntimeError(
            f"snapshot at {path}: manifest tree is not a scheduler "
            f"snapshot (no 'version' field) — wrong or corrupted file")
    refs = _array_refs(tree, set())
    missing = sorted(refs - set(arrays))
    extra = sorted(set(arrays) - refs)
    if missing or extra:
        raise RuntimeError(
            f"snapshot at {path}: manifest/array mismatch — "
            f"{len(missing)} referenced arrays missing from the payload "
            f"({missing[:5]}{'…' if len(missing) > 5 else ''}), "
            f"{len(extra)} unreferenced arrays present "
            f"({extra[:5]}{'…' if len(extra) > 5 else ''})")


def save_snapshot(path: str, snap: dict) -> str:
    """Persist a ``Scheduler.snapshot()`` durably: commits the next
    checksummed generation under ``path`` (``core.durable``: chunked
    ``arrays.bin`` + manifest, temp + fsync + atomic rename).  Returns
    ``path`` — :func:`load_snapshot` reads the newest clean generation
    back from it."""
    arrays: dict[str, np.ndarray] = {}
    tree = _split_arrays(snap, arrays, "snap")
    durable.write_generation(path, tree, arrays)
    return path


def _load_legacy_snapshot(path: str) -> dict:
    """Pre-PR-8 single-directory layout: ``manifest.json`` + one
    ``arrays.npz`` directly under ``path`` (DESIGN.md §13 migration
    note).  Corruption surfaces as a clear RuntimeError, not a raw
    zipfile/numpy traceback."""
    with open(os.path.join(path, "manifest.json")) as f:
        tree = json.load(f)
    npz_path = os.path.join(path, "arrays.npz")
    try:
        with np.load(npz_path) as npz:
            arrays = {k: npz[k] for k in npz.files}
    except (zipfile.BadZipFile, ValueError, OSError, EOFError) as e:
        raise RuntimeError(
            f"legacy snapshot archive {npz_path} is corrupt or "
            f"truncated ({e}); re-save the snapshot with the current "
            f"generation-based format") from e
    _validate_snapshot(path, tree, arrays)
    return _join_arrays(tree, arrays)


def load_snapshot(path: str, generation: int | None = None) -> dict:
    """Load a persisted snapshot.  Default: the newest generation under
    ``path`` that passes every array checksum — torn or bit-flipped
    generations are skipped (never returned), falling back to the last
    fully-committed one.  ``generation`` pins one generation exactly
    (no fallback).  Also reads the pre-PR-8 ``arrays.npz`` layout."""
    if os.path.exists(os.path.join(path, "arrays.npz")):
        return _load_legacy_snapshot(path)
    if generation is not None:
        tree, arrays, _manifest = durable.load_generation(path, generation)
    else:
        _gen, tree, arrays, _manifest, _skipped = \
            durable.load_latest_good(path)
    _validate_snapshot(path, tree, arrays)
    return _join_arrays(tree, arrays)
