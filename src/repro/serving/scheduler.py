"""Continuous-batching scheduler over the jitted prefill/decode entry points.

One preallocated slot-pool KV cache (``Model.init_cache`` layout, batch dim
= ``num_slots``) is stepped by a single jitted masked decode whose shape
never changes, so arbitrary request arrival patterns are served without
retracing.  Per-slot state threads through ``cache["pos"]`` as a vector
[num_slots]; an ``active`` mask freezes retired/free slots (DESIGN.md §7).

Lifecycle of a request:

  submit() ─→ queue ─→ admission (free slot): single-request jitted prefill
  at the pool's ``cache_len`` + ``Model.splice_cache`` of the row into the
  pool (one in-place donated write) ─→ masked decode steps until EOS or the
  token budget ─→ retirement frees the slot for the next queued request.

The first generated token comes from the prefill logits (same contract as
``engine.generate``); sampling uses a per-request PRNG stream
(``fold_in(base_key, uid)``), split once per *sampled* token — greedy
decoding never consumes randomness, so temperature=0 results are
key-independent.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    """One generation request.  ``inputs`` are the per-request model inputs
    with leading batch dim 1 (at minimum ``tokens [1, S]``; multimodal
    frontends add their embedding arrays)."""
    uid: int
    inputs: dict
    max_new_tokens: int
    key: jax.Array | None = None          # per-request sampling stream


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    tokens: np.ndarray                    # [n_generated] int32
    logprobs: np.ndarray                  # [n_generated] float32
    finish_reason: str                    # "eos" | "length"
    prompt_len: int
    submit_time: float                    # perf_counter at submit()
    finish_time: float                    # perf_counter at retirement


@dataclasses.dataclass
class _Queued:
    req: Request
    prompt_len: int
    submit_time: float


@dataclasses.dataclass
class _Slot:
    uid: int
    max_new: int
    key: jax.Array | None
    prompt_len: int
    submit_time: float
    tokens: list[int] = dataclasses.field(default_factory=list)
    logprobs: list[float] = dataclasses.field(default_factory=list)
    last_tok: int = 0


class Scheduler:
    """Continuous-batching loop: ``submit()`` any time, ``step()`` advances
    every active slot by one token and admits queued requests into freed
    slots, ``run()`` drains."""

    def __init__(self, model: Model, params, num_slots: int, cache_len: int,
                 *, eos_id: int | None = None, temperature: float = 0.0,
                 key: jax.Array | None = None):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.model = model
        self.params = params
        # Touch the model's PlanBook up front: every TT layer's execution
        # plan is resolved (or confirmed resolved) here, outside any jit
        # trace, so admission prefills and the masked decode step perform
        # ZERO plan resolutions — asserted by tests via
        # kernels.plan.plan_resolutions() and the serve.py CI smoke.
        model.plan_book
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.temperature = float(temperature)
        self.base_key = key
        self.queue: deque[_Queued] = deque()
        self.slots: list[_Slot | None] = [None] * num_slots
        self.cache = None                 # pool; built from first prefill
        self.finished: list[FinishedRequest] = []
        self.steps_run = 0                # decode steps executed
        self.tokens_out = 0               # total generated tokens
        # shared across Scheduler instances of the same model: a server
        # creating one Scheduler per batch must not recompile the pick
        self._pick = model._jit_get(("pick", self.temperature),
                                    self._build_pick)

    # ------------------------------------------------------------- interface
    def submit(self, req: Request, submit_time: float | None = None) -> None:
        S = int(req.inputs["tokens"].shape[1])
        if self.model.cfg.frontend == "vit":
            S += int(req.inputs["image_embeds"].shape[1])
        if req.max_new_tokens < 0:
            raise ValueError("max_new_tokens must be >= 0")
        if S + req.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request uid={req.uid}: prompt ({S}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds cache_len={self.cache_len}")
        self.queue.append(_Queued(
            req, S, time.perf_counter() if submit_time is None
            else submit_time))

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def idle(self) -> bool:
        return not self.queue and self.num_active == 0

    def step(self) -> list[FinishedRequest]:
        """Admit into free slots, then run one masked decode step.  Returns
        the requests retired during this call."""
        done: list[FinishedRequest] = []
        for i in range(self.num_slots):
            if self.slots[i] is None and self.queue:
                self._admit(self.queue.popleft(), i, done)
        if self.num_active:
            self._decode_once(done)
        self.finished.extend(done)
        return done

    def run(self) -> dict[int, FinishedRequest]:
        """Drain queue + active slots; returns {uid: FinishedRequest}."""
        out = {}
        while not self.idle:
            for f in self.step():
                out[f.uid] = f
        return out

    # -------------------------------------------------------------- internal
    def _build_pick(self):
        temp = self.temperature

        def pick(logits, keys):
            """logits [B,V]; keys [B,2] uint32 (ignored when greedy) →
            (tokens [B] int32, logprobs [B] float32)."""
            lp = jax.nn.log_softmax(logits, -1)
            if temp == 0.0:
                tok = jnp.argmax(logits, -1)
            else:
                tok = jax.vmap(
                    lambda k, lg: jax.random.categorical(k, lg / temp)
                )(keys, logits)
            tok = tok.astype(jnp.int32)
            return tok, jnp.take_along_axis(lp, tok[:, None], -1)[:, 0]

        return jax.jit(pick)

    def _req_key(self, req: Request) -> jax.Array | None:
        if self.temperature == 0.0:
            return None                   # greedy: no randomness consumed
        if req.key is not None:
            return req.key
        base = (self.base_key if self.base_key is not None
                else jax.random.PRNGKey(0))
        return jax.random.fold_in(base, req.uid)

    def _next_key(self, slot: _Slot) -> jax.Array:
        slot.key, sub = jax.random.split(slot.key)
        return sub

    def _pick_one(self, logits_row, slot: _Slot) -> tuple[int, float]:
        """Pick for a single request (admission path): same jitted pick as
        the batched decode, batch dim 1."""
        if self.temperature == 0.0:
            keys = jnp.zeros((1, 2), jnp.uint32)
        else:
            keys = self._next_key(slot)[None]
        tok, lp = self._pick(logits_row[None], keys)
        return int(tok[0]), float(lp[0])

    def _ensure_pool(self, row_cache: dict) -> None:
        """Allocate the slot pool from the first prefilled row's cache tree
        (guarantees dtype/shape agreement with what prefill produces; every
        leaf except ``pos`` is [layers, 1, ...] → [layers, num_slots, ...])."""
        if self.cache is not None:
            return
        B = self.num_slots

        def expand(leaf):
            return jnp.zeros(leaf.shape[:1] + (B,) + leaf.shape[2:],
                             leaf.dtype)

        self.cache = {"pos": jnp.zeros((B,), jnp.int32)}
        for k, v in row_cache.items():
            if k != "pos":
                self.cache[k] = jax.tree.map(expand, v)

    def _admit(self, q: _Queued, slot_idx: int,
               done: list[FinishedRequest]) -> None:
        req = q.req
        if req.max_new_tokens == 0:       # nothing to generate: no prefill
            done.append(FinishedRequest(
                uid=req.uid, tokens=np.zeros((0,), np.int32),
                logprobs=np.zeros((0,), np.float32), finish_reason="length",
                prompt_len=q.prompt_len, submit_time=q.submit_time,
                finish_time=time.perf_counter()))
            return
        logits, row_cache = self.model.jitted_prefill(
            self.cache_len, shape_key=q.prompt_len)(self.params, req.inputs)
        slot = _Slot(uid=req.uid, max_new=req.max_new_tokens,
                     key=self._req_key(req),
                     prompt_len=q.prompt_len, submit_time=q.submit_time)
        tok, lp = self._pick_one(logits[0, -1], slot)
        slot.tokens.append(tok)
        slot.logprobs.append(lp)
        slot.last_tok = tok
        self.tokens_out += 1
        if self._finished_reason(slot):
            done.append(self._retire(slot))
            return                        # never occupied a decode slot
        self._ensure_pool(row_cache)
        self.cache = self.model.jitted_splice()(
            self.cache, row_cache, jnp.asarray(slot_idx, jnp.int32))
        self.slots[slot_idx] = slot

    def _decode_once(self, done: list[FinishedRequest]) -> None:
        B = self.num_slots
        toks = np.zeros((B, 1), np.int32)
        active = np.zeros((B,), bool)
        for i, s in enumerate(self.slots):
            if s is not None:
                toks[i, 0] = s.last_tok
                active[i] = True
        logits, self.cache = self.model.jitted_decode_step_masked()(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(active))
        if self.temperature == 0.0:
            keys = jnp.zeros((B, 2), jnp.uint32)
        else:
            keys = jnp.stack([
                self._next_key(s) if s is not None
                else jnp.zeros((2,), jnp.uint32)
                for s in self.slots])
        tok, lp = self._pick(logits[:, 0, :], keys)
        tok, lp = np.asarray(tok), np.asarray(lp)
        self.steps_run += 1
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.tokens.append(int(tok[i]))
            s.logprobs.append(float(lp[i]))
            s.last_tok = int(tok[i])
            self.tokens_out += 1
            if self._finished_reason(s):
                done.append(self._retire(s))
                self.slots[i] = None

    def _finished_reason(self, slot: _Slot) -> str | None:
        if self.eos_id is not None and slot.last_tok == self.eos_id:
            return "eos"
        if len(slot.tokens) >= slot.max_new:
            return "length"
        return None

    def _retire(self, slot: _Slot) -> FinishedRequest:
        return FinishedRequest(
            uid=slot.uid,
            tokens=np.asarray(slot.tokens, np.int32),
            logprobs=np.asarray(slot.logprobs, np.float32),
            finish_reason=self._finished_reason(slot),
            prompt_len=slot.prompt_len,
            submit_time=slot.submit_time,
            finish_time=time.perf_counter())


def make_requests(batch: dict, max_new_tokens: int,
                  key: jax.Array | None = None) -> list[Request]:
    """Split a pre-batched input dict (engine.generate contract) into one
    Request per row; row index becomes the uid."""
    arrays = {k: v for k, v in batch.items() if k != "cache_len"}
    B = arrays["tokens"].shape[0]
    out = []
    for b in range(B):
        out.append(Request(
            uid=b,
            inputs={k: v[b:b + 1] for k, v in arrays.items()},
            max_new_tokens=max_new_tokens,
            key=None if key is None else jax.random.fold_in(key, b)))
    return out
