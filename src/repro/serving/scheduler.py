"""Continuous-batching scheduler over the jitted prefill/decode entry points.

Two pool layouts serve the same masked decode step (DESIGN.md §7):

  dense (default) — one preallocated slot-pool KV cache (``Model.init_cache``
  layout, batch dim = ``num_slots``): every slot owns ``cache_len`` rows of
  every leaf regardless of how many tokens it actually holds.

  paged (``paged=True``) — fixed-size blocks in per-leaf arenas
  ``[layers, num_blocks + 1, block, ...]`` plus a per-slot block table;
  admission reserves ``ceil((prompt + max_new) / block)`` blocks from a
  refcounted free list (``serving.paging.BlockAllocator``), so admission is
  *by memory, not slot count*, a 16-token request holds one block where a
  4096-token request holds 64, and requests whose prompt prefix hashes to
  already-resident blocks share them copy-on-write and skip the covered
  prefill compute entirely (``prefill_resume``).

Lifecycle of a request:

  submit() ─→ queue ─→ admission (free slot + free blocks): bucketed
  single-request jitted prefill (or suffix-only resume prefill on a prefix
  hit) + a donated splice/scatter into the pool ─→ masked decode steps
  until EOS or the token budget ─→ retirement frees the slot and decrefs
  its blocks (published prefix blocks stay cached until evicted LRU).

The first generated token comes from the prefill logits (same contract as
``engine.generate``).  Sampling parameters ride on the ``Request``
(``temperature``, ``top_k``); each sampled request draws from its own PRNG
stream (``fold_in(base_key, uid)``), split once per *sampled* token —
greedy requests never consume randomness, so temperature=0 results are
key-independent.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.models.transformer import block_cache_kinds
from .paging import BlockAllocator, chain_hashes, logical_blocks

NEG_INF = -1e30


@dataclasses.dataclass
class Request:
    """One generation request.  ``inputs`` are the per-request model inputs
    with leading batch dim 1 (at minimum ``tokens [1, S]``; multimodal
    frontends add their embedding arrays).  ``temperature``/``top_k`` are
    per-request sampling parameters: temperature 0 is greedy (consumes no
    PRNG), top_k 0 disables the top-k filter."""
    uid: int
    inputs: dict
    max_new_tokens: int
    key: jax.Array | None = None          # per-request sampling stream
    temperature: float = 0.0
    top_k: int = 0


@dataclasses.dataclass
class FinishedRequest:
    uid: int
    tokens: np.ndarray                    # [n_generated] int32
    logprobs: np.ndarray                  # [n_generated] float32
    finish_reason: str                    # "eos" | "length"
    prompt_len: int
    submit_time: float                    # perf_counter at submit()
    finish_time: float                    # perf_counter at retirement


@dataclasses.dataclass
class _Queued:
    req: Request
    prompt_len: int
    submit_time: float


@dataclasses.dataclass
class _Slot:
    uid: int
    max_new: int
    key: jax.Array | None
    prompt_len: int
    submit_time: float
    temperature: float = 0.0
    top_k: int = 0
    tokens: list[int] = dataclasses.field(default_factory=list)
    logprobs: list[float] = dataclasses.field(default_factory=list)
    last_tok: int = 0


class Scheduler:
    """Continuous-batching loop: ``submit()`` any time, ``step()`` advances
    every active slot by one token and admits queued requests into freed
    slots, ``run()`` drains."""

    def __init__(self, model: Model, params, num_slots: int, cache_len: int,
                 *, eos_id: int | None = None, key: jax.Array | None = None,
                 paged: bool = False, block_size: int = 64,
                 num_blocks: int | None = None, prefix_cache: bool = True,
                 bucket_prompts: bool = True):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.model = model
        self.params = params
        # Touch the model's PlanBook up front: every TT layer's execution
        # plan is resolved (or confirmed resolved) here, outside any jit
        # trace, so admission prefills and the masked decode step perform
        # ZERO plan resolutions — asserted by tests via
        # kernels.plan.plan_resolutions() and the serve.py CI smoke.
        model.plan_book
        self.num_slots = num_slots
        self.eos_id = eos_id
        self.base_key = key
        self.paged = paged
        self.bucket_prompts = bucket_prompts
        if paged:
            self.block = block_size
            self.max_blocks = logical_blocks(cache_len, block_size)
            # the pool's logical length is block-aligned so prefilled rows
            # scatter into whole blocks
            self.cache_len = self.max_blocks * block_size
            self.num_blocks = (num_blocks if num_blocks is not None
                               else num_slots * self.max_blocks)
            self.allocator = BlockAllocator(self.num_blocks, block_size)
            self.prefix_cache = prefix_cache and model.supports_prefix_reuse
            self._slot_blocks: list[list[int] | None] = [None] * num_slots
            self.block_hwm = 0                # live blocks high-water mark
            self.prefix_hit_tokens = 0        # prompt tokens found resident
            self.prefix_prompt_tokens = 0     # prompt tokens seen (paged)
            self.prefill_tokens_skipped = 0   # prefill compute avoided
        else:
            self.cache_len = cache_len
        self.queue: deque[_Queued] = deque()
        self.slots: list[_Slot | None] = [None] * num_slots
        self.cache = None                 # pool; built from first prefill
        self.finished: list[FinishedRequest] = []
        self.steps_run = 0                # decode steps executed
        self.tokens_out = 0               # total generated tokens
        # shared across Scheduler instances of the same model: a server
        # creating one Scheduler per batch must not recompile the pick
        self._pick = model._jit_get("pick", self._build_pick)

    # ------------------------------------------------------------- interface
    def submit(self, req: Request, submit_time: float | None = None) -> None:
        S = int(req.inputs["tokens"].shape[1])
        if self.model.cfg.frontend == "vit":
            S += int(req.inputs["image_embeds"].shape[1])
        if req.max_new_tokens < 0:
            raise ValueError("max_new_tokens must be >= 0")
        if S + req.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request uid={req.uid}: prompt ({S}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds cache_len={self.cache_len}")
        if self.paged and logical_blocks(
                S + req.max_new_tokens, self.block) > self.num_blocks:
            raise ValueError(
                f"request uid={req.uid} needs more blocks than the pool "
                f"has ({self.num_blocks}) — it could never be admitted")
        self.queue.append(_Queued(
            req, S, time.perf_counter() if submit_time is None
            else submit_time))

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def idle(self) -> bool:
        return not self.queue and self.num_active == 0

    def stats(self) -> dict:
        """Pool/paging counters for reporting (serve.py, bench_serve_tt)."""
        out = {"tokens_out": self.tokens_out, "steps_run": self.steps_run,
               "kv_pool_bytes": self.kv_pool_bytes()}
        if self.paged:
            out.update(
                block_size=self.block, num_blocks=self.num_blocks,
                blocks_in_use=self.allocator.in_use,
                block_high_water=self.block_hwm,
                prefix_hit_tokens=self.prefix_hit_tokens,
                prefix_prompt_tokens=self.prefix_prompt_tokens,
                prefill_tokens_skipped=self.prefill_tokens_skipped,
                prefix_hit_rate=(
                    self.prefix_hit_tokens / self.prefix_prompt_tokens
                    if self.prefix_prompt_tokens else 0.0))
        return out

    def kv_pool_bytes(self) -> int:
        if self.cache is None:
            return 0
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache))

    def reset_stats(self) -> None:
        """Zero the reporting counters (after a warm-up request, so compile
        effects stay out of steady-state numbers).  Owned here so every
        counter added to :meth:`stats` gets excluded by construction."""
        self.finished.clear()
        self.tokens_out = self.steps_run = 0
        if self.paged:
            self.block_hwm = self.allocator.in_use
            self.prefix_hit_tokens = self.prefix_prompt_tokens = 0
            self.prefill_tokens_skipped = 0

    def step(self) -> list[FinishedRequest]:
        """Admit into free slots (paged mode additionally requires the
        block reservation to fit — admission by memory), then run one
        masked decode step.  Returns the requests retired during this
        call."""
        done: list[FinishedRequest] = []
        blocked = False                    # head failure is slot-independent
        for i in range(self.num_slots):
            while self.queue and self.slots[i] is None:
                if not self._try_admit(self.queue[0], i, done):
                    blocked = True         # head doesn't fit: keep FIFO order
                    break
                self.queue.popleft()
            if blocked:
                break
        if self.num_active:
            self._decode_once(done)
        self.finished.extend(done)
        return done

    def run(self) -> dict[int, FinishedRequest]:
        """Drain queue + active slots; returns {uid: FinishedRequest}."""
        out = {}
        while not self.idle:
            for f in self.step():
                out[f.uid] = f
        return out

    # -------------------------------------------------------------- sampling
    def _build_pick(self):
        def pick(logits, keys, temps, topk):
            """logits [B,V]; keys [B,2] uint32 (ignored for greedy rows);
            temps [B] float32; topk [B] int32 (0 = no filter) →
            (tokens [B] int32, logprobs [B] float32).  One compiled pick
            serves every mix of per-request sampling params."""
            V = logits.shape[-1]
            lp = jax.nn.log_softmax(logits, -1)
            greedy = jnp.argmax(logits, -1)
            srt = jnp.sort(logits, axis=-1)[:, ::-1]          # descending
            kth = jnp.take_along_axis(
                srt, jnp.clip(topk - 1, 0, V - 1)[:, None], 1)[:, 0]
            keep = (topk[:, None] <= 0) | (logits >= kth[:, None])
            safe_t = jnp.where(temps > 0, temps, 1.0)[:, None]
            scaled = jnp.where(keep, logits, NEG_INF) / safe_t
            sampled = jax.vmap(jax.random.categorical)(keys, scaled)
            tok = jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
            return tok, jnp.take_along_axis(lp, tok[:, None], -1)[:, 0]

        return jax.jit(pick)

    def _req_key(self, req: Request) -> jax.Array | None:
        if req.temperature <= 0.0:
            return None                   # greedy: no randomness consumed
        if req.key is not None:
            return req.key
        base = (self.base_key if self.base_key is not None
                else jax.random.PRNGKey(0))
        # uids may be negative (warm-up requests); fold_in wants uint32
        return jax.random.fold_in(base, req.uid & 0xFFFFFFFF)

    def _next_key(self, slot: _Slot) -> jax.Array:
        slot.key, sub = jax.random.split(slot.key)
        return sub

    def _pick_one(self, logits_row, slot: _Slot) -> tuple[int, float]:
        """Pick for a single request (admission path): same jitted pick as
        the batched decode, batch dim 1."""
        if slot.temperature > 0.0:
            keys = self._next_key(slot)[None]
        else:
            keys = jnp.zeros((1, 2), jnp.uint32)
        tok, lp = self._pick(
            logits_row[None], keys,
            jnp.asarray([slot.temperature], jnp.float32),
            jnp.asarray([slot.top_k], jnp.int32))
        return int(tok[0]), float(lp[0])

    # ------------------------------------------------------------ pool build
    def _ensure_pool(self, row_cache: dict) -> None:
        """Allocate the pool from the first prefilled row's cache tree
        (guarantees dtype/shape agreement with what prefill produces)."""
        if self.cache is not None:
            return
        B = self.num_slots
        if not self.paged:
            def expand(leaf):
                return jnp.zeros(leaf.shape[:1] + (B,) + leaf.shape[2:],
                                 leaf.dtype)

            self.cache = {"pos": jnp.zeros((B,), jnp.int32)}
            for k, v in row_cache.items():
                if k != "pos":
                    self.cache[k] = jax.tree.map(expand, v)
            return
        nb1 = self.num_blocks + 1         # + write-sentinel block
        cache: dict = {
            "pos": jnp.zeros((B,), jnp.int32),
            "block_tables": jnp.full((B, self.max_blocks), self.num_blocks,
                                     jnp.int32)}
        for gi, (period, _count) in enumerate(self.model.groups):
            g = {}
            for i, bd in enumerate(period):
                kinds = block_cache_kinds(bd)
                b = {}
                for name, row in row_cache[f"g{gi}"][f"b{i}"].items():
                    if kinds[name] == "slot":
                        b[name] = jnp.zeros(
                            row.shape[:1] + (B,) + row.shape[2:], row.dtype)
                    else:                 # row [layers, 1, T, ...] → arena
                        b[name] = jnp.zeros(
                            (row.shape[0], nb1, self.block) + row.shape[3:],
                            row.dtype)
                g[f"b{i}"] = b
            cache[f"g{gi}"] = g
        self.cache = cache

    # -------------------------------------------------------------- admission
    def _try_admit(self, q: _Queued, slot_idx: int,
                   done: list[FinishedRequest]) -> bool:
        """Admit the queue head into ``slot_idx``.  Returns False when the
        paged pool cannot reserve the request's blocks yet (the request
        stays queued; retirements will free blocks)."""
        req = q.req
        if req.max_new_tokens == 0:       # nothing to generate: no prefill
            done.append(FinishedRequest(
                uid=req.uid, tokens=np.zeros((0,), np.int32),
                logprobs=np.zeros((0,), np.float32), finish_reason="length",
                prompt_len=q.prompt_len, submit_time=q.submit_time,
                finish_time=time.perf_counter()))
            return True
        if self.paged:
            return self._admit_paged(q, slot_idx, done)
        self._admit_dense(q, slot_idx, done)
        return True

    def _row_prefill(self, inputs):
        if self.bucket_prompts:
            fn = self.model.jitted_prefill_bucketed(self.cache_len)
            return fn(self.params, inputs)
        return self.model.jitted_prefill(
            self.cache_len,
            shape_key=int(inputs["tokens"].shape[1]))(self.params, inputs)

    def _start_slot(self, q: _Queued) -> _Slot:
        req = q.req
        return _Slot(uid=req.uid, max_new=req.max_new_tokens,
                     key=self._req_key(req), prompt_len=q.prompt_len,
                     submit_time=q.submit_time,
                     temperature=float(req.temperature),
                     top_k=int(req.top_k))

    def _admit_dense(self, q: _Queued, slot_idx: int,
                     done: list[FinishedRequest]) -> None:
        logits, row_cache = self._row_prefill(q.req.inputs)
        slot = self._start_slot(q)
        tok, lp = self._pick_one(logits[0, -1], slot)
        slot.tokens.append(tok)
        slot.logprobs.append(lp)
        slot.last_tok = tok
        self.tokens_out += 1
        if self._finished_reason(slot):
            done.append(self._retire(slot))
            return                        # never occupied a decode slot
        self._ensure_pool(row_cache)
        self.cache = self.model.jitted_splice()(
            self.cache, row_cache, jnp.asarray(slot_idx, jnp.int32))
        self.slots[slot_idx] = slot

    def _admit_paged(self, q: _Queued, slot_idx: int,
                     done: list[FinishedRequest]) -> bool:
        req = q.req
        S = q.prompt_len
        blk = self.block
        alloc = self.allocator
        need = logical_blocks(min(S + req.max_new_tokens, self.cache_len),
                              blk)
        # ---- prefix lookup: acquire the longest chain of resident blocks
        hashes: list[bytes] = []
        shared: list[int] = []
        if self.prefix_cache:
            hashes = chain_hashes(np.asarray(req.inputs["tokens"]), blk)
            for h in hashes:
                bid = alloc.acquire(h)
                if bid is None:
                    break
                shared.append(bid)
        matched = len(shared)
        covered = matched * blk
        full_cover = matched > 0 and covered >= S
        # resume must compute >= 1 token for logits: full coverage COWs the
        # last matched block and recomputes only its final token
        start = S - 1 if full_cover else covered
        fresh_needed = need - matched + (1 if full_cover else 0)
        # if we are the COW source's only owner, the COW's decref returns
        # it to the pool mid-admission — credit it, or an idle pool could
        # refuse a request that actually fits (admission livelock)
        credit = (1 if full_cover and alloc.refcount(shared[-1]) == 1
                  else 0)
        if fresh_needed > alloc.available + credit:
            for bid in shared:            # rollback: request stays queued
                alloc.decref(bid)
            return False
        # ---- build source/destination tables (dst != src ⇒ COW block)
        src = list(shared)
        dst = list(shared)
        if full_cover:
            dst[-1] = alloc.cow(shared[-1])
        fresh = [alloc.alloc() for _ in range(need - len(dst))]
        src += fresh
        dst += fresh
        sentinel = self.num_blocks
        src_t = np.full(self.max_blocks, sentinel, np.int32)
        dst_t = np.full(self.max_blocks, sentinel, np.int32)
        src_t[:len(src)] = src
        dst_t[:len(dst)] = dst
        # ---- prefill: full prompt (splice) or suffix only (resume)
        slot = self._start_slot(q)
        if start == 0:
            logits, row_cache = self._row_prefill(req.inputs)
            self._ensure_pool(row_cache)
            self.cache = self.model.jitted_splice_paged()(
                self.cache, row_cache, jnp.asarray(slot_idx, jnp.int32),
                jnp.asarray(dst_t))
        else:
            suffix = {k: (v[:, start:] if k == "tokens" else v)
                      for k, v in req.inputs.items()}
            logits, self.cache = self.model.jitted_prefill_resume(
                self.cache_len)(self.params, suffix, self.cache, slot_idx,
                                src_t, dst_t, start, S - start)
            self.prefill_tokens_skipped += start
        # ---- publish full prompt blocks for future sharing
        if self.prefix_cache:
            for i in range(min(len(hashes), len(dst))):
                alloc.publish(dst[i], hashes[i])
        self._slot_blocks[slot_idx] = dst
        self.prefix_prompt_tokens += S
        self.prefix_hit_tokens += min(covered, S)
        self.block_hwm = max(self.block_hwm, alloc.in_use)
        # ---- first token
        tok, lp = self._pick_one(logits[0, -1], slot)
        slot.tokens.append(tok)
        slot.logprobs.append(lp)
        slot.last_tok = tok
        self.tokens_out += 1
        if self._finished_reason(slot):
            done.append(self._retire(slot))
            self._release_blocks(slot_idx)
            return True                   # never occupied a decode slot
        self.slots[slot_idx] = slot
        return True

    def _release_blocks(self, slot_idx: int) -> None:
        blocks = self._slot_blocks[slot_idx]
        if blocks is not None:
            for bid in blocks:
                self.allocator.decref(bid)
            self._slot_blocks[slot_idx] = None

    # ---------------------------------------------------------------- decode
    def _decode_once(self, done: list[FinishedRequest]) -> None:
        B = self.num_slots
        toks = np.zeros((B, 1), np.int32)
        active = np.zeros((B,), bool)
        temps = np.zeros((B,), np.float32)
        topk = np.zeros((B,), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                toks[i, 0] = s.last_tok
                active[i] = True
                temps[i] = s.temperature
                topk[i] = s.top_k
        logits, self.cache = self.model.jitted_decode_step_masked()(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(active))
        if any(s is not None and s.temperature > 0.0 for s in self.slots):
            keys = jnp.stack([
                self._next_key(s) if s is not None and s.temperature > 0.0
                else jnp.zeros((2,), jnp.uint32)
                for s in self.slots])
        else:                             # all greedy: no splits consumed
            keys = jnp.zeros((B, 2), jnp.uint32)
        tok, lp = self._pick(logits[:, 0, :], keys, jnp.asarray(temps),
                             jnp.asarray(topk))
        tok, lp = np.asarray(tok), np.asarray(lp)
        self.steps_run += 1
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.tokens.append(int(tok[i]))
            s.logprobs.append(float(lp[i]))
            s.last_tok = int(tok[i])
            self.tokens_out += 1
            if self._finished_reason(s):
                done.append(self._retire(s))
                if self.paged:
                    self._release_blocks(i)
                self.slots[i] = None

    def _finished_reason(self, slot: _Slot) -> str | None:
        if self.eos_id is not None and slot.last_tok == self.eos_id:
            return "eos"
        if len(slot.tokens) >= slot.max_new:
            return "length"
        return None

    def _retire(self, slot: _Slot) -> FinishedRequest:
        return FinishedRequest(
            uid=slot.uid,
            tokens=np.asarray(slot.tokens, np.int32),
            logprobs=np.asarray(slot.logprobs, np.float32),
            finish_reason=self._finished_reason(slot),
            prompt_len=slot.prompt_len,
            submit_time=slot.submit_time,
            finish_time=time.perf_counter())


def make_requests(batch: dict, max_new_tokens: int,
                  key: jax.Array | None = None, temperature: float = 0.0,
                  top_k: int = 0) -> list[Request]:
    """Split a pre-batched input dict (engine.generate contract) into one
    Request per row; row index becomes the uid.  The batch-level sampling
    params become per-request params."""
    arrays = {k: v for k, v in batch.items() if k != "cache_len"}
    B = arrays["tokens"].shape[0]
    out = []
    for b in range(B):
        out.append(Request(
            uid=b,
            inputs={k: v[b:b + 1] for k, v in arrays.items()},
            max_new_tokens=max_new_tokens,
            key=None if key is None else jax.random.fold_in(key, b),
            temperature=temperature, top_k=top_k))
    return out
